"""Target-legality analyzer over schedules (pass 2) + the combined API.

Statically proves every fusion group's schedule lowerable: tile names
applicable to the kernel kind, grid divisibility and lane/sublane
alignment of the tiles the lowerer will ACTUALLY use (lowerers clamp a
tile to its dimension before building the grid, so the analyzer
reasons about ``eff = min(tile, dim)``, not the raw schedule value —
a default 128-tile on a 64-wide dim is legal and lowers as one block),
pipelined VMEM footprint against the capacity budget, loop orders,
split-K flags, epilogues, and compute-dtype support.

``target=None`` analyzes against the portability envelope of
DESIGN.md §9 (16 MiB VMEM, 8-sublane alignment — legal everywhere, the
same budget ``rules.check_tiles`` enforces at rewrite time); an
explicit ``HardwareTarget`` analyzes against that chip's real
lane/sublane/VMEM geometry and dtype tables, catching e.g. a float16
compute dtype on a TPU before any lowering is attempted.

``analyze_program`` composes pass 1 + pass 2 (legality only runs when
well-formedness holds — schedules over a broken graph produce noise,
not signal); ``check_program`` is the raising form the gates use.
"""
from __future__ import annotations

from repro.analysis.diagnostics import (AnalysisError, Diagnostic, error,
                                        warning)
from repro.core import hardware, rules
from repro.core.kernel_ir import KernelProgram, sched_kind, \
    sched_kind_of_group

# kinds whose matrix-unit tiles must respect sublane alignment
ALIGNED_KINDS = ("matmul", "grouped_matmul", "flash_attention")
MAX_PIPELINE_DEPTH = 8

# legal loop-order letter sets per kernel kind (sorted)
_ORDERS = {"matmul": (["k", "m", "n"],),
           "grouped_matmul": (["k", "m", "n"], ["c", "d", "f"])}

_EPILOGUES: set[str] = set()


def _legal_epilogues() -> set[str]:
    if not _EPILOGUES:
        ops = sorted(rules.FUSABLE_EPILOGUES)
        _EPILOGUES.update(ops)
        _EPILOGUES.update(f"{a}_{b}" for a in ops for b in ops)
    return _EPILOGUES


def _group_schedule_diags(prog: KernelProgram, group: tuple[str, ...],
                          tgt, envelope: bool) -> list[Diagnostic]:
    out: list[Diagnostic] = []
    kind = sched_kind_of_group(prog, group)
    sched = prog.schedule_for(group)
    root = prog.group_root(group)
    span = (root,)
    nm = prog.node_map
    main = next((nm[n] for n in group
                 if sched_kind(nm[n].op) == kind), nm[group[0]])
    dims = rules.tileable_dims(main, prog.shapes(), prog.input_specs)
    align = 8 if envelope else max(8, tgt.sublane)

    # tiles: applicability, divisibility of the CLAMPED tile, alignment
    eff: dict[str, int] = {}
    for tname, t in sched.blocks_dict.items():
        if dims and tname not in dims:
            out.append(error(
                "MT020", f"tile parameter {tname!r} not applicable to "
                f"{kind} kernel {main.name!r} (has {sorted(dims)})",
                span=span,
                hint=f"use one of {sorted(dims)}"))
            continue
        if tname not in dims:
            continue
        d = dims[tname]
        if t <= 0:
            out.append(error(
                "MT021", f"tile {tname}={t} must be positive",
                span=span))
            continue
        e = min(int(t), d)
        eff[tname] = e
        if e and d % e != 0:
            # the rmsnorm lowerer degrades a non-dividing rows tile to
            # row-at-a-time instead of refusing — report, don't gate
            mk = warning if kind == "rmsnorm" else error
            out.append(mk(
                "MT021", f"tile {tname}={t} (clamped to {e}) does not "
                f"divide dim {d} of {main.name!r}", span=span,
                hint=f"pick a divisor of {d}"))
        if kind in ALIGNED_KINDS and e % align != 0 and e != d:
            out.append(error(
                "MT022", f"tile {tname}={e} is not {align}-aligned for "
                f"{kind} on {tgt.name}", span=span,
                hint=f"tiles must be multiples of {align} (sublane)"))

    # pipelined VMEM footprint of the effective tiles
    depth = sched.pipeline_depth
    if not 1 <= depth <= MAX_PIPELINE_DEPTH:
        out.append(error(
            "MT024", f"pipeline depth {depth} out of range "
            f"[1, {MAX_PIPELINE_DEPTH}]", span=span))
    else:
        budget = rules.VMEM_BYTES if envelope else tgt.vmem_bytes
        vmem = rules.vmem_tile_bytes(kind, eff, dims)
        if vmem * max(1, depth) > budget:
            out.append(error(
                "MT023", f"VMEM overflow on {tgt.name}: "
                f"{vmem * max(1, depth) / 2**20:.1f} MiB (depth "
                f"{depth}) > {budget / 2**20:.0f} MiB budget",
                span=span,
                hint="shrink tiles or lower pipeline_depth"))

    # loop order
    order = sched.loop_order
    if order:
        legal = _ORDERS.get(kind)
        if legal is None:
            out.append(error(
                "MT025", f"{kind} kernels take no loop order; schedule "
                f"has {order}", span=span))
        elif sorted(order) not in [list(o) for o in legal]:
            out.append(error(
                "MT025", f"invalid loop order {order} for {kind}",
                span=span,
                hint=f"a permutation of one of {legal}"))

    # split-K flags
    for f in sched.flags:
        if not f.startswith(rules.SplitKRule.FLAG):
            continue
        raw = f[len(rules.SplitKRule.FLAG):]
        try:
            S = int(raw)
        except ValueError:
            out.append(error(
                "MT027", f"unparseable split_k flag {f!r}", span=span))
            continue
        msg = ""
        if kind != "matmul":
            msg = f"split_k on a {kind} kernel (matmul only)"
        elif not 2 <= S <= 16:
            msg = f"split factor {S} out of range [2, 16]"
        else:
            skr = rules.SplitKRule()
            d2 = skr._anchor_dims(prog, group)
            if d2 is None:
                msg = "split_k kernel has no single matmul anchor"
            else:
                M, K = d2
                if M > skr.SKINNY_M:
                    msg = (f"split_k is for skinny-M matmuls "
                           f"(M={M} > {skr.SKINNY_M})")
                elif K % S != 0 or (K // S) % 8 != 0:
                    msg = (f"split factor {S} does not divide K={K} "
                           "into lane-aligned chunks")
        if msg:
            out.append(error("MT027", msg, span=span,
                             hint="see rules.SplitKRule legality"))

    # epilogue: "" | "none" | op | op_op over the fusable vocabulary
    # (ops themselves contain underscores — row_max — so membership is
    # checked against the enumerated legal strings, not split tokens)
    epi = sched.epilogue
    if epi not in ("", "none") and epi not in _legal_epilogues():
        out.append(error(
            "MT028", f"unknown schedule epilogue {epi!r}", span=span,
            hint="an epilogue is one or two '_'-joined ops from "
                 f"{sorted(rules.FUSABLE_EPILOGUES)}"))

    # compute dtype vs the target's matrix-unit tables
    if not envelope:
        table = dict(tgt.matmul_flops_by_dtype)
        for n in group:
            dt = nm[n].attr("compute_dtype")
            if dt is None or dt == "float32":
                continue
            key = hardware._DTYPE_TABLE_KEYS.get(dt, dt)
            if key not in table:
                out.append(error(
                    "MT026", f"compute dtype {dt!r} on node {n!r} has "
                    f"no matmul rate on {tgt.name} "
                    f"(supports {sorted(table)})", span=(n,),
                    hint="pick a dtype the target's matrix unit "
                         "supports, or float32"))
    return out


def analyze_legality(prog: KernelProgram,
                     target=None) -> list[Diagnostic]:
    """Pass 2 alone — assumes ``prog`` is well-formed (run the
    verifier first; ``analyze_program`` composes both)."""
    envelope = target is None
    tgt = hardware.resolve(target)
    out: list[Diagnostic] = []
    for g in prog.fusion_groups:
        out += _group_schedule_diags(prog, g, tgt, envelope)
    return out


def analyze_program(prog: KernelProgram,
                    target=None) -> list[Diagnostic]:
    """Full static analysis: well-formedness, then (only when no
    errors — schedules over a broken graph are noise) target
    legality.  Errors first, then warnings, each stably ordered."""
    from repro.analysis.verifier import verify_program
    diags = verify_program(prog)
    if not any(d.is_error for d in diags):
        diags += analyze_legality(prog, target)
    return (sorted((d for d in diags if d.is_error),
                   key=lambda d: (d.code, d.span))
            + sorted((d for d in diags if not d.is_error),
                     key=lambda d: (d.code, d.span)))


def check_program(prog: KernelProgram, target=None,
                  name: str = "") -> list[Diagnostic]:
    """Gate form: raise ``AnalysisError`` carrying every ERROR
    diagnostic; return the warnings (callers may log them)."""
    diags = analyze_program(prog, target)
    errors = tuple(d for d in diags if d.is_error)
    if errors:
        raise AnalysisError(errors, program=name)
    return [d for d in diags if not d.is_error]
