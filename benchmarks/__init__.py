"""Benchmark harness package (one module per paper table).

A real package (not a path-hack namespace): modules import each other
relatively, so ``python -m benchmarks.run`` works from any directory
with the repo root and ``src/`` on PYTHONPATH.
"""
