"""Serve-path benchmark: the online half of the evaluate/serve loop.

Three streams, mirroring production traffic shapes:

* **KernelService** under a Zipf-skewed optimize-request stream (hot
  kernels dominate, as many users submit the same few) driven by
  concurrent client threads — reports throughput, p50/p99 request
  latency, the coalescing hit-rate (identical in-flight requests
  sharing one search) and the segmented-LRU slab-eviction counters
  that replaced the old drop-wholesale store reset.
* **Fleet** (DESIGN.md §13): a multi-tenant Zipf stream over N
  replicas sharing ONE measurement DB — (F1) the in-process fleet with
  background measured refinement, gating that at least one analytic
  answer is hot-swapped for a measured winner mid-stream; (F2) a
  separate-process replica wave against a single-replica baseline,
  gating aggregate throughput scaling and that the shared winner store
  deduplicates search work (dup_ratio) with cross-replica warm starts;
  (F3) a restart wave over the warm DB, gating a zero-re-search
  warm-start rate.
* **Engine** under a mixed-length prompt stream — continuous batching
  with per-slot positions; reports token throughput, per-request
  completion latency and mean slot occupancy, plus a batched-vs-solo
  parity check (the mixed-length correctness bug this PR fixes).

Gates (non-zero exit, wired into CI bench-smoke):
  * coalescing hit-rate must be > 0 on the repeated-request burst,
  * every service/fleet result must be oracle-correct,
  * the fleet must hot-swap >= 1 analytic pick for a measured winner,
  * multi-process replicas must scale aggregate throughput vs one
    replica, share search work through the DB (dup_ratio bounded,
    peer warm starts observed), and a restarted replica must answer
    repeats with ZERO re-searches (warm_rate gated, also via
    check_regression on the committed CSV),
  * batched Engine output must be token-identical to solo generation,
  * slab eviction must have run without a whole-store reset (the
    mechanism no longer exists; the counter row pins that).

  PYTHONPATH=src python -m benchmarks.serve_bench [--fast]
      [--out results/serve_bench.txt] [--csv results/serve_bench.csv]
"""
from __future__ import annotations

import argparse
import concurrent.futures as cf
import dataclasses
import os
import time

import numpy as np

from repro.core import OptimizeConfig

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

# the measured-mode scenario's search signature: beam search, depth 3,
# rerank the top 3 survivors by measured time
_BEAM3 = OptimizeConfig(mode="greedy_cost", strategy="beam",
                        max_steps=3, rerank_top_k=3)


def _pct(xs, p) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), p))


# ---------------------------------------------------------------------------
# KernelService stream
# ---------------------------------------------------------------------------

def bench_service(fast: bool) -> tuple[dict, list[str]]:
    from repro.core import tasks as T
    from repro.serve.engine import KernelService

    suite = T.kb_level1() + T.kb_level2() + T.kb_level3()
    n_req = 80 if fast else 300
    svc = KernelService(config=OptimizeConfig(
                            mode="greedy_cost",
                            max_steps=3 if fast else 6),
                        serve_workers=4,
                        max_programs=150 if fast else 1200,
                        evict_slab=30 if fast else 150)
    hot = suite[0]

    # phase 1 — repeated-request burst: the same task submitted
    # back-to-back while the first search is in flight MUST coalesce
    t0 = time.perf_counter()
    burst = [svc.submit(hot) for _ in range(16)]
    burst_res = [svc.result(f) for f in burst]
    burst_s = time.perf_counter() - t0
    burst_coalesced = svc.stats()["coalesced"]

    # phase 2 — Zipf-skewed concurrent client stream
    rng = np.random.default_rng(0)
    picks = [(int(z) - 1) % len(suite) for z in rng.zipf(1.5, n_req)]

    def one(i: int):
        t = time.perf_counter()
        r = svc.optimize(suite[i])
        return time.perf_counter() - t, bool(r.correct)

    t0 = time.perf_counter()
    with cf.ThreadPoolExecutor(max_workers=8) as ex:
        timed = list(ex.map(one, picks))
    wall = time.perf_counter() - t0
    svc.close()

    lats = [t for t, _ in timed]
    st = svc.stats()
    hot_fp = burst_res[0].program.fingerprint()

    # phase 3 — measured-mode spot check: a small measured service with
    # an on-disk DB; the restarted service must warm-start from it
    meas = _measured_spot_check()

    m = {
        "requests": st["requests"],
        "throughput_rps": n_req / wall,
        "p50_ms": 1e3 * _pct(lats, 50),
        "p99_ms": 1e3 * _pct(lats, 99),
        "coalesced": st["coalesced"],
        "coalesce_rate": st["coalesced"] / st["requests"],
        "burst_coalesced": burst_coalesced,
        "evictions": st["evictions"],
        "evicted_programs": st["evicted_programs"],
        "whole_store_resets": 0,     # mechanism removed: slabs only
        "hot_winner_cached": int(hot_fp in svc.store.programs),
        "store_programs": len(svc.store.programs),
        "all_correct": int(all(ok for _, ok in timed)
                           and all(r.correct for r in burst_res)),
        **{f"measured_{k}": v for k, v in meas.items()},
    }
    lines = [
        f"KernelService: {n_req} Zipf requests over {len(suite)} tasks, "
        f"8 client threads (+16-deep identical burst, {burst_s:.2f}s)",
        f"  throughput      : {m['throughput_rps']:.1f} req/s",
        f"  latency         : p50 {m['p50_ms']:.1f} ms, "
        f"p99 {m['p99_ms']:.1f} ms",
        f"  coalescing      : {m['coalesced']}/{m['requests']} requests "
        f"({100 * m['coalesce_rate']:.1f}%), "
        f"{m['burst_coalesced']}/15 possible on the burst",
        f"  store           : {m['store_programs']} programs, "
        f"{m['evictions']} slab evictions "
        f"({m['evicted_programs']} programs), "
        f"{m['whole_store_resets']} whole-store resets, "
        f"hot winner cached: {bool(m['hot_winner_cached'])}",
        f"  measured mode   : {m['measured_measured']} timed, "
        f"db {m['measured_db_hits']} hits / "
        f"{m['measured_db_misses']} misses, "
        f"{m['measured_warm_starts']} warm starts on restart, "
        f"reranked: {bool(m['measured_reranked'])}",
    ]
    return m, lines


def _measured_spot_check() -> dict:
    """Measured service + on-disk DB: counters for the stats row and the
    restart warm-start path (full coverage lives in measure_bench /
    tests; this keeps the serve-side counters honest in CI).  Sizes are
    fixed — already spot-check small in both CI and full runs."""
    import shutil
    import tempfile

    from repro.core import tasks as T
    from repro.measure.harness import MeasureConfig
    from repro.serve.engine import KernelService

    task = T.kb_level1()[0]
    db_dir = tempfile.mkdtemp(prefix="serve_bench_measure_db_")
    cfg = MeasureConfig(repeats=2, warmup=1)
    try:
        svc = KernelService(config=_BEAM3, measure=True,
                            measure_db=db_dir, measure_cfg=cfg)
        r1 = svc.optimize(task)
        st1 = svc.stats()
        svc.close()
        # a fresh process image of the service against the same DB dir:
        # the repeat request must warm-start (no search, no timing)
        svc2 = KernelService(config=_BEAM3, measure=True,
                             measure_db=db_dir, measure_cfg=cfg)
        r2 = svc2.optimize(task)
        st2 = svc2.stats()
        svc2.close()
    finally:
        shutil.rmtree(db_dir, ignore_errors=True)
    return {
        "measured": st1["measured"],
        "db_hits": st1["db_hits"],
        "db_misses": st1["db_misses"],
        "warm_starts": st2["warm_starts"],
        "reranked": int(r1.reranked),
        "warm_fp_match": int(r1.program.fingerprint()
                             == r2.program.fingerprint()),
        "warm_searchless": int(st2["fresh_applies"] == 0
                               and st2["measured"] == 0),
        "correct": int(r1.correct and r2.correct),
    }


# ---------------------------------------------------------------------------
# Fleet stream (DESIGN.md §13)
# ---------------------------------------------------------------------------

def _fleet_suite():
    from repro.core import tasks as T
    return T.kb_level1() + T.kb_level2() + T.kb_level3()


def _fleet_measure_cfg():
    from repro.measure.harness import MeasureConfig
    return MeasureConfig(repeats=1, warmup=0)


def bench_fleet(fast: bool) -> tuple[dict, list[str]]:
    """F1: in-process fleet (3 replicas, one DB, background refiner)
    under a multi-tenant Zipf stream, with a mid-stream refinement
    barrier so the tail of the stream observes the hot swap."""
    import shutil
    import tempfile

    from repro.serve.fleet import Fleet, FleetConfig

    suite = _fleet_suite()
    n_req = 600 if fast else 10_000
    tenants = ("alpha", "beta", "gamma", "delta")
    rng = np.random.default_rng(2)
    picks = [(int(z) - 1) % len(suite) for z in rng.zipf(1.5, n_req)]
    tens = [tenants[i] for i in rng.integers(0, len(tenants), n_req)]
    db_dir = tempfile.mkdtemp(prefix="serve_bench_fleet_db_")
    try:
        fl = Fleet(db_dir,
                   FleetConfig(replicas=3, rerank_top_k=2,
                               max_pending=64),
                   measure_cfg=_fleet_measure_cfg(),
                   config=OptimizeConfig(mode="greedy_cost",
                                         max_steps=3),
                   serve_workers=2)

        def one(i: int):
            t = time.perf_counter()
            r = fl.optimize(suite[picks[i]], tenant=tens[i])
            return time.perf_counter() - t, bool(r.correct)

        head = n_req // 3
        t0 = time.perf_counter()
        with cf.ThreadPoolExecutor(max_workers=8) as ex:
            timed = list(ex.map(one, range(head)))
        # mid-stream refinement barrier: the background workers land
        # their measured winners HERE, so the stream's tail serves
        # hot-swapped (measured) answers for the hot keys
        fl.drain_refinement(timeout=1200)
        with cf.ThreadPoolExecutor(max_workers=8) as ex:
            timed += list(ex.map(one, range(head, n_req)))
        wall = time.perf_counter() - t0
        fl.drain_refinement(timeout=1200)
        st = fl.stats()
        fl.close()
    finally:
        shutil.rmtree(db_dir, ignore_errors=True)

    lats = [t for t, _ in timed]
    served = st["tenants"]
    m = {
        "requests": n_req,
        "replicas": st["n_replicas"],
        "throughput_rps": n_req / wall,
        "p50_ms": 1e3 * _pct(lats, 50),
        "p99_ms": 1e3 * _pct(lats, 99),
        "hot_swaps": st["hot_swaps"],
        "refined": st["refined"],
        "refine_errors": st["refine_errors"],
        "warm_starts": st["warm_starts"],
        "coalesced": st["coalesced"],
        "rejected": st["rejected"],
        "tenant_min": min(served.values()),
        "tenant_max": max(served.values()),
        "all_correct": int(all(ok for _, ok in timed)),
    }
    lines = [
        f"Fleet: {n_req} Zipf requests, {len(tenants)} tenants, "
        f"{m['replicas']} replicas + 1 refiner over one DB, "
        f"8 client threads",
        f"  throughput      : {m['throughput_rps']:.1f} req/s "
        f"aggregate",
        f"  latency         : p50 {m['p50_ms']:.1f} ms, "
        f"p99 {m['p99_ms']:.1f} ms",
        f"  refinement      : {m['refined']} winners measured in "
        f"background, {m['hot_swaps']} analytic answers hot-swapped "
        f"mid-stream, {m['refine_errors']} errors",
        f"  sharing         : {m['warm_starts']} warm starts, "
        f"{m['coalesced']} coalesced, {m['rejected']} rejected",
        f"  tenants         : served {m['tenant_min']}-"
        f"{m['tenant_max']} per tenant",
    ]
    return m, lines


def _fleet_replica_worker(db_dir, picks, barrier, out_q) -> None:
    """One separate-process serving replica: its own KernelService over
    the shared DB directory, answering its request slice.  Runs under
    the spawn start method (fork after jax import is unsafe)."""
    from repro.serve.engine import KernelService
    suite = _fleet_suite()
    svc = KernelService(measure=True, measure_db=db_dir,
                        config=OptimizeConfig(mode="greedy_cost",
                                              max_steps=3,
                                              rerank_top_k=0),
                        measure_cfg=_fleet_measure_cfg(),
                        serve_workers=2)
    barrier.wait()            # jax imported, service built: go
    t0 = time.perf_counter()
    ok = all(svc.optimize(suite[i]).correct for i in picks)
    wall = time.perf_counter() - t0
    st = svc.stats()
    svc.close()
    out_q.put({"wall": wall, "ok": int(ok),
               "fresh": st["fresh_applies"],
               "warm": st["warm_starts"],
               "corrupt": st["db_corrupt_records"]})


def _run_replica_procs(db_dir: str, slices) -> tuple[list, float]:
    import multiprocessing as mp
    ctx = mp.get_context("spawn")
    barrier = ctx.Barrier(len(slices) + 1)
    q = ctx.Queue()
    procs = [ctx.Process(target=_fleet_replica_worker,
                         args=(db_dir, s, barrier, q)) for s in slices]
    for p in procs:
        p.start()
    barrier.wait()            # excludes interpreter/jax startup
    t0 = time.perf_counter()
    outs = [q.get(timeout=2400) for _ in procs]
    wall = time.perf_counter() - t0
    for p in procs:
        p.join(60)
    return outs, wall


def bench_fleet_scale(fast: bool) -> tuple[dict, list[str]]:
    """F2 + F3: separate-process replicas over one shared DB.

    Every replica gets the SAME Zipf request multiset in its OWN
    arrival order (the production shape: the same hot kernels reach
    every replica, interleaved differently), so a fleet that did NOT
    share its winner store would redo the baseline's search work 3x —
    ``dup_ratio`` (summed fleet fresh-rule applications over the
    baseline's) reads ~3 without sharing and near 1 with it.  Identical
    per-replica order would instead march the replicas through the
    same searches in lockstep, hiding the sharing entirely.  The
    1-replica baseline runs in its own spawned process too, so both
    sides pay identical jit-cache cold starts.  F3 then replays the
    slice on a FRESH service over the now-warm DB: every repeat must
    be answered from winners/ with zero re-searches."""
    import shutil
    import tempfile

    from repro.serve.engine import KernelService

    suite = _fleet_suite()
    # the scaling probe is deliberately search-dominated: past ~120
    # requests the (cheap, serial-on-one-core) warm answers swamp the
    # shared-search win and scaling tends to 1.0x on a single-core
    # host regardless of protocol quality — stream SCALE is F1's job
    # (10k requests in-process); this phase sizes for the sharing
    # signal in both modes
    n = 120
    n_rep = 3
    rng = np.random.default_rng(3)
    picks = [(int(z) - 1) % len(suite) for z in rng.zipf(1.5, n)]

    dir_single = tempfile.mkdtemp(prefix="serve_bench_scale1_")
    dir_fleet = tempfile.mkdtemp(prefix="serve_bench_scaleN_")
    try:
        base_outs, wall_1 = _run_replica_procs(dir_single, [picks])
        slices = [[picks[j] for j in rng.permutation(n)]
                  for _ in range(n_rep)]
        fleet_outs, wall_n = _run_replica_procs(dir_fleet, slices)

        rps_single = n / wall_1
        rps_fleet = n_rep * n / wall_n
        fresh_single = max(base_outs[0]["fresh"], 1)
        fresh_fleet = sum(o["fresh"] for o in fleet_outs)

        # F3 — restart wave: a fresh service (fresh process image: new
        # store, new caches) over the warm shared DB must answer every
        # repeat from winners/ without a single re-search
        svc = KernelService(measure=True, measure_db=dir_fleet,
                            config=OptimizeConfig(mode="greedy_cost",
                                                  max_steps=3,
                                                  rerank_top_k=0),
                            measure_cfg=_fleet_measure_cfg(),
                            serve_workers=2)
        t0 = time.perf_counter()
        ok_warm = all(svc.optimize(suite[i]).correct for i in picks)
        wall_warm = time.perf_counter() - t0
        st_warm = svc.stats()
        svc.close()
    finally:
        shutil.rmtree(dir_single, ignore_errors=True)
        shutil.rmtree(dir_fleet, ignore_errors=True)

    m = {
        "requests": n,
        "replicas": n_rep,
        "rps_single": rps_single,
        "rps_fleet": rps_fleet,
        "scaling": rps_fleet / rps_single,
        "dup_ratio": fresh_fleet / fresh_single,
        "peer_warm_starts": sum(o["warm"] for o in fleet_outs),
        "corrupt_records": sum(o["corrupt"] for o in fleet_outs)
        + base_outs[0]["corrupt"],
        "all_correct": int(all(o["ok"] for o in fleet_outs)
                           and base_outs[0]["ok"]),
        "warm_rate": st_warm["warm_starts"] / n,
        "warm_fresh_applies": st_warm["fresh_applies"],
        "warm_rps": n / wall_warm,
        "warm_correct": int(ok_warm),
    }
    lines = [
        f"Fleet scale: {n_rep} replica processes x {n} Zipf requests "
        f"(same requests, shuffled arrival) over one shared DB "
        f"vs 1 replica process",
        f"  throughput      : {rps_fleet:.1f} req/s aggregate vs "
        f"{rps_single:.1f} solo -> {m['scaling']:.2f}x scaling",
        f"  search sharing  : dup_ratio {m['dup_ratio']:.2f} "
        f"(no sharing would read ~{n_rep}.0), "
        f"{m['peer_warm_starts']} cross-replica warm starts, "
        f"{m['corrupt_records']} corrupt records",
        f"  restart wave    : warm-start rate "
        f"{100 * m['warm_rate']:.1f}%, {m['warm_fresh_applies']} "
        f"fresh rule applications (must be 0), "
        f"{m['warm_rps']:.1f} req/s",
    ]
    return m, lines


# ---------------------------------------------------------------------------
# Engine stream
# ---------------------------------------------------------------------------

def _tiny_cfg():
    from repro.configs.registry import get_config, reduced
    cfg = reduced(get_config("qwen2_5_3b"))
    return dataclasses.replace(cfg, n_layers=2, d_model=64,
                               vocab_size=128, true_vocab_size=128)


def bench_engine(fast: bool) -> tuple[dict, list[str]]:
    import jax
    import jax.numpy as jnp
    from repro.models import api
    from repro.serve.engine import Engine, Request

    cfg = _tiny_cfg()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    n_req = 16 if fast else 48
    rng = np.random.default_rng(1)

    completions: list[float] = []

    class TimedEngine(Engine):
        def _retire(self, slot, s, pos):
            r = slot[s]
            was_done = r.done
            super()._retire(slot, s, pos)
            if r.done and not was_done:
                completions.append(time.perf_counter())

    eng = TimedEngine(cfg, params, max_len=64, batch_slots=4)
    prompts = [jnp.asarray(rng.integers(1, 100, rng.integers(1, 12)),
                           jnp.int32) for _ in range(n_req)]
    reqs = [Request(p, int(rng.integers(4, 13))) for p in prompts]
    want = [r.max_new_tokens for r in reqs]

    t0 = time.perf_counter()
    eng.run(reqs)
    wall = time.perf_counter() - t0
    lats = [c - t0 for c in completions]

    n_tok = sum(len(r.out) for r in reqs)
    st = eng.stats
    occ = st["occupancy_sum"] / max(st["decode_steps"], 1)
    # parity gate: mixed-length batched == solo, token-identical
    par_eng = Engine(cfg, params, max_len=64, batch_slots=4)
    outs = par_eng.generate(prompts[:6], max_new_tokens=5)
    parity = all(o == par_eng.generate([p], max_new_tokens=5)[0]
                 for p, o in zip(prompts[:6], outs))
    m = {
        "requests": n_req,
        "tokens": n_tok,
        "tok_per_s": n_tok / wall,
        "p50_ms": 1e3 * _pct(lats, 50),
        "p99_ms": 1e3 * _pct(lats, 99),
        "occupancy": occ,
        "truncations": st["truncations"],
        "budgets_met": int([len(r.out) for r in reqs] == want),
        "parity": int(parity),
    }
    lines = [
        f"Engine: {n_req} mixed-length requests (len 1-11, budgets "
        f"4-12) through 4 slots, token-level continuous batching",
        f"  throughput      : {m['tok_per_s']:.1f} tok/s "
        f"({n_tok} tokens in {wall:.2f}s)",
        f"  request latency : p50 {m['p50_ms']:.1f} ms, "
        f"p99 {m['p99_ms']:.1f} ms",
        f"  slot occupancy  : {100 * occ:.1f}% mean, "
        f"{st['truncations']} truncations, budgets met: "
        f"{bool(m['budgets_met'])}",
        f"  parity          : batched == solo token-identical: "
        f"{parity}",
    ]
    return m, lines


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke sizes")
    ap.add_argument("--out", default=os.path.join(RESULTS,
                                                  "serve_bench.txt"))
    ap.add_argument("--csv", default=os.path.join(RESULTS,
                                                  "serve_bench.csv"))
    args = ap.parse_args()

    svc_m, svc_lines = bench_service(args.fast)
    flt_m, flt_lines = bench_fleet(args.fast)
    scl_m, scl_lines = bench_fleet_scale(args.fast)
    eng_m, eng_lines = bench_engine(args.fast)

    text = "\n".join(svc_lines + flt_lines + scl_lines
                     + eng_lines) + "\n"
    print(text)
    os.makedirs(RESULTS, exist_ok=True)
    with open(args.out, "w") as f:
        f.write(text)
    with open(args.csv, "w") as f:
        f.write("name,us_per_call,derived\n")
        f.write(
            f"serve/service,{1e6 / svc_m['throughput_rps']:.1f},"
            f"coalesce_rate={svc_m['coalesce_rate']:.3f};"
            f"evictions={svc_m['evictions']};"
            f"resets={svc_m['whole_store_resets']};"
            f"hot_cached={svc_m['hot_winner_cached']};"
            f"p99_ms={svc_m['p99_ms']:.1f}\n")
        f.write(
            f"serve/measured,{svc_m['measured_measured']:.1f},"
            f"db_hits={svc_m['measured_db_hits']};"
            f"db_misses={svc_m['measured_db_misses']};"
            f"warm_starts={svc_m['measured_warm_starts']};"
            f"warm_searchless={svc_m['measured_warm_searchless']}\n")
        f.write(
            f"serve/fleet,{1e6 / flt_m['throughput_rps']:.1f},"
            f"hot_swaps={flt_m['hot_swaps']};"
            f"refined={flt_m['refined']};"
            f"warm_starts={flt_m['warm_starts']};"
            f"rejected={flt_m['rejected']};"
            f"p99_ms={flt_m['p99_ms']:.1f}\n")
        f.write(
            f"serve/fleet_scale,{1e6 / scl_m['rps_fleet']:.1f},"
            f"scaling={scl_m['scaling']:.2f};"
            f"dup_ratio={scl_m['dup_ratio']:.2f};"
            f"peer_warm_starts={scl_m['peer_warm_starts']};"
            f"corrupt={scl_m['corrupt_records']}\n")
        f.write(
            f"serve/fleet_warm,{1e6 / scl_m['warm_rps']:.1f},"
            f"warm_rate={scl_m['warm_rate']:.3f};"
            f"fresh_applies={scl_m['warm_fresh_applies']}\n")
        f.write(
            f"serve/engine,{1e6 / eng_m['tok_per_s']:.1f},"
            f"occupancy={eng_m['occupancy']:.2f};"
            f"parity={eng_m['parity']};"
            f"truncations={eng_m['truncations']};"
            f"p99_ms={eng_m['p99_ms']:.1f}\n")

    failures = []
    if svc_m["burst_coalesced"] <= 0:
        failures.append("coalescing hit-rate is 0 on the repeated-"
                        "request burst")
    if not svc_m["all_correct"]:
        failures.append("a service result failed the oracle")
    if svc_m["evictions"] >= 1 and not svc_m["hot_winner_cached"]:
        failures.append("slab eviction dropped the hot winner")
    if not eng_m["parity"]:
        failures.append("batched generation diverged from solo")
    if not eng_m["budgets_met"]:
        failures.append("a request missed its token budget")
    if not svc_m["measured_correct"]:
        failures.append("a measured-mode result failed the oracle")
    if not (svc_m["measured_warm_starts"] >= 1
            and svc_m["measured_warm_searchless"]
            and svc_m["measured_warm_fp_match"]):
        failures.append("measured-mode restart did not warm-start from "
                        "the on-disk DB")
    if flt_m["hot_swaps"] < 1:
        failures.append("background refinement hot-swapped no analytic "
                        "answer mid-stream")
    if not flt_m["all_correct"]:
        failures.append("a fleet result failed the oracle")
    if flt_m["rejected"] > 0:
        failures.append("admission control rejected requests under an "
                        "in-budget stream")
    if flt_m["refine_errors"] > 0:
        failures.append("a background refinement errored")
    if not scl_m["all_correct"] or not scl_m["warm_correct"]:
        failures.append("a replica-process result failed the oracle")
    # on a single-core host the replicas' warm paths time-slice one
    # CPU, so the whole aggregate gain comes from search deduplication
    # (ceiling ~n_rep/dup_ratio); the floor asserts a real gain while
    # staying honest about that ceiling — multi-core runners clear it
    # by a wide margin
    if scl_m["scaling"] < 1.1:
        failures.append(
            f"aggregate throughput did not scale past one replica "
            f"({scl_m['scaling']:.2f}x < 1.1x)")
    if scl_m["dup_ratio"] > 2.3:
        failures.append(
            f"replicas duplicated search work the shared DB should "
            f"have deduplicated (dup_ratio {scl_m['dup_ratio']:.2f} "
            f"> 2.3; no sharing reads ~3.0)")
    if scl_m["peer_warm_starts"] < 1:
        failures.append("no replica warm-started from a peer's winner")
    if scl_m["corrupt_records"] > 0:
        failures.append("concurrent replicas produced corrupt records")
    if scl_m["warm_rate"] < 0.999 or scl_m["warm_fresh_applies"] != 0:
        failures.append(
            f"restarted replica re-searched repeat requests "
            f"(warm_rate {scl_m['warm_rate']:.3f}, "
            f"{scl_m['warm_fresh_applies']} fresh applies)")
    for msg in failures:
        print(f"FAIL: {msg}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
