"""Micro Coding — stepwise implementation of semantic actions.

The paper uses a general-purpose LLM to implement ONE atomic optimization
at a time on the previous kernel.  Offline we realise the same contract
with a deterministic structured rewrite engine over the kernel IR
(DESIGN.md §2): ``apply(program, action) -> ApplyResult`` where failures
reproduce the LLM failure modes the paper's reward tiers grade:

  * compile_error  — illegal tile (does not divide / VMEM OOM / misaligned),
                     illegal fusion (no kernel template for the merged
                     pattern), bogus region;
  * wrong_result   — the engine "miscompiles" nothing by construction, but
                     the validator still executes the rewritten program
                     against the original's outputs (belt & braces — this
                     is the tier-2 check an LLM-backed MicroCoder needs);
  * ok             — new program + validated.

An LLM-backed implementation can be slotted in behind ``MicroCoder``.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import actions as A
from repro.core.kernel_ir import (ELEMENTWISE, KernelProgram, evaluate,
                                  make_inputs, _sched_kind)

VMEM_BYTES = 16 * 2 ** 20        # per-core VMEM budget (v5e class)

# fusion templates: (group op-pattern) the kernel library can actually emit
FUSABLE_EPILOGUES = {"bias", "relu", "gelu", "silu", "add", "row_max"}


@dataclasses.dataclass(frozen=True)
class ApplyResult:
    status: str                  # ok | compile_error | wrong_result
    program: KernelProgram | None = None
    detail: str = ""


class MicroCoder(Protocol):
    def apply(self, prog: KernelProgram, act: A.Action) -> ApplyResult: ...


# ---------------------------------------------------------------------------

class StructuredMicroCoder:
    """Deterministic rewrite engine with compile/shape/VMEM legality."""

    def __init__(self, validate: bool = False, seed: int = 0):
        self.validate = validate
        self.seed = seed

    # -- entry point -------------------------------------------------------
    def apply(self, prog: KernelProgram, act: A.Action) -> ApplyResult:
        if act.kind == "stop":
            return ApplyResult("ok", prog, "terminal")
        try:
            if act.kind == "tiling":
                new = self._tile(prog, act)
            elif act.kind == "reorder":
                new = self._reorder(prog, act)
            elif act.kind == "pipeline":
                new = self._pipeline(prog, act)
            elif act.kind == "fusion":
                new = self._fuse(prog, act)
            else:
                return ApplyResult("compile_error", None,
                                   f"unknown action kind {act.kind}")
        except CompileError as e:
            return ApplyResult("compile_error", None, str(e))
        new = new.replace(history=prog.history + (act.describe(),))
        if self.validate and not self._check(prog, new):
            return ApplyResult("wrong_result", None, "validation mismatch")
        return ApplyResult("ok", new)

    # -- transformations ----------------------------------------------------
    def _group_for_root(self, prog, root):
        for g in prog.fusion_groups:
            if prog.group_root(g) == root:
                return g
        raise CompileError(f"no kernel rooted at {root!r}")

    def _tile(self, prog: KernelProgram, act: A.Action) -> KernelProgram:
        g = self._group_for_root(prog, act.region)
        tiles = dict(act.param)
        self._check_tiles(prog, g, tiles)
        sched = prog.schedule_for(g).replace(blocks=tiles)
        return prog.with_schedule(act.region, sched)

    def _reorder(self, prog: KernelProgram, act: A.Action) -> KernelProgram:
        g = self._group_for_root(prog, act.region)
        kind = A._sched_kind_of_group(prog, g)
        if kind not in ("matmul", "grouped_matmul"):
            raise CompileError(f"loop reorder not applicable to {kind}")
        order = tuple(act.param)
        if sorted(order) != ["k", "m", "n"]:
            raise CompileError(f"invalid loop order {order}")
        sched = prog.schedule_for(g).replace(loop_order=order)
        return prog.with_schedule(act.region, sched)

    def _pipeline(self, prog: KernelProgram, act: A.Action) -> KernelProgram:
        g = self._group_for_root(prog, act.region)
        depth = int(act.param[0])
        if not 1 <= depth <= 8:
            raise CompileError(f"pipeline depth {depth} out of range")
        # deeper pipelines multiply live tile buffers: re-check VMEM
        sched = prog.schedule_for(g).replace(pipeline_depth=depth)
        tmp = prog.with_schedule(act.region, sched)
        self._check_tiles(tmp, g, sched.blocks_dict or None)
        return tmp

    def _fuse(self, prog: KernelProgram, act: A.Action) -> KernelProgram:
        a_root, b_root = act.region, act.param[0]
        ga = self._group_for_root(prog, a_root)
        gb = self._group_for_root(prog, b_root)
        if ga == gb:
            raise CompileError("cannot fuse a kernel with itself")
        if (a_root, b_root) not in A.fusion_candidates(prog):
            raise CompileError(
                f"{a_root} and {b_root} are not dataflow-adjacent")
        merged = ga + gb
        nm = prog.node_map
        ops = [nm[n].op for n in merged]
        if sorted(ops) == ["av", "qk_scores", "softmax"]:
            return self._rewrite_flash(prog, ga, gb, merged)
        self._check_fusion_pattern(prog, merged)
        groups = tuple(g for g in prog.fusion_groups if g not in (ga, gb))
        # preserve topological position of the producer group
        idx = prog.fusion_groups.index(ga)
        groups = groups[:idx] + (merged,) + groups[idx:]
        sm = prog.schedule_map
        sched = sm.pop(a_root, None)
        sm.pop(b_root, None)
        epi = self._epilogue_of(prog, merged)
        if sched is not None and epi:
            sched = sched.replace(epilogue=epi)
        new = prog.replace(fusion_groups=groups,
                           schedules=tuple(sorted(
                               (sm | ({a_root: sched} if sched else {}))
                               .items())))
        return new

    def _rewrite_flash(self, prog: KernelProgram, ga, gb, merged
                       ) -> KernelProgram:
        """qk_scores + softmax + av  ==>  one fused attention node
        (the flash kernel).  The fused node keeps the av node's name so
        downstream consumers stay wired."""
        nm = prog.node_map
        qk = next(nm[n] for n in merged if nm[n].op == "qk_scores")
        av = next(nm[n] for n in merged if nm[n].op == "av")
        fused = dataclasses.replace(
            av, op="attention",
            inputs=(qk.inputs[0], qk.inputs[1], av.inputs[1]),
            attrs=qk.attrs)
        drop = set(merged) - {av.name}
        nodes = tuple(fused if n.name == av.name else n
                      for n in prog.nodes if n.name not in drop)
        groups = tuple(g for g in prog.fusion_groups if g not in (ga, gb))
        idx = prog.fusion_groups.index(ga)
        groups = groups[:idx] + ((av.name,),) + groups[idx:]
        sm = {k: v for k, v in prog.schedule_map.items()
              if k not in merged}
        from repro.kernels.schedule import default_schedule
        sm[av.name] = default_schedule("flash_attention")
        return prog.replace(nodes=nodes, fusion_groups=groups,
                            schedules=tuple(sorted(sm.items())))

    # -- legality checks -----------------------------------------------------
    def _check_tiles(self, prog, group, tiles):
        kind = A._sched_kind_of_group(prog, group)
        sched = prog.schedule_for(group)
        tiles = tiles or sched.blocks_dict
        if not tiles:
            return
        shapes = prog.shapes()
        nm = prog.node_map
        main = next((nm[n] for n in group
                     if _sched_kind(nm[n].op) == kind), nm[group[0]])
        dims = self._tileable_dims(main, shapes, prog.input_specs)
        vmem = 0
        for tname, t in tiles.items():
            if dims and tname not in dims:
                raise CompileError(
                    f"tile parameter {tname!r} not applicable to "
                    f"{kind} kernel {main.name} (has {sorted(dims)})")
            if tname in dims:
                if dims[tname] % t != 0:
                    raise CompileError(
                        f"tile {tname}={t} does not divide dim "
                        f"{dims[tname]} of {main.name}")
                if kind in ("matmul", "grouped_matmul",
                            "flash_attention") and t % 8 != 0:
                    raise CompileError(
                        f"tile {tname}={t} violates TPU lane alignment")
        # VMEM footprint: product-ish estimate per kernel kind
        vmem = self._vmem_bytes(kind, tiles, dims)
        depth = max(1, sched.pipeline_depth)
        if vmem * (1 + (depth - 1)) > VMEM_BYTES:
            raise CompileError(
                f"VMEM overflow: {vmem * depth / 2**20:.1f}MiB "
                f"(depth {depth}) > 16MiB")

    @staticmethod
    def _tileable_dims(node, shapes, inputs):
        sh = {k: v.shape for k, v in (shapes | dict(inputs)).items()}
        if node.op == "matmul":
            a, b = sh[node.inputs[0]], sh[node.inputs[1]]
            return {"bm": int(np.prod(a[:-1])), "bk": a[-1], "bn": b[-1]}
        if node.op == "grouped_matmul":
            a, b = sh[node.inputs[0]], sh[node.inputs[1]]
            return {"bc": a[1], "bd": a[2], "bf": b[2]}
        if node.op == "attention":
            q = sh[node.inputs[0]]
            k = sh[node.inputs[1]]
            return {"bq": q[1], "bk": k[1]}
        if node.op == "qk_scores":
            q, k = sh[node.inputs[0]], sh[node.inputs[1]]
            return {"bm": q[1], "bk": q[-1], "bn": k[1]}
        if node.op == "av":
            p, v = sh[node.inputs[0]], sh[node.inputs[1]]
            return {"bm": p[2], "bk": p[3], "bn": v[-1]}
        if node.op in ("rwkv_chunk", "ssm_chunk"):
            return {"chunk": sh[node.inputs[0]][1]}
        if node.op == "rmsnorm":
            x = sh[node.inputs[0]]
            return {"rows": int(np.prod(x[:-1]))}
        return {}

    @staticmethod
    def _vmem_bytes(kind, tiles, dims):
        t = lambda n, d: tiles.get(n, min(d.get(n, 128), 128))
        if kind in ("matmul", "grouped_matmul"):
            bm = t("bm", dims) if kind == "matmul" else t("bc", dims)
            bn = t("bn", dims) if kind == "matmul" else t("bf", dims)
            bk = t("bk", dims) if kind == "matmul" else t("bd", dims)
            return 4 * (bm * bk + bk * bn + 2 * bm * bn)
        if kind == "flash_attention":
            bq, bk = t("bq", dims), t("bk", dims)
            hd = 128
            return 4 * (bq * hd * 2 + 2 * bk * hd + bq * bk)
        if kind in ("rwkv6_scan", "ssm_scan"):
            c = t("chunk", dims)
            return 4 * (c * c * 64 + 4 * c * 64 + 128 * 128)
        if kind == "rmsnorm":
            return 4 * 2 * t("rows", dims) * 4096
        return 1 << 16

    def _check_fusion_pattern(self, prog, merged):
        nm = prog.node_map
        ops = [nm[n].op for n in merged]
        anchors = [o for o in ops if o not in ELEMENTWISE]
        # pattern 1: [rmsnorm prologue +] matmul + elementwise epilogue(s)
        if anchors in ([], ["matmul"], ["rmsnorm", "matmul"],
                       ["matmul", "row_max"], ["grouped_matmul"],
                       ["rmsnorm"], ["softmax"],
                       ["qk_scores", "softmax"],   # softmax-epilogue GEMM
                       ["matmul", "softmax"]):
            return
        # pattern 2: attention triple matmul+softmax+matmul -> flash kernel
        if ops.count("matmul") == 2 and "softmax" in ops and \
                all(o in ("matmul", "softmax", "bias", "mul") for o in ops):
            return
        # scans fuse with their elementwise pre/post processing
        if anchors and anchors[0] in ("rwkv_chunk", "ssm_chunk") and \
                all(o in ELEMENTWISE or o == anchors[0] for o in ops):
            return
        raise CompileError(
            f"no fused-kernel template for op pattern {ops}")

    @staticmethod
    def _epilogue_of(prog, merged):
        nm = prog.node_map
        ops = [nm[n].op for n in merged]
        if "matmul" not in ops and "grouped_matmul" not in ops:
            return ""
        epis = [o for o in ops if o in FUSABLE_EPILOGUES or o == "row_max"]
        return "_".join(epis[:2]) if epis else ""

    # -- tier-2 validation ---------------------------------------------------
    def _check(self, old: KernelProgram, new: KernelProgram) -> bool:
        key = jax.random.PRNGKey(self.seed)
        inputs = make_inputs(old, key)
        try:
            outs_old = evaluate(old, inputs)
            outs_new = evaluate(new, inputs)
        except Exception:
            return False
        for a, b in zip(outs_old, outs_new):
            if a.shape != b.shape or not bool(
                    jnp.allclose(a, b, rtol=1e-3, atol=1e-3)):
                return False
        return True


class CompileError(Exception):
    pass
