"""Declarative rewrite-rule registry — the extensible optimization space.

Every optimization the Macro policy can propose is ONE self-contained
``RewriteRule`` bundling (DESIGN.md §12):

  (a) candidate enumeration over a ``KernelProgram`` — *target-aware*:
      curated tile presets are derived from the active
      ``hardware.HardwareTarget``'s lane/sublane geometry and VMEM
      capacity, not a global v5e-flavored table;
  (b) a legality predicate — *target-independent* (the portability
      envelope of DESIGN.md §9: one TranspositionStore's transition
      memo serves every target), raising ``CompileError``;
  (c) the IR rewrite itself;
  (d) policy-vocabulary serialization (``words``) so the Macro LM can
      score the action without per-kind special cases;
  (e) cost-model and lowering hooks (``adjust_matmul``,
      ``compute_dtype``, ``lower_cast``) so pricing and measured
      execution learn about the rule without editing their dispatch.

``candidate_actions``, ``StructuredMicroCoder``, ``KernelEnv``,
``policy.action_words``, the search strategies and the measure harness
all consume this registry; none of them switches on ``act.kind``.
Rules registered with ``default=True`` form the classic curated space
(byte-identical to the pre-registry action set — regression-tested in
``tests/test_rules.py``); ``default=False`` rules (``dtype``,
``split_k``) join only when a caller asks for the *extended* space.

Adding a rule is ~30 lines and zero edits elsewhere — see README
"Adding an optimization rule".
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.analysis.diagnostics import Diagnostic, error
from repro.core import hardware
from repro.core.actions import Action, STOP, fusion_candidates
from repro.core.kernel_ir import (ELEMENTWISE, KernelProgram,
                                  sched_kind, sched_kind_of_group)

# ---------------------------------------------------------------------------
# shared legality helpers (target-INDEPENDENT — DESIGN.md §9)
# ---------------------------------------------------------------------------

# portability-envelope VMEM budget: the minimum across registered
# targets, so a legal rewrite is legal on every chip and transition
# memos never need a target component in their keys
VMEM_BYTES = 16 * 2 ** 20

# fusion templates: (group op-pattern) the kernel library can emit
FUSABLE_EPILOGUES = {"bias", "relu", "gelu", "silu", "add", "row_max"}

LOOP_ORDERS = [("m", "n", "k"), ("n", "m", "k"),
               ("m", "k", "n"), ("k", "m", "n")]
PIPELINE_DEPTHS = (1, 2, 3, 4)

BAD_TILES = [{"bm": 96, "bn": 80, "bk": 56}, {"bm": 8192, "bn": 8192,
             "bk": 8192}, {"bq": 100, "bk": 60}, {"chunk": 7},
             {"bm": 33, "bn": 100, "bk": 17}]

# number buckets shared by the policy DSL and the rules' ``words``
# serialization (policy.py re-exports these)
NUM_BUCKETS = [1, 2, 4, 7, 8, 16, 32, 56, 64, 100, 128, 256, 384, 512,
               640, 768, 896, 1024, 2048, 4096, 8192]


def bucket(v: int) -> str:
    b = min(NUM_BUCKETS, key=lambda x: abs(np.log2(max(v, 1) / x)))
    return f"n{b}"


class CompileError(Exception):
    """A rewrite/legality failure.  When the failure maps to a stable
    analysis code the raiser attaches the ``Diagnostic`` (code + node
    span + fix-hint, see ``repro.analysis.diagnostics``) so callers —
    the serve path, the measure harness, the lint CLI — can surface
    structured context instead of a bare string."""

    def __init__(self, message: str, diagnostic: Diagnostic = None):
        super().__init__(message)
        self.diagnostic = diagnostic


def _compile_error(code: str, message: str, *, span: tuple = (),
                   hint: str = "") -> CompileError:
    return CompileError(message, error(code, message, span=span,
                                       hint=hint))


def group_for_root(prog: KernelProgram, root: str) -> tuple[str, ...]:
    for g in prog.fusion_groups:
        if prog.group_root(g) == root:
            return g
    raise CompileError(f"no kernel rooted at {root!r}")


def tileable_dims(node, shapes, inputs) -> dict[str, int]:
    sh = {k: v.shape for k, v in (shapes | dict(inputs)).items()}
    if node.op == "matmul":
        a, b = sh[node.inputs[0]], sh[node.inputs[1]]
        return {"bm": int(np.prod(a[:-1])), "bk": a[-1], "bn": b[-1]}
    if node.op == "grouped_matmul":
        a, b = sh[node.inputs[0]], sh[node.inputs[1]]
        return {"bc": a[1], "bd": a[2], "bf": b[2]}
    if node.op == "attention":
        q = sh[node.inputs[0]]
        k = sh[node.inputs[1]]
        return {"bq": q[1], "bk": k[1]}
    if node.op == "qk_scores":
        q, k = sh[node.inputs[0]], sh[node.inputs[1]]
        return {"bm": q[1], "bk": q[-1], "bn": k[1]}
    if node.op == "av":
        p, v = sh[node.inputs[0]], sh[node.inputs[1]]
        return {"bm": p[2], "bk": p[3], "bn": v[-1]}
    if node.op in ("rwkv_chunk", "ssm_chunk"):
        return {"chunk": sh[node.inputs[0]][1]}
    if node.op == "rmsnorm":
        x = sh[node.inputs[0]]
        return {"rows": int(np.prod(x[:-1]))}
    return {}


def vmem_tile_bytes(kind: str, tiles: dict, dims: dict) -> float:
    """Single-buffer VMEM footprint estimate per kernel kind."""
    t = lambda n, d: tiles.get(n, min(d.get(n, 128), 128))
    if kind in ("matmul", "grouped_matmul"):
        bm = t("bm", dims) if kind == "matmul" else t("bc", dims)
        bn = t("bn", dims) if kind == "matmul" else t("bf", dims)
        bk = t("bk", dims) if kind == "matmul" else t("bd", dims)
        return 4 * (bm * bk + bk * bn + 2 * bm * bn)
    if kind == "flash_attention":
        bq, bk = t("bq", dims), t("bk", dims)
        hd = 128
        return 4 * (bq * hd * 2 + 2 * bk * hd + bq * bk)
    if kind in ("rwkv6_scan", "ssm_scan"):
        c = t("chunk", dims)
        return 4 * (c * c * 64 + 4 * c * 64 + 128 * 128)
    if kind == "rmsnorm":
        return 4 * 2 * t("rows", dims) * 4096
    return 1 << 16


def check_tiles(prog: KernelProgram, group, tiles) -> None:
    """Legality of a tile dict for a group: name applicability,
    divisibility, lane alignment, pipelined VMEM budget (the
    portability envelope, NOT the per-target capacity)."""
    kind = sched_kind_of_group(prog, group)
    sched = prog.schedule_for(group)
    tiles = tiles or sched.blocks_dict
    if not tiles:
        return
    shapes = prog.shapes()
    nm = prog.node_map
    main = next((nm[n] for n in group
                 if sched_kind(nm[n].op) == kind), nm[group[0]])
    dims = tileable_dims(main, shapes, prog.input_specs)
    span = (main.name,)
    for tname, t in tiles.items():
        if dims and tname not in dims:
            raise _compile_error(
                "MT020",
                f"tile parameter {tname!r} not applicable to "
                f"{kind} kernel {main.name} (has {sorted(dims)})",
                span=span, hint=f"use one of {sorted(dims)}")
        if tname in dims:
            if dims[tname] % t != 0:
                raise _compile_error(
                    "MT021",
                    f"tile {tname}={t} does not divide dim "
                    f"{dims[tname]} of {main.name}",
                    span=span, hint=f"pick a divisor of {dims[tname]}")
            if kind in ("matmul", "grouped_matmul",
                        "flash_attention") and t % 8 != 0:
                raise _compile_error(
                    "MT022",
                    f"tile {tname}={t} violates TPU lane alignment",
                    span=span, hint="tiles must be multiples of 8")
    vmem = vmem_tile_bytes(kind, tiles, dims)
    depth = max(1, sched.pipeline_depth)
    if vmem * (1 + (depth - 1)) > VMEM_BYTES:
        raise _compile_error(
            "MT023",
            f"VMEM overflow: {vmem * depth / 2**20:.1f}MiB "
            f"(depth {depth}) > 16MiB",
            span=span, hint="shrink tiles or lower pipeline_depth")


def check_fusion_pattern(prog: KernelProgram, merged) -> None:
    nm = prog.node_map
    ops = [nm[n].op for n in merged]
    anchors = [o for o in ops if o not in ELEMENTWISE]
    # pattern 1: [rmsnorm prologue +] matmul + elementwise epilogue(s)
    if anchors in ([], ["matmul"], ["rmsnorm", "matmul"],
                   ["matmul", "row_max"], ["grouped_matmul"],
                   ["rmsnorm"], ["softmax"],
                   ["qk_scores", "softmax"],   # softmax-epilogue GEMM
                   ["matmul", "softmax"]):
        return
    # pattern 2: attention triple matmul+softmax+matmul -> flash kernel
    if ops.count("matmul") == 2 and "softmax" in ops and \
            all(o in ("matmul", "softmax", "bias", "mul") for o in ops):
        return
    # scans fuse with their elementwise pre/post processing
    if anchors and anchors[0] in ("rwkv_chunk", "ssm_chunk") and \
            all(o in ELEMENTWISE or o == anchors[0] for o in ops):
        return
    raise _compile_error(
        "MT011", f"no fused-kernel template for op pattern {ops}",
        span=tuple(merged),
        hint="legal patterns are listed in check_fusion_pattern")


def epilogue_of(prog: KernelProgram, merged) -> str:
    nm = prog.node_map
    ops = [nm[n].op for n in merged]
    if "matmul" not in ops and "grouped_matmul" not in ops:
        return ""
    epis = [o for o in ops if o in FUSABLE_EPILOGUES or o == "row_max"]
    return "_".join(epis[:2]) if epis else ""


# ---------------------------------------------------------------------------
# target-aware curated tile presets
# ---------------------------------------------------------------------------

# geometric preset ladders in units of the anchor tile U.  U is
# max(lane, 128): absolute tile sizes drive the modeled re-read
# traffic, so a finer-laned chip (gpu_a100, lane 64) must not shrink
# the ladder — it keeps the full-size rungs (every multiple of 128 is
# lane-64-aligned) and ADDS finer natively-aligned entries below.  On
# tpu_v5e (lane 128, sublane 8) this reproduces the historical
# TILE_PRESETS bit-exactly.
_MATMUL_LADDER = [(1, 1, 1), (2, 1, 1), (1, 2, 1), (2, 2, 1), (4, 1, 1),
                  (1, 1, 2), (4, 2, 1), (2, 2, 2)]
_FLASH_LADDER = [(1, 1), (2, 1), (1, 2), (2, 2), (4, 1), (0.5, 0.5),
                 (4, 2), (8, 1)]
_GROUPED_LADDER = [(1, 1, 1), (2, 1, 1), (1, 2, 1), (2, 2, 1), (4, 1, 1)]

# memo keyed by the geometry that actually derives the presets (NOT
# the target name): a re-registered or ad-hoc target with new
# lane/sublane/VMEM computes fresh, one with the same geometry shares
# — every entry is a pure function of its key, no invalidation needed
_PRESET_CACHE: dict[tuple[str, int, int, float], list[dict]] = {}


def tile_presets(kind: str, target=None) -> list[dict]:
    """Curated tile candidates for one kernel kind on one target,
    derived from lane/sublane geometry and capacity-filtered against
    the target's VMEM (double-buffered footprint must fit)."""
    tgt = hardware.resolve(target)
    key = (kind, tgt.lane, tgt.sublane, tgt.vmem_bytes)
    hit = _PRESET_CACHE.get(key)
    if hit is not None:
        return hit
    L, s = tgt.lane, tgt.sublane
    U = max(L, 128)
    if kind == "matmul":
        raw = [{"bm": int(m * U), "bn": int(n * U), "bk": int(k * U)}
               for m, n, k in _MATMUL_LADDER]
        raw.append({"bm": U // 2, "bn": U // 2, "bk": U // 2})
        if L < U:
            # finer lane-granular tile only this chip can run
            # (reduced-efficiency option for ragged shapes, the same
            # role the U//2 rung plays on the anchor geometry)
            raw.append({"bm": L // 2, "bn": L // 2, "bk": L // 2})
    elif kind == "flash_attention":
        raw = [{"bq": int(q * U), "bk": int(k * U)}
               for q, k in _FLASH_LADDER]
        if L < U:
            raw.append({"bq": L // 2, "bk": L // 2})
    elif kind == "rmsnorm":
        raw = [{"rows": m * U} for m in (1, 2, 4, 8)]
    elif kind in ("rwkv6_scan", "ssm_scan"):
        # chunk granularity follows the sublane: a chunk narrower than
        # 2 sublanes wastes row granularity on this chip
        raw = [{"chunk": m * s} for m in (2, 4, 8, 16)]
    elif kind == "grouped_matmul":
        raw = [{"bc": int(c * U), "bf": int(f * U), "bd": int(d * U)}
               for c, f, d in _GROUPED_LADDER]
        if L < U:
            raw.append({"bc": L, "bf": L, "bd": L})
    else:
        raw = []
    if kind in ("matmul", "grouped_matmul", "flash_attention"):
        # VMEM capacity filter: a preset whose double-buffered tiles
        # cannot fit the target's on-chip memory is never proposed
        raw = [p for p in raw
               if 2 * vmem_tile_bytes(kind, p, {}) <= tgt.vmem_bytes]
    _PRESET_CACHE[key] = raw
    return raw


# ---------------------------------------------------------------------------
# the rule protocol
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PriceAdjust:
    """Cost-model deltas a rule contributes to one matmul node."""
    hbm_scale: float = 1.0
    hbm_extra: float = 0.0
    vpu_extra: float = 0.0


class RewriteRule:
    """One optimization: enumeration + legality + rewrite + vocab +
    pricing/lowering hooks.  Subclass, set ``kind``, register."""

    kind: str = ""
    default: bool = True        # member of the classic curated space
    terminal: bool = False      # a stop-like action (no rewrite)

    # -- (a) enumeration ---------------------------------------------------
    def group_actions(self, prog, group, root, kind, target
                      ) -> list[Action]:
        """Curated candidates targeting one fusion group."""
        return []

    def global_actions(self, prog, target) -> list[Action]:
        """Curated candidates over the whole program (e.g. fusions)."""
        return []

    def bad_group_actions(self, prog, group, root, kind, target
                          ) -> list[Action]:
        """'w/o AS' extras: invalid-prone proposals, per group."""
        return []

    def bad_global_actions(self, prog, target) -> list[Action]:
        return []

    # -- (b)+(c) legality and rewrite --------------------------------------
    def rewrite(self, prog: KernelProgram, act: Action) -> KernelProgram:
        """Apply ``act``; raise ``CompileError`` when illegal.  MUST be
        target-independent (DESIGN.md §9)."""
        raise CompileError(f"rule {self.kind!r} has no rewrite")

    # -- (d) policy vocabulary ---------------------------------------------
    def param_words(self, act: Action) -> list[str]:
        return []

    def words(self, act: Action, slots: dict[str, str]) -> list[str]:
        return ([act.kind, slots.get(act.region, "r0")]
                + self.param_words(act) + ["</s>"])

    def describe(self, act: Action) -> str:
        p = dict(act.param) if act.param and isinstance(
            act.param[0], tuple) else act.param
        return f"{act.kind} @ {act.region} -> {p}"

    # -- (e) cost-model / oracle / lowering hooks --------------------------
    def check_tol(self, prog: KernelProgram
                  ) -> tuple[float, float, bool] | None:
        """Relaxed (rtol, atol, norm_scaled) the oracle should allow
        for programs carrying this rule's markers; None = no opinion.
        ``norm_scaled=True`` asks the checker to scale atol by the
        reference output's max magnitude (reduced-precision error grows
        with magnitude, and fixed atol cannot straddle a relu's
        near-zero crossings and a deep chain's thousands at once)."""
        return None

    def marked_nodes(self, prog: KernelProgram) -> set:
        """Node names whose semantics this rule altered.  Oracle checks
        relax tolerance ONLY for outputs data-dependent on these nodes
        (``output_tolerances``); an empty set with a ``check_tol``
        opinion relaxes the whole program."""
        return set()

    def compute_dtype(self, node) -> str | None:
        """Per-node matmul compute dtype override for the cost model."""
        return None

    def adjust_matmul(self, adj: PriceAdjust, node, sched, out_spec,
                      M, N, K, tiles, target) -> None:
        """Mutate ``adj`` with this rule's pricing deltas for one
        matmul node (neutral by default)."""

    def lower_cast(self, prog, group) -> str | None:
        """Dtype the measure harness should cast a lowered group's
        outputs to (None = leave the kernel's native output)."""
        return None


# ---------------------------------------------------------------------------
# the four classic rules (byte-identical migration of the frozen space)
# ---------------------------------------------------------------------------

class TilingRule(RewriteRule):
    kind = "tiling"

    def group_actions(self, prog, group, root, kind, target):
        return [Action("tiling", root, tuple(sorted(p.items())))
                for p in tile_presets(kind, target)]

    def bad_group_actions(self, prog, group, root, kind, target):
        return [Action("tiling", root, tuple(sorted(bad.items())))
                for bad in BAD_TILES]

    def rewrite(self, prog, act):
        g = group_for_root(prog, act.region)
        tiles = dict(act.param)
        check_tiles(prog, g, tiles)
        sched = prog.schedule_for(g).replace(blocks=tiles)
        return prog.with_schedule(act.region, sched)

    def param_words(self, act):
        out = []
        for bn, bv in act.param:
            out += [bn, bucket(bv)]
        return out


class ReorderRule(RewriteRule):
    kind = "reorder"

    def group_actions(self, prog, group, root, kind, target):
        if kind not in ("matmul", "grouped_matmul"):
            return []
        return [Action("reorder", root, order) for order in LOOP_ORDERS]

    def rewrite(self, prog, act):
        g = group_for_root(prog, act.region)
        kind = sched_kind_of_group(prog, g)
        if kind not in ("matmul", "grouped_matmul"):
            raise CompileError(f"loop reorder not applicable to {kind}")
        order = tuple(act.param)
        if sorted(order) != ["k", "m", "n"]:
            raise CompileError(f"invalid loop order {order}")
        sched = prog.schedule_for(g).replace(loop_order=order)
        return prog.with_schedule(act.region, sched)

    def param_words(self, act):
        return ["order"] + list(act.param)


class PipelineRule(RewriteRule):
    kind = "pipeline"

    def group_actions(self, prog, group, root, kind, target):
        if kind == "elementwise":
            return []
        return [Action("pipeline", root, (d,)) for d in PIPELINE_DEPTHS]

    def rewrite(self, prog, act):
        g = group_for_root(prog, act.region)
        depth = int(act.param[0])
        if not 1 <= depth <= 8:
            raise CompileError(f"pipeline depth {depth} out of range")
        # deeper pipelines multiply live tile buffers: re-check VMEM
        sched = prog.schedule_for(g).replace(pipeline_depth=depth)
        tmp = prog.with_schedule(act.region, sched)
        check_tiles(tmp, g, sched.blocks_dict or None)
        return tmp

    def param_words(self, act):
        return ["depth", bucket(act.param[0])]


class FusionRule(RewriteRule):
    kind = "fusion"

    def global_actions(self, prog, target):
        return [Action("fusion", a, (b,))
                for a, b in fusion_candidates(prog)]

    def bad_global_actions(self, prog, target):
        names = [n.name for n in prog.nodes]
        return [Action("fusion", a, (b,)) for a, b in itertools.islice(
            itertools.combinations(names, 2), 12)]

    def rewrite(self, prog, act):
        a_root, b_root = act.region, act.param[0]
        ga = group_for_root(prog, a_root)
        gb = group_for_root(prog, b_root)
        if ga == gb:
            raise CompileError("cannot fuse a kernel with itself")
        if (a_root, b_root) not in fusion_candidates(prog):
            raise CompileError(
                f"{a_root} and {b_root} are not dataflow-adjacent")
        merged = ga + gb
        nm = prog.node_map
        ops = [nm[n].op for n in merged]
        if sorted(ops) == ["av", "qk_scores", "softmax"]:
            return self._rewrite_flash(prog, ga, gb, merged)
        check_fusion_pattern(prog, merged)
        groups = tuple(g for g in prog.fusion_groups if g not in (ga, gb))
        # preserve topological position of the producer group
        idx = prog.fusion_groups.index(ga)
        groups = groups[:idx] + (merged,) + groups[idx:]
        sm = prog.schedule_map
        sched = sm.pop(a_root, None)
        sm.pop(b_root, None)
        epi = epilogue_of(prog, merged)
        if sched is not None and epi:
            sched = sched.replace(epilogue=epi)
        return prog.replace(fusion_groups=groups,
                            schedules=tuple(sorted(
                                (sm | ({a_root: sched} if sched else {}))
                                .items())))

    @staticmethod
    def _rewrite_flash(prog, ga, gb, merged):
        """qk_scores + softmax + av  ==>  one fused attention node
        (the flash kernel).  The fused node keeps the av node's name so
        downstream consumers stay wired."""
        nm = prog.node_map
        qk = next(nm[n] for n in merged if nm[n].op == "qk_scores")
        av = next(nm[n] for n in merged if nm[n].op == "av")
        fused = dataclasses.replace(
            av, op="attention",
            inputs=(qk.inputs[0], qk.inputs[1], av.inputs[1]),
            attrs=qk.attrs)
        drop = set(merged) - {av.name}
        nodes = tuple(fused if n.name == av.name else n
                      for n in prog.nodes if n.name not in drop)
        groups = tuple(g for g in prog.fusion_groups if g not in (ga, gb))
        idx = prog.fusion_groups.index(ga)
        groups = groups[:idx] + ((av.name,),) + groups[idx:]
        sm = {k: v for k, v in prog.schedule_map.items()
              if k not in merged}
        from repro.kernels.schedule import default_schedule
        sm[av.name] = default_schedule("flash_attention")
        return prog.replace(nodes=nodes, fusion_groups=groups,
                            schedules=tuple(sorted(sm.items())))

    def param_words(self, act):
        # the target slot is resolved in ``words`` (needs the slot map)
        return []

    def words(self, act, slots):
        return [act.kind, slots.get(act.region, "r0"), "@",
                slots.get(act.param[0], "r0"), "</s>"]


class StopRule(RewriteRule):
    kind = "stop"
    terminal = True

    def words(self, act, slots):
        return ["stop", "</s>"]

    def describe(self, act):
        return "stop optimization"


# ---------------------------------------------------------------------------
# extension rules — registered through the registry alone, no dispatch
# edits anywhere else (the extensibility proof of DESIGN.md §12)
# ---------------------------------------------------------------------------

class DtypeRule(RewriteRule):
    """bf16 compute with f32 accumulation on a matmul-family anchor.

    The rewrite stamps ``compute_dtype``/``out_dtype`` attrs on the
    group's matmul/grouped_matmul anchors: operands are rounded to
    bf16, accumulated in f32, and the output is stored bf16.  Pricing
    flows through the existing byte accounting (a bf16 output spec
    halves the group's HBM-out bytes and every downstream consumer's
    operand reads) plus the per-dtype matmul FLOP/s table of the
    ``HardwareTarget``.  The oracle grades the rewrite at a relaxed
    tolerance (bf16 rounding is far above the f32 2e-3 default)."""

    kind = "dtype"
    default = False

    DTYPE = "bfloat16"
    RTOL = 5e-2
    ATOL = 2e-2          # x the reference output's max magnitude

    def _anchors(self, prog, group):
        nm = prog.node_map
        return [nm[n] for n in group
                if nm[n].op in ("matmul", "grouped_matmul")]

    def group_actions(self, prog, group, root, kind, target):
        if kind not in ("matmul", "grouped_matmul"):
            return []
        anchors = self._anchors(prog, group)
        if not anchors or any(a.attr("compute_dtype") for a in anchors):
            return []
        if prog.inputs and prog.inputs[0][1].dtype != "float32":
            return []
        return [Action("dtype", root, (self.DTYPE,))]

    def rewrite(self, prog, act):
        g = group_for_root(prog, act.region)
        dt = act.param[0]
        if dt != self.DTYPE:
            raise CompileError(f"unsupported compute dtype {dt!r}")
        anchors = self._anchors(prog, g)
        if not anchors:
            raise CompileError(
                f"no matmul anchor in kernel {act.region!r} to cast")
        if any(a.attr("compute_dtype") for a in anchors):
            raise CompileError(
                f"kernel {act.region!r} is already reduced-precision")
        names = {a.name for a in anchors}
        extra = (("compute_dtype", dt), ("out_dtype", dt))
        nodes = tuple(
            dataclasses.replace(n, attrs=n.attrs + extra)
            if n.name in names else n for n in prog.nodes)
        return prog.replace(nodes=nodes)

    def param_words(self, act):
        return ["bf16"]

    def check_tol(self, prog):
        if self.marked_nodes(prog):
            return (self.RTOL, self.ATOL, True)
        return None

    def marked_nodes(self, prog):
        return {n.name for n in prog.nodes
                if n.attr("compute_dtype") or n.attr("out_dtype")}

    def compute_dtype(self, node):
        return node.attr("compute_dtype")

    def lower_cast(self, prog, group):
        nm = prog.node_map
        for n in group:
            od = nm[n].attr("out_dtype")
            if od:
                return od
        return None


class SplitKRule(RewriteRule):
    """K-split + partial-sum reduce for skinny-M matmuls.

    Schedule-level rewrite: a ``split_k=S`` flag on the group's
    schedule partitions the K reduction into S concurrent partial
    streams whose f32 partials are reduced at the end.  The math is
    unchanged (the oracle accepts it structurally, like any
    schedule-only rewrite); the pricing hook owns the *stream
    occupancy* term: a matmul whose live output rows under-fill the
    DMA/compute pipeline (rows < 2·sublane) is priced at a fraction
    ``rows·S / (2·sublane)`` of peak HBM bandwidth, and split-K buys
    the occupancy back at the price of ``2·(S-1)·M·N`` partial bytes
    plus the VPU reduce.  Every pre-registry program has
    ``rows >= 2·sublane`` on all registered targets, so classic prices
    are untouched (regression-tested)."""

    kind = "split_k"
    default = False

    SKINNY_M = 64          # legality: target-independent envelope
    SPLITS = (2, 4, 8)
    FLAG = "split_k="

    @classmethod
    def splits_of(cls, sched) -> int:
        for f in sched.flags:
            if f.startswith(cls.FLAG):
                return int(f[len(cls.FLAG):])
        return 1

    def _anchor_dims(self, prog, group):
        """(M, K) of the group's single plain-matmul anchor, else None."""
        nm = prog.node_map
        anchors = [nm[n] for n in group if nm[n].op == "matmul"]
        if len(anchors) != 1:
            return None
        a_spec = prog.shapes().get(anchors[0].inputs[0])
        if a_spec is None:
            a_spec = prog.input_specs.get(anchors[0].inputs[0])
        if a_spec is None or len(a_spec.shape) < 2:
            return None
        return int(np.prod(a_spec.shape[:-1])), int(a_spec.shape[-1])

    def group_actions(self, prog, group, root, kind, target):
        if kind != "matmul":
            return []
        dims = self._anchor_dims(prog, group)
        if dims is None:
            return []
        M, K = dims
        if M > self.SKINNY_M:
            return []
        return [Action("split_k", root, (s,)) for s in self.SPLITS
                if K % s == 0 and (K // s) % 8 == 0]

    def rewrite(self, prog, act):
        g = group_for_root(prog, act.region)
        if sched_kind_of_group(prog, g) != "matmul":
            raise CompileError("split_k applies to matmul kernels only")
        dims = self._anchor_dims(prog, g)
        if dims is None:
            raise CompileError(
                f"kernel {act.region!r} has no single matmul anchor")
        M, K = dims
        if M > self.SKINNY_M:
            raise CompileError(
                f"split_k is for skinny-M matmuls (M={M} > "
                f"{self.SKINNY_M})")
        S = int(act.param[0])
        if not 2 <= S <= 16:
            raise CompileError(f"split factor {S} out of range")
        if K % S != 0 or (K // S) % 8 != 0:
            raise CompileError(
                f"split factor {S} does not evenly divide K={K} into "
                "lane-aligned chunks")
        sched = prog.schedule_for(g)
        flags = tuple(f for f in sched.flags
                      if not f.startswith(self.FLAG))
        sched = sched.replace(flags=flags + (f"{self.FLAG}{S}",))
        return prog.with_schedule(act.region, sched)

    def param_words(self, act):
        return ["sk", bucket(act.param[0])]

    def adjust_matmul(self, adj, node, sched, out_spec, M, N, K,
                      tiles, target):
        tgt = hardware.resolve(target)
        S = self.splits_of(sched)
        rows = min(M, tiles.get("bm", 128))
        occ = min(1.0, (rows * S) / (2.0 * tgt.sublane))
        adj.hbm_scale *= 1.0 / max(occ, 1e-9)
        if S > 1:
            itemsize = out_spec.bytes / max(out_spec.elems, 1)
            adj.hbm_extra += 2.0 * (S - 1) * M * N * itemsize
            adj.vpu_extra += float((S - 1) * M * N)


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------

_RULES: dict[str, RewriteRule] = {}       # insertion-ordered


def register_rule(rule: RewriteRule, *, overwrite: bool = False) -> None:
    if rule.kind in _RULES and not overwrite:
        raise ValueError(f"rule {rule.kind!r} already registered "
                         "(pass overwrite=True to replace)")
    _RULES[rule.kind] = rule


def get_rule(kind: str) -> RewriteRule:
    try:
        return _RULES[kind]
    except KeyError:
        raise KeyError(f"unknown rewrite rule {kind!r}; registered: "
                       f"{sorted(_RULES)}") from None


def registered_rules(extended: bool = True) -> list[RewriteRule]:
    return [r for r in _RULES.values() if extended or r.default]


def is_terminal(act: Action) -> bool:
    r = _RULES.get(act.kind)
    return bool(r is not None and r.terminal)


def describe(act: Action) -> str:
    r = _RULES.get(act.kind)
    if r is not None:
        return r.describe(act)
    p = dict(act.param) if act.param and isinstance(
        act.param[0], tuple) else act.param
    return f"{act.kind} @ {act.region} -> {p}"


def action_words(act: Action, slots: dict[str, str]) -> list[str]:
    r = _RULES.get(act.kind)
    if r is not None:
        return r.words(act, slots)
    # unknown kind: generic serialization (encode() drops OOV words)
    return [act.kind, slots.get(act.region, "r0"), "</s>"]


def apply_rule(prog: KernelProgram, act: Action) -> KernelProgram:
    """Rewrite via the registry; raises CompileError (incl. for unknown
    kinds — an unknown proposal is exactly a compile failure)."""
    r = _RULES.get(act.kind)
    if r is None:
        raise CompileError(f"unknown action kind {act.kind}")
    return r.rewrite(prog, act)


def candidate_actions(prog: KernelProgram, target=None,
                      extended: bool = False) -> list[Action]:
    """Curated action space: per-group candidates from every per-group
    rule (registration order), then program-wide candidates, then
    stop.  On the default target with ``extended=False`` this is
    byte-identical to the pre-registry frozen space."""
    tgt = hardware.resolve(target)
    rules = registered_rules(extended)
    acts: list[Action] = []
    for g in prog.fusion_groups:
        root = prog.group_root(g)
        kind = sched_kind_of_group(prog, g)
        for r in rules:
            if not r.terminal:
                acts += r.group_actions(prog, g, root, kind, tgt)
    for r in rules:
        if not r.terminal:
            acts += r.global_actions(prog, tgt)
    acts.append(STOP)
    return acts


def unrestricted_actions(prog: KernelProgram, target=None,
                         extended: bool = False) -> list[Action]:
    """'w/o AS' ablation: curated + each rule's invalid-prone extras."""
    tgt = hardware.resolve(target)
    rules = registered_rules(extended)
    acts = candidate_actions(prog, tgt, extended)
    for g in prog.fusion_groups:
        root = prog.group_root(g)
        kind = sched_kind_of_group(prog, g)
        for r in rules:
            acts += r.bad_group_actions(prog, g, root, kind, tgt)
    for r in rules:
        acts += r.bad_global_actions(prog, tgt)
    return acts


def check_tolerance(prog: KernelProgram, rtol: float, atol: float
                    ) -> tuple[float, float, bool]:
    """Program-wide oracle tolerance for ``prog``: the defaults,
    relaxed to the max any rule with markers in the program asks for
    (a pure function of the program, so memoized checks stay pure
    functions of their key).  The third element asks the checker to
    scale atol by the reference output's max magnitude (see
    ``RewriteRule.check_tol``).  Oracle checks of multi-output
    programs should prefer ``output_tolerances``, which scopes each
    rule's relaxation to the outputs its markers actually reach."""
    norm = False
    for r in _RULES.values():
        tol = r.check_tol(prog)
        if tol is not None:
            rtol, atol = max(rtol, tol[0]), max(atol, tol[1])
            norm = norm or tol[2]
    return rtol, atol, norm


def output_tolerances(prog: KernelProgram, rtol: float, atol: float
                      ) -> list[tuple[float, float, bool]]:
    """Per-output (rtol, atol, norm_scaled): a rule's relaxation
    applies only to outputs data-dependent on its ``marked_nodes`` —
    an unrelated output of the same program is still graded at the
    defaults, so a relaxed rewrite cannot mask a miscompile elsewhere.
    A rule relaxing without markers relaxes every output."""
    per = [(rtol, atol, False)] * len(prog.outputs)
    for r in _RULES.values():
        tol = r.check_tol(prog)
        if tol is None:
            continue
        marked = r.marked_nodes(prog)
        if marked:
            tainted = set(marked)
            for n in prog.nodes:          # topological order
                if n.name in tainted or any(i in tainted
                                            for i in n.inputs):
                    tainted.add(n.name)
        else:
            tainted = None                # whole-program relaxation
        per = [(max(p[0], tol[0]), max(p[1], tol[1]), p[2] or tol[2])
               if tainted is None or o in tainted else p
               for p, o in zip(per, prog.outputs)]
    return per


def outputs_match(ref, got, rtol: float, atol: float,
                  norm_scaled: bool = False, per_output=None) -> bool:
    """Shared oracle comparison: equal output count, shapes equal +
    allclose per output, with atol optionally scaled by the
    reference's max magnitude (the ``check_tolerance`` contract).
    ``per_output`` (from ``output_tolerances``) overrides the scalar
    tolerances per output.  Used by the store's memoized check, the
    serial pipeline check, the micro-coder's tier-2 validation and the
    measure harness's lowering verification so the paths cannot
    diverge."""
    import jax.numpy as jnp
    ref, got = list(ref), list(got)
    if len(ref) != len(got):
        return False
    for i, (x, y) in enumerate(zip(ref, got)):
        r, a, nrm = per_output[i] if per_output is not None \
            else (rtol, atol, norm_scaled)
        if x.shape != y.shape:
            return False
        if nrm:
            a = a * max(1.0, float(jnp.max(jnp.abs(x))))
        if not bool(jnp.allclose(x, y, rtol=r, atol=a)):
            return False
    return True


def compute_dtype_of(node) -> str | None:
    for r in _RULES.values():
        dt = r.compute_dtype(node)
        if dt is not None:
            return dt
    return None


def matmul_price(node, sched, out_spec, M, N, K, tiles, target
                 ) -> PriceAdjust:
    adj = PriceAdjust()
    for r in _RULES.values():
        r.adjust_matmul(adj, node, sched, out_spec, M, N, K, tiles,
                        target)
    return adj


def lower_cast(prog: KernelProgram, group) -> str | None:
    for r in _RULES.values():
        dt = r.lower_cast(prog, group)
        if dt is not None:
            return dt
    return None


register_rule(TilingRule())
register_rule(ReorderRule())
register_rule(PipelineRule())
register_rule(FusionRule())
register_rule(StopRule())
register_rule(DtypeRule())
register_rule(SplitKRule())
