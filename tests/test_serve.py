"""Continuous-batching serve path.

The load-bearing property (ISSUE 3 acceptance): mixed-length batched
``Engine.generate`` is token-identical to per-prompt solo generation —
per-slot prefill/positions/masks make the rows mathematically
independent.  Plus: per-request EOS, truncation surfacing, slot refill
without group barriers, and the ``prefill_transformer`` left-pad
contamination regression.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced
from repro.models import api
from repro.serve.engine import (Engine, Request, make_serve_step,
                                prefill_transformer)


def _tiny_cfg():
    cfg = reduced(get_config("qwen2_5_3b"))
    return dataclasses.replace(cfg, n_layers=2, d_model=64,
                               vocab_size=128, true_vocab_size=128)


def _tiny():
    cfg = _tiny_cfg()
    return cfg, api.init_params(cfg, jax.random.PRNGKey(0))


def _prompts(n, key=1, lo=1, hi=9):
    k = jax.random.PRNGKey(key)
    return [jax.random.randint(jax.random.fold_in(k, i),
                               (int(1 + i * 7919 % (hi - lo)),), 1, 100,
                               jnp.int32) for i in range(n)]


# ---------------------------------------------------------------------------
# mixed-length parity (the acceptance criterion)
# ---------------------------------------------------------------------------

def test_mixed_length_batched_equals_solo():
    """Every prompt of a mixed-length group decodes to exactly the
    tokens its solo run produces — the old left-pad/shared-pos engine
    corrupted every prompt shorter than its group's longest."""
    cfg, params = _tiny()
    eng = Engine(cfg, params, max_len=32, batch_slots=3)
    prompts = _prompts(7)
    assert len({len(p) for p in prompts}) > 1       # genuinely mixed
    outs = eng.generate(prompts, max_new_tokens=6)
    for p, o in zip(prompts, outs):
        solo = eng.generate([p], max_new_tokens=6)[0]
        assert o == solo, (len(p), o, solo)


def test_slot_refill_no_group_barrier():
    """A long request never holds finished short ones hostage (and vice
    versa): freed slots refill from the queue every step, so the step
    count tracks the longest request, not the sum of group maxima."""
    cfg, params = _tiny()
    eng = Engine(cfg, params, max_len=64, batch_slots=2)
    budgets = [2, 12, 2, 2, 2]
    reqs = [Request(p, b) for p, b in zip(_prompts(5), budgets)]
    eng.run(reqs)
    assert [len(r.out) for r in reqs] == budgets
    assert all(r.done and not r.truncated for r in reqs)
    # continuous schedule: 11 decode steps (the long request's budget
    # dominates; short requests ride along in the second slot).  The
    # old lockstep grouping needed 13.
    assert eng.stats["decode_steps"] == 11
    assert eng.stats["prefills"] == 5


# ---------------------------------------------------------------------------
# per-request EOS + truncation surfacing
# ---------------------------------------------------------------------------

def test_per_request_eos_stops_early():
    cfg, params = _tiny()
    eng = Engine(cfg, params, max_len=32, batch_slots=2)
    p = _prompts(1)[0]
    free = eng.generate([p], max_new_tokens=8)[0]
    eos = free[2]                       # a token the model will emit
    req = Request(p, max_new_tokens=8, eos_id=eos)
    other = Request(_prompts(2)[1], max_new_tokens=8)
    eng.run([req, other])
    stop = free.index(eos)
    assert req.out == free[:stop + 1]   # stopped AT its own eos
    assert req.done and not req.truncated
    assert len(other.out) == 8          # neighbour kept decoding


def test_truncation_is_reported_per_request():
    """pos hitting max_len retires THAT request with truncated=True; the
    old engine silently broke the whole group mid-generation."""
    cfg, params = _tiny()
    eng = Engine(cfg, params, max_len=8, batch_slots=2)
    long_r = Request(jnp.arange(1, 6, dtype=jnp.int32), 10)   # len 5
    short_r = Request(jnp.arange(1, 3, dtype=jnp.int32), 4)   # len 2
    eng.run([long_r, short_r])
    # cache rows 5..7 leave room for 3 decode writes after the prefill
    # token: 4 tokens total, then truncation is surfaced
    assert len(long_r.out) == 4
    assert long_r.truncated and long_r.done
    assert short_r.out and not short_r.truncated  # unaffected neighbour
    assert len(short_r.out) == 4
    assert eng.stats["truncations"] == 1


def test_overlong_prompt_is_truncated_not_crashed():
    cfg, params = _tiny()
    eng = Engine(cfg, params, max_len=8, batch_slots=1)
    r = Request(jnp.arange(1, 20, dtype=jnp.int32), 4)        # len 19 > 8
    eng.run([r])
    assert r.truncated and r.done
    assert len(r.out) >= 1


# ---------------------------------------------------------------------------
# decode-step plumbing: vector positions == scalar positions
# ---------------------------------------------------------------------------

def test_vector_pos_decode_matches_scalar():
    cfg, params = _tiny()
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 5), 1, 100)
    logits, cache = prefill_transformer(cfg, params, toks, 12)
    step = make_serve_step(cfg)
    nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    ls, _ = step(params, cache, nxt, jnp.int32(5))
    lv, _ = step(params, cache, nxt, jnp.full((2,), 5, jnp.int32))
    np.testing.assert_allclose(np.asarray(lv), np.asarray(ls),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# prefill_transformer left-pad contamination (satellite bugfix)
# ---------------------------------------------------------------------------

def test_prefill_pad_mask_matches_solo():
    """Left-padded mixed-length prefill with ``lengths`` masks the pad
    keys/values, so the short row's last-token logits match its solo
    prefill; the unmasked path attends to the pads and diverges."""
    cfg, params = _tiny()
    key = jax.random.PRNGKey(4)
    long_p = jax.random.randint(key, (7,), 1, 100, jnp.int32)
    short_p = jax.random.randint(jax.random.fold_in(key, 1), (3,), 1,
                                 100, jnp.int32)
    toks = jnp.stack([jnp.pad(short_p, (4, 0)), long_p])
    lengths = jnp.array([3, 7])
    lg_m, cache_m = prefill_transformer(cfg, params, toks, 16,
                                        lengths=lengths)
    lg_u, _ = prefill_transformer(cfg, params, toks, 16)
    lg_solo, cache_solo = prefill_transformer(cfg, params,
                                              short_p[None], 16)
    # masked batched == solo (RoPE is relative, so the left-shifted
    # absolute positions cancel in every attention score)
    np.testing.assert_allclose(np.asarray(lg_m[0, -1]),
                               np.asarray(lg_solo[0, -1]),
                               rtol=2e-3, atol=2e-3)
    # the long row is pad-free either way
    np.testing.assert_allclose(np.asarray(lg_m[1, -1]),
                               np.asarray(lg_u[1, -1]),
                               rtol=1e-6, atol=1e-6)
    # the seed's unmasked path really was contaminated
    assert not np.allclose(np.asarray(lg_u[0, -1]),
                           np.asarray(lg_solo[0, -1]),
                           rtol=2e-3, atol=2e-3)
    # decode after a masked prefill fences the pad cache lines with
    # ``start = S - lengths``
    step = make_serve_step(cfg)
    nxt = jnp.argmax(lg_m[:, -1], -1)[:, None].astype(jnp.int32)
    lg2, _ = step(params, cache_m, nxt, jnp.int32(7),
                  jnp.asarray([4, 0], jnp.int32))
    nxt_solo = jnp.argmax(lg_solo[:, -1], -1)[:, None].astype(jnp.int32)
    lg2_solo, _ = step(params, cache_solo, nxt_solo, jnp.int32(3))
    np.testing.assert_allclose(np.asarray(lg2[0, 0]),
                               np.asarray(lg2_solo[0, 0]),
                               rtol=2e-3, atol=2e-3)
