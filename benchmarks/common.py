"""Shared benchmark plumbing: cached policy training + suite evaluation."""
from __future__ import annotations

import os
import pickle
import time

import jax
import numpy as np

from repro.core import (CollectConfig, EnvConfig, EvalEngine,
                        MacroPolicy, OptimizeConfig, PPOConfig,
                        PPOTrainer, PolicyConfig, TranspositionStore,
                        collect_suite, get_reward_source)
from repro.core import tasks as T

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
POLICY_PATH = os.path.join(RESULTS, "macro_policy.pkl")
# committed measurement DB replayed as the PPO reward signal: training
# is hermetic (no timing at train time) yet measured-grounded
REWARD_DB = os.path.join(RESULTS, "policy_reward_db")

# One transposition store for the whole benchmark process: every table,
# mode and ablation sweeps the same suites, so rewrites, cost pricing
# and oracle outputs are shared across all of them.
STORE = TranspositionStore()
WORKERS = max(2, (os.cpu_count() or 2))


def build_reward_db(db_dir: str = REWARD_DB, seed: int = 0,
                    per_task: int = 12, force: bool = False):
    """Populate (or open) the committed reward MeasureDB.

    One-time, OUTSIDE the training loop: collects the same offline
    trees the PPO run replays (same CollectConfig seeds, extended
    action space) and actually executes the root + the ``per_task``
    analytically-cheapest distinct programs of every training task,
    persisting the samples.  Training then replays these measurements
    hermetically through a ``MeasuredRewardSource`` — re-running PPO
    never re-times anything (DESIGN.md §14).
    """
    from repro.measure.db import MeasureDB
    from repro.measure.harness import ExecutionHarness, MeasureConfig
    db = MeasureDB(db_dir)
    if not force and any(True for _ in db.iter_samples()):
        return db
    harness = ExecutionHarness(db=db, cfg=MeasureConfig(
        mode="xla", repeats=3, warmup=1, verify=False))
    trees = collect_suite(
        T.train_tasks(),
        CollectConfig(episodes_random=5, episodes_greedy=6, seed=seed),
        env_cfg=EnvConfig(extended_rules=True), store=STORE)
    for tree in trees.values():
        task = tree.nodes[tree.root].program
        ranked = sorted(tree.nodes.values(), key=lambda n: n.cost_s)
        picked, seen = [], set()
        for node in [tree.nodes[tree.root]] + ranked:
            fp = node.program.fingerprint()
            if fp in seen:
                continue
            seen.add(fp)
            picked.append(node.program)
            if len(picked) > per_task:
                break
        for prog in picked:
            harness.measure(task, prog)
    return db


def train_policy(iters: int = 24, episodes: int = 8, seed: int = 0,
                 pcfg: PolicyConfig | None = None,
                 reward: str = "measured",
                 extended: bool = True) -> MacroPolicy:
    """PPO-train the Macro policy.

    ``reward`` selects the RewardSource pricing the offline trees'
    node costs ("analytic" | "calibrated" | "measured"; the latter two
    replay ``results/policy_reward_db``); ``extended`` trains over the
    full extended-registry action vocabulary (dtype / split_k rules
    included) so the policy's action space matches ``PolicySearch``.
    """
    pcfg = pcfg if pcfg is not None else PolicyConfig()
    rs = None
    if reward != "analytic":
        rs = get_reward_source(reward, db=build_reward_db(seed=seed))
    env_cfg = EnvConfig(extended_rules=extended)
    trees = collect_suite(
        T.train_tasks(),
        CollectConfig(episodes_random=5, episodes_greedy=6, seed=seed),
        env_cfg=env_cfg, store=STORE, reward_source=rs)
    trainer = PPOTrainer(
        trees, pcfg=pcfg,
        cfg=PPOConfig(iters=iters, episodes_per_iter=episodes, seed=seed,
                      max_candidates=32, lr=1e-3, entropy_coef=0.02),
        env_cfg=env_cfg)
    policy = trainer.train()
    policy.train_log = trainer.log
    policy.meta = {
        "reward_source": rs.name if rs is not None else "analytic",
        "reward_db_hits": getattr(rs, "hits", 0),
        "reward_db_misses": getattr(rs, "misses", 0),
        "extended_rules": extended, "vocab_size": pcfg.vocab,
        "iters": iters, "episodes": episodes, "seed": seed}
    return policy


def cached_policy(retrain: bool = False, **kw) -> MacroPolicy:
    os.makedirs(RESULTS, exist_ok=True)
    if not retrain and os.path.exists(POLICY_PATH):
        with open(POLICY_PATH, "rb") as f:
            blob = pickle.load(f)
        pol = MacroPolicy(blob["cfg"], params=jax.tree.map(
            jax.numpy.asarray, blob["params"]))
        pol.train_log = blob.get("log", [])
        pol.meta = blob.get("meta", {})
        return pol
    pol = train_policy(**kw)
    with open(POLICY_PATH, "wb") as f:
        pickle.dump({"cfg": pol.cfg,
                     "params": jax.tree.map(np.asarray, pol.params),
                     "log": getattr(pol, "train_log", []),
                     "meta": getattr(pol, "meta", {})}, f)
    return pol


def eval_mode(suite, mode: str, policy=None, curated: bool = True,
              seed: int = 0, max_steps: int = 8,
              workers: int | None = None) -> dict:
    """Evaluate one (suite x mode) cell through the batched engine.

    Metrics match the serial ``evaluate_suite`` path (seed_stride=0:
    same per-task seeds; the store memoizes only pure functions) — see
    the golden regression in tests/test_engine.py and the oracle-input
    caveat in core/engine.py.
    """
    eng = EvalEngine(policy, store=STORE,
                     config=OptimizeConfig(mode=mode, curated=curated,
                                           seed=seed,
                                           max_steps=max_steps),
                     workers=WORKERS if workers is None else workers)
    t0 = time.time()
    out = eng.evaluate_suite(suite)
    out["wall_s"] = time.time() - t0
    return out


def fmt_row(table: str, name: str, metrics: dict,
            target=None) -> str:
    """CSV: name,us_per_call,derived (spec format); ``target`` selects
    which chip the modeled times are priced against."""
    times = [1e6 * _prog_time(r.program, target)
             for r in metrics["results"]]
    return (f"{table}/{name},{np.mean(times):.1f},"
            f"acc={metrics['accuracy']:.2f};"
            f"fast1={metrics['fast1']:.2f};fast2={metrics['fast2']:.2f};"
            f"speedup={metrics['mean_speedup']:.2f}")


def _prog_time(prog, target=None) -> float:
    from repro.core import program_cost
    return program_cost(prog, target).total_s
