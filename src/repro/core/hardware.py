"""Hardware-target registry — the chip the cost model prices against.

A ``HardwareTarget`` is the full set of roofline parameters one chip
contributes to the analytic cost model: peak matmul FLOP/s per dtype,
vector-unit FLOP/s, HBM and on-chip (VMEM/SMEM) bandwidth and capacity,
the lane/sublane tile-alignment geometry of the matrix unit, and the
per-kernel dispatch overhead.  Everything downstream of the cost model
(env rewards, pipeline/search scoring, the transposition store's cost
memo, autotuned schedule installation) is parameterized by a target, so
one process can price the same program against many chips.

Three targets ship registered (public datasheet numbers):

  tpu_v5e   — 197 TFLOP/s bf16, 819 GB/s HBM, 16 GiB (the seed model's
              constants; stays the default so existing prices are
              bit-identical)
  tpu_v4    — 275 TFLOP/s bf16, 1228 GB/s HBM, 32 GiB
  gpu_a100  — 312 TFLOP/s bf16 (dense), 1555 GB/s HBM2e, 40 GiB; GPU
              tensor-core alignment is finer-grained (lane 64 /
              sublane 16) and kernel launch overhead is higher

Semantics notes (DESIGN.md §9): targets are frozen and registry names
are unique — a cost memo keyed ``(fingerprint, target.name)`` is a pure
function of its key.  Re-registering a name with different numbers
requires ``overwrite=True`` and invalidates any store holding costs for
that name (drop the store wholesale, same rule as a cost-model code
change).
"""
from __future__ import annotations

import dataclasses

GIB = 2 ** 30
MIB = 2 ** 20

# IR dtype string -> datasheet table key (matmul_flops_by_dtype)
_DTYPE_TABLE_KEYS = {"bfloat16": "bf16", "float16": "fp16"}


@dataclasses.dataclass(frozen=True)
class HardwareTarget:
    name: str
    kind: str                    # "tpu" | "gpu"
    # dtype -> peak matmul FLOP/s, first entry = default mixed-precision
    # rate used for dtypes without their own entry (programs are priced
    # at the matrix unit's native rate regardless of storage dtype)
    matmul_flops_by_dtype: tuple[tuple[str, float], ...]
    vector_flops: float          # elementwise / softmax / exp chains
    hbm_bw: float                # bytes/s
    hbm_bytes: float             # capacity
    vmem_bw: float               # on-chip (VMEM / SMEM+L2) bytes/s
    vmem_bytes: float            # on-chip capacity per core/SM-aggregate
    lane: int = 128              # full-efficiency tile multiple
    sublane: int = 8             # reduced-efficiency tile multiple
    launch_s: float = 1.5e-6     # per-kernel dispatch overhead

    def matmul_flops(self, dtype: str = "bf16") -> float:
        """Peak matmul FLOP/s for a dtype.  IR dtype names are
        normalized to the table's datasheet keys ("bfloat16" -> "bf16")
        so a rule-declared compute dtype prices against its real entry;
        anything else without an entry — notably f32 storage — falls
        back to the first (native mixed-precision) rate, the seed
        model's deliberate "priced at the matrix unit's native rate
        regardless of storage dtype" semantics."""
        d = dict(self.matmul_flops_by_dtype)
        key = _DTYPE_TABLE_KEYS.get(dtype, dtype)
        return d.get(key, self.matmul_flops_by_dtype[0][1])

    def mxu_efficiency(self, tiles: dict[str, int]) -> float:
        """Achievable fraction of peak for a tile dict: full-rate when
        every tile is lane-aligned, reduced when sublane-aligned, poor
        otherwise (padding + partial-tile waste)."""
        if not tiles:
            return 0.45
        vals = list(tiles.values())
        if all(v % self.lane == 0 for v in vals):
            return 0.85
        if all(v % self.sublane == 0 for v in vals):
            return 0.45
        return 0.15


_REGISTRY: dict[str, HardwareTarget] = {}

DEFAULT_TARGET = "tpu_v5e"


def register_target(t: HardwareTarget, *, overwrite: bool = False) -> None:
    if t.name in _REGISTRY and not overwrite:
        raise ValueError(f"target {t.name!r} already registered "
                         "(pass overwrite=True to replace — and drop "
                         "any TranspositionStore holding its costs)")
    _REGISTRY[t.name] = t


def get_target(name: str) -> HardwareTarget:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown hardware target {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def registered_targets() -> list[str]:
    return sorted(_REGISTRY)


def resolve(target: "HardwareTarget | str | None") -> HardwareTarget:
    """None -> default target; str -> registry lookup; pass-through."""
    if target is None:
        return _REGISTRY[DEFAULT_TARGET]
    if isinstance(target, str):
        return get_target(target)
    return target


register_target(HardwareTarget(
    name="tpu_v5e", kind="tpu",
    matmul_flops_by_dtype=(("bf16", 197e12), ("int8", 394e12)),
    vector_flops=4e12,
    hbm_bw=819e9, hbm_bytes=16 * GIB,
    vmem_bw=11e12, vmem_bytes=16 * MIB,
    lane=128, sublane=8, launch_s=1.5e-6))

register_target(HardwareTarget(
    name="tpu_v4", kind="tpu",
    matmul_flops_by_dtype=(("bf16", 275e12), ("int8", 275e12)),
    vector_flops=4.4e12,
    hbm_bw=1228e9, hbm_bytes=32 * GIB,
    vmem_bw=15e12, vmem_bytes=16 * MIB,
    lane=128, sublane=8, launch_s=1.5e-6))

register_target(HardwareTarget(
    name="gpu_a100", kind="gpu",
    matmul_flops_by_dtype=(("bf16", 312e12), ("fp16", 312e12),
                           ("tf32", 156e12), ("int8", 624e12)),
    vector_flops=19.5e12,
    hbm_bw=1555e9, hbm_bytes=40 * GIB,
    vmem_bw=19e12, vmem_bytes=20 * MIB,
    lane=64, sublane=16, launch_s=4e-6))
