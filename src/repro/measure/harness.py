"""Measured execution of candidate ``KernelProgram``s.

The analytic roofline (``core/cost_model.py``) prices programs against a
TPU datasheet; nothing in it ever *runs* one.  This harness closes that
loop: it lowers a program through the same kernel library the
micro-coding schedules target and times the result on the backend that
is actually attached —

* fusion groups whose pattern the Pallas kernel library implements
  (matmul + fusable epilogue chain, the flash-attention node, rmsnorm,
  grouped matmul) are lowered to the real ``kernels/*`` Pallas calls
  with the group's ``KernelSchedule`` (tiles, loop order, epilogue), in
  **interpret mode** when no TPU is attached (CPU CI) so the schedule
  still shapes the executed grid;
* everything else (elementwise chains, the unfused qk/av ops, scans)
  runs through the jnp reference semantics inside the same jit.

Every measurement is warmup + repeated timing + MAD outlier rejection +
trimmed median (``measure/timing.py``), stamped with an environment
fingerprint (backend, jax version, mode, target constants) and persisted
to a ``MeasureDB`` so later sessions — and the ``KernelService`` — reuse
it instead of re-timing (DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses
import threading
from collections.abc import Callable

import jax
import numpy as np

from repro.core import cost_model, hardware
from repro.core.kernel_ir import (KernelProgram, _eval_op, evaluate,
                                  make_inputs_np, program_to_json)
from repro.measure.db import MeasureDB, MeasureSample, env_fingerprint
from repro.measure.timing import robust_time_s, time_thunk

# epilogue chains _lower_matmul_group can hand to the matmul kernel's
# fused epilogue (kernels/matmul.py::_apply_epilogue)
_EPILOGUE_ACTS = ("relu", "gelu", "silu")


class MeasureError(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class MeasureConfig:
    warmup: int = 1
    repeats: int = 5
    trim: float = 0.2            # trimmed-median fraction per side
    mad_k: float = 4.0           # MAD outlier-rejection threshold
    mode: str = "auto"           # auto | xla | pallas
    max_grid_cells: int = 1024   # pallas-interpret compile-cost cap
    verify: bool = True          # cross-check lowering vs the oracle
    verify_tol: float = 5e-2
    seed: int = 0                # measurement-input seed


@dataclasses.dataclass(frozen=True)
class LoweredProgram:
    fn: Callable                 # jitted: inputs dict -> list of outputs
    mode: str                    # "xla" | "pallas" | "pallas_interpret"
    n_pallas: int                # groups lowered to Pallas kernels


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------

def _grid_cells(*dims_and_blocks: tuple[int, int]) -> int:
    n = 1
    for dim, blk in dims_and_blocks:
        n *= max(1, dim // max(1, blk))
    return n


def _external_uses(prog: KernelProgram, group: tuple[str, ...]
                   ) -> set[str]:
    internal = set(group)
    used = set()
    for n in prog.nodes:
        if n.name in internal:
            continue
        for i in n.inputs:
            if i in internal:
                used.add(i)
    for o in prog.outputs:
        if o in internal:
            used.add(o)
    return used


def _lower_matmul_group(prog, group, shapes, sched, interpret,
                        max_cells):
    """One fused Pallas matmul for ``anchor + epilogue chain``, when the
    whole group maps onto the kernel's epilogue vocabulary; otherwise
    the anchor alone goes to Pallas and the rest stays eager.  Returns
    (emit_fn, covered_names, emit_name) or None if ineligible."""
    nm = prog.node_map
    anchors = [n for n in group if nm[n].op == "matmul"]
    if len(anchors) != 1:
        return None
    anchor = nm[anchors[0]]
    a_spec = shapes.get(anchor.inputs[0],
                        prog.input_specs.get(anchor.inputs[0]))
    b_spec = shapes.get(anchor.inputs[1],
                        prog.input_specs.get(anchor.inputs[1]))
    if a_spec is None or b_spec is None or len(a_spec.shape) != 2 \
            or len(b_spec.shape) != 2:
        return None
    M, K = a_spec.shape
    N = b_spec.shape[1]
    bm = min(sched.block("bm", 128), M)
    bn = min(sched.block("bn", 128), N)
    bk = min(sched.block("bk", 128), K)
    if M % bm or N % bn or K % bk:
        return None
    if interpret and _grid_cells((M, bm), (N, bn), (K, bk)) > max_cells:
        return None

    # can the rest of the group ride the kernel's fused epilogue?
    rest = [nm[n] for n in group if n != anchor.name]
    epilogue, bias_in, covered = "none", None, [anchor.name]
    cur = anchor.name
    for node in rest:
        if node.op == "bias" and epilogue == "none" \
                and node.inputs[0] == cur:
            epilogue, bias_in, cur = "bias", node.inputs[1], node.name
            covered.append(node.name)
        elif node.op in _EPILOGUE_ACTS and node.inputs[0] == cur \
                and not epilogue.split("_")[-1] in _EPILOGUE_ACTS:
            epilogue = (f"{epilogue}_{node.op}"
                        if epilogue != "none" else node.op)
            cur = node.name
            covered.append(node.name)
        elif node.op == "row_max" and epilogue == "none" \
                and node.inputs[0] == cur and len(rest) == 1:
            epilogue, cur = "row_max", node.name
            covered.append(node.name)
        else:
            break
    if len(covered) < len(group):
        # chain did not absorb the whole group -> anchor-only kernel
        epilogue, bias_in, covered, cur = "none", None, [anchor.name], \
            anchor.name
    elif any(n in _external_uses(prog, group) for n in covered[:-1]):
        # a fused intermediate is consumed outside the group: the
        # kernel would not materialize it — fall back to anchor-only
        epilogue, bias_in, covered, cur = "none", None, [anchor.name], \
            anchor.name
    if bias_in is not None:
        b_shape = shapes.get(bias_in,
                             prog.input_specs.get(bias_in)).shape
        if len(b_shape) != 1:
            return None

    from repro.kernels import matmul as mm

    def emit(env):
        bias = env[bias_in] if bias_in is not None else None
        return mm.matmul(env[anchor.inputs[0]], env[anchor.inputs[1]],
                         epilogue=epilogue, bias=bias, schedule=sched,
                         interpret=interpret)
    return emit, tuple(covered), cur


def _lower_attention_group(prog, group, shapes, sched, interpret,
                           max_cells):
    nm = prog.node_map
    att = [n for n in group if nm[n].op == "attention"]
    if len(att) != 1:
        return None
    node = nm[att[0]]
    q = shapes.get(node.inputs[0], prog.input_specs.get(node.inputs[0]))
    k = shapes.get(node.inputs[1], prog.input_specs.get(node.inputs[1]))
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    bq = min(sched.block("bq", 128), Sq)
    bk = min(sched.block("bk", 128), Sk)
    if Sq % bq or Sk % bk or H % KV or hd % 8:
        return None
    if interpret and B * H * _grid_cells((Sq, bq), (Sk, bk)) > max_cells:
        return None

    from repro.kernels import flash_attention as fa

    def emit(env):
        return fa.flash_attention(
            env[node.inputs[0]], env[node.inputs[1]],
            env[node.inputs[2]],
            causal=bool(node.attr("causal", True)),
            window=int(node.attr("window", 0)),
            schedule=sched, interpret=interpret)
    return emit, (node.name,), node.name


def _lower_rmsnorm_group(prog, group, shapes, sched, interpret,
                         max_cells):
    nm = prog.node_map
    rn_nodes = [n for n in group if nm[n].op == "rmsnorm"]
    if len(rn_nodes) != 1:
        return None
    node = nm[rn_nodes[0]]
    x = shapes.get(node.inputs[0], prog.input_specs.get(node.inputs[0]))
    R = int(np.prod(x.shape[:-1]))
    br = min(sched.block("rows", 256), R)
    if R % br:
        br = 1
    if interpret and _grid_cells((R, br)) > max_cells:
        return None

    from repro.kernels import rmsnorm as rn

    def emit(env):
        return rn.rmsnorm(env[node.inputs[0]], env[node.inputs[1]],
                          schedule=sched, interpret=interpret)
    return emit, (node.name,), node.name


def _lower_grouped_matmul_group(prog, group, shapes, sched, interpret,
                                max_cells):
    nm = prog.node_map
    anchors = [n for n in group if nm[n].op == "grouped_matmul"]
    if len(anchors) != 1:
        return None
    node = nm[anchors[0]]
    x = shapes.get(node.inputs[0], prog.input_specs.get(node.inputs[0]))
    E, C, D = x.shape
    F = shapes.get(node.inputs[1],
                   prog.input_specs.get(node.inputs[1])).shape[-1]
    bc = min(sched.block("bc", 128), C)
    bf = min(sched.block("bf", 128), F)
    bd = min(sched.block("bd", 128), D)
    if C % bc or F % bf or D % bd:
        return None
    if interpret and E * _grid_cells((C, bc), (F, bf), (D, bd)) \
            > max_cells:
        return None

    from repro.kernels import grouped_matmul as gm

    def emit(env):
        return gm.grouped_matmul(env[node.inputs[0]],
                                 env[node.inputs[1]],
                                 schedule=sched, interpret=interpret)
    return emit, (node.name,), node.name


_GROUP_LOWERERS = {
    "matmul": _lower_matmul_group,
    "flash_attention": _lower_attention_group,
    "rmsnorm": _lower_rmsnorm_group,
    "grouped_matmul": _lower_grouped_matmul_group,
}


def _cast_emit(emit_fn, dtype: str):
    """Wrap a group's emit to cast its outputs (rules.lower_cast)."""
    def emit(env):
        return jax.tree.map(lambda t: t.astype(dtype), emit_fn(env))
    return emit


def lower_program(prog: KernelProgram, *, mode: str = "auto",
                  max_grid_cells: int = 1024) -> LoweredProgram:
    """Build a jitted callable executing ``prog`` with its schedules.

    ``mode``: ``"xla"`` jits the reference semantics only (the host
    backend's compiled baseline); ``"auto"``/``"pallas"`` additionally
    lower eligible fusion groups to the Pallas kernel library —
    interpret mode off-TPU — with ``"pallas"`` raising ``MeasureError``
    when not a single group is Pallas-eligible (tests use this to pin
    coverage).  The executed math is identical in every mode; only the
    kernel realization differs.

    Rewrite rules participate through registry hooks: a rule whose
    markers are present in a group may ask for the lowered outputs to
    be cast (``rules.lower_cast`` — the dtype rule's bf16 storage), so
    the measured kernel is faithful to what the oracle graded without
    this module dispatching on rule kinds.
    """
    from repro.core import rules
    from repro.core.kernel_ir import sched_kind_of_group

    interpret = jax.default_backend() != "tpu"
    plans: dict[str, tuple] = {}     # emit node -> (emit_fn, covered)
    covered_all: set[str] = set()
    n_pallas = 0
    if mode in ("auto", "pallas"):
        shapes = prog.shapes()
        for g in prog.fusion_groups:
            kind = sched_kind_of_group(prog, g)
            lower = _GROUP_LOWERERS.get(kind)
            if lower is None:
                continue
            try:
                plan = lower(prog, g, shapes, prog.schedule_for(g),
                             interpret, max_grid_cells)
            except Exception:
                # an unexpected shape/rank a lowerer did not guard for
                # must degrade to the eager path, not kill the caller
                plan = None
            if plan is None:
                continue
            emit_fn, covered, emit_name = plan
            cast = rules.lower_cast(prog, g)
            if cast is not None:
                emit_fn = _cast_emit(emit_fn, cast)
            plans[emit_name] = (emit_fn, covered)
            covered_all.update(covered)
            n_pallas += 1
    elif mode != "xla":
        raise MeasureError(f"unknown measurement mode {mode!r}")
    if mode == "pallas" and n_pallas == 0:
        raise MeasureError(
            f"no Pallas-eligible fusion group in {prog.name!r}")

    def fn(inputs):
        env = dict(inputs)
        for n in prog.nodes:
            if n.name in plans:
                env[n.name] = plans[n.name][0](env)
            elif n.name in covered_all:
                continue          # materialized inside a fused kernel
            else:
                env[n.name] = _eval_op(n, [env[i] for i in n.inputs])
        return [env[o] for o in prog.outputs]

    used = ("xla" if n_pallas == 0 else
            "pallas_interpret" if interpret else "pallas")
    return LoweredProgram(jax.jit(fn), used, n_pallas)


# ---------------------------------------------------------------------------
# the harness
# ---------------------------------------------------------------------------

class ExecutionHarness:
    """Measure programs; cache in a ``MeasureDB``; count hits/misses.

    Thread-safe: actual timed execution is serialized under one lock so
    concurrent service workers cannot perturb each other's samples (a
    measurement taken while another thread saturates the host would be
    noise, not signal).  ``runner`` injects a synthetic measurement
    function ``(task, prog, target) -> seconds`` for deterministic
    tests and offline what-if studies — everything downstream (DB,
    calibration, reranking) is exercised identically.
    """

    def __init__(self, *, db: MeasureDB | None = None,
                 cfg: MeasureConfig | None = None,
                 runner: Callable | None = None):
        self.db = db
        self.cfg = cfg or MeasureConfig()
        self.runner = runner
        self.stats = {"measured": 0, "db_hits": 0, "db_misses": 0,
                      "verify_fallbacks": 0, "analysis_rejects": 0}
        self._lock = threading.RLock()
        self._env_fps: dict[str, tuple[str, tuple]] = {}
        self._lowered: dict[str, LoweredProgram] = {}
        self._inputs: dict[tuple[str, int], dict] = {}
        self._analysis: dict[str, tuple] = {}   # prog_fp -> error diags

    # -- environment ---------------------------------------------------------
    def env_fp(self, target=None) -> str:
        tgt = hardware.resolve(target)
        with self._lock:
            hit = self._env_fps.get(tgt.name)
            if hit is None:
                cfg = self.cfg
                # max_grid_cells joins the rigor: it decides whether a
                # candidate lowers to pallas-interpret or falls back to
                # xla, and those regimes' wall times must never share a
                # key; seed fixes the measurement inputs
                hit = env_fingerprint(
                    tgt, cfg.mode,
                    rigor=(cfg.warmup, cfg.repeats, cfg.trim,
                           cfg.mad_k, cfg.max_grid_cells, cfg.seed))
                self._env_fps[tgt.name] = hit
        return hit[0]

    def _env(self, target) -> tuple[tuple[str, str], ...]:
        self.env_fp(target)
        return self._env_fps[hardware.resolve(target).name][1]

    # -- measurement ---------------------------------------------------------
    def _analysis_errors(self, prog: KernelProgram) -> tuple:
        """Memoized ERROR diagnostics for ``prog`` (portability
        envelope) — the static gate in front of lowering/timing."""
        fp = prog.fingerprint()
        hit = self._analysis.get(fp)
        if hit is None:
            from repro.analysis.legality import analyze_program
            try:
                hit = tuple(d for d in analyze_program(prog)
                            if d.is_error)
            except Exception:
                hit = ()     # analyzer crash must not block measuring
            with self._lock:
                self._analysis[fp] = hit
        return hit

    def measure(self, task: KernelProgram, prog: KernelProgram, *,
                target=None) -> MeasureSample:
        tgt = hardware.resolve(target)
        env_fp = self.env_fp(tgt)
        key = (task.fingerprint(), prog.fingerprint(), tgt.name, env_fp)
        if self.db is not None:
            hit = self.db.get(*key)
            if hit is not None:
                with self._lock:
                    self.stats["db_hits"] += 1
                return hit
        # refuse to spend lowering + wall-clock on a program static
        # analysis already rejects; the MeasureError carries the
        # diagnostics (rerankers skip the candidate, like any failure)
        errs = self._analysis_errors(prog)
        if errs:
            with self._lock:
                self.stats["analysis_rejects"] += 1
            raise MeasureError(
                f"static analysis rejects {prog.name!r}: "
                + "; ".join(d.render() for d in errs[:3])
                + (f" (+{len(errs) - 3} more)" if len(errs) > 3 else ""))
        pc = cost_model.program_cost(prog, tgt)
        with self._lock:
            if self.db is not None:
                # double-checked: a concurrent same-key caller may have
                # timed this program while we waited for the lock
                hit = self.db.get(*key)
                if hit is not None:
                    self.stats["db_hits"] += 1
                    return hit
                self.stats["db_misses"] += 1
            if self.runner is not None:
                t = float(self.runner(task, prog, tgt))
                samples, n_rej, used = (t,), 0, "injected"
            else:
                try:
                    t, samples, n_rej, used = self._time(prog)
                except MeasureError:
                    raise
                except Exception as e:
                    # surface every measurement failure through ONE
                    # exception type so rerankers can skip the
                    # candidate instead of failing the request
                    raise MeasureError(
                        f"measuring {prog.name!r} failed: "
                        f"{type(e).__name__}: {e}") from e
            self.stats["measured"] += 1
        try:
            # embed the measured program so the sample is self-contained
            # training data for the learned cost model (DESIGN.md §17);
            # a program with non-JSON attrs just ships without one
            prog_json = program_to_json(prog)
        except (TypeError, ValueError):
            prog_json = None
        sample = MeasureSample(
            task_fp=key[0], prog_fp=key[1], target=tgt.name,
            env_fp=env_fp, time_s=t, samples=tuple(samples),
            n_rejected=n_rej, mode=used, analytic_s=pc.total_s,
            bottleneck=pc.bottleneck.split(":")[-1],
            env=self._env(tgt), program=prog_json)
        if self.db is not None:
            self.db.put(sample)
        return sample

    def _time(self, prog: KernelProgram
              ) -> tuple[float, list[float], int, str]:
        cfg = self.cfg
        lowered = self._lower(prog)
        inputs = self._task_inputs(prog)

        def thunk():
            jax.block_until_ready(lowered.fn(inputs))

        samples = time_thunk(thunk, warmup=cfg.warmup,
                             repeats=cfg.repeats)
        t, n_rej = robust_time_s(samples, trim=cfg.trim,
                                 mad_k=cfg.mad_k)
        return t, samples, n_rej, lowered.mode

    def _lower(self, prog: KernelProgram) -> LoweredProgram:
        fp = prog.fingerprint()
        hit = self._lowered.get(fp)
        if hit is not None:
            return hit
        lowered = lower_program(prog, mode=self.cfg.mode,
                                max_grid_cells=self.cfg.max_grid_cells)
        if self.cfg.verify and lowered.mode != "xla":
            # same per-output tolerance contract as the store /
            # pipeline / coder checks: a rule with markers (e.g. bf16
            # dtype) relaxes verification only for the outputs its
            # marked nodes reach — without the relaxation a valid
            # reduced-precision lowering would systematically fall
            # back to xla and drop out of measured reranking; with a
            # whole-program one, a kernel bug in an unrelated group
            # could ride along
            from repro.core import rules
            per_tol = rules.output_tolerances(
                prog, self.cfg.verify_tol, self.cfg.verify_tol)
            try:
                inputs = self._task_inputs(prog)
                want = evaluate(prog, inputs)
                got = lowered.fn(inputs)
                ok = rules.outputs_match(want, got, self.cfg.verify_tol,
                                         self.cfg.verify_tol,
                                         per_output=per_tol)
            except Exception:
                # a lowering that cannot even execute is graded like a
                # mismatch: fall back to the reference semantics
                ok = False
            if not ok:
                # a lowering that disagrees with the oracle must never
                # produce a sample: time the reference semantics instead
                self.stats["verify_fallbacks"] += 1
                lowered = lower_program(prog, mode="xla")
        if len(self._lowered) > 256:    # bound jit-cache growth
            self._lowered.clear()
        self._lowered[fp] = lowered
        return lowered

    def _task_inputs(self, prog: KernelProgram) -> dict:
        key = (repr(prog.inputs), self.cfg.seed)
        hit = self._inputs.get(key)
        if hit is None:
            hit = {k: jax.numpy.asarray(v) for k, v in
                   make_inputs_np(prog, self.cfg.seed).items()}
            if len(self._inputs) > 64:
                self._inputs.clear()
            self._inputs[key] = hit
        return hit

    def stats_dict(self) -> dict:
        with self._lock:
            out = dict(self.stats)
        # DB health counters ride along (db_* prefixed) so the serving
        # layer surfaces corruption/crash-reaping without reaching into
        # the DB itself (a fleet replica's stats() is its health probe)
        db = self.db.stats_dict() if self.db is not None else {}
        for k in ("corrupt_records", "tmp_reaped", "lock_timeouts",
                  "winner_refreshes"):
            out[f"db_{k}"] = db.get(k, 0)
        return out
