"""End-to-end driver: train the Macro Thinking policy with PPO.

    PYTHONPATH=src python examples/train_policy.py [--iters 30]

This is the paper's training pipeline end to end: collect offline
optimization trajectories on the training tasks (NO benchmark instances),
build the tree-structured RL environment, PPO-train the lightweight LM
policy with the staged reward shaping, then evaluate against the random
and untrained baselines on held-out benchmark tasks.
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core import (CollectConfig, MTMCPipeline, MacroPolicy,  # noqa: E402
                        OptimizeConfig, PPOConfig, PPOTrainer,
                        collect_suite, evaluate_suite)
from repro.core import tasks  # noqa: E402
from repro.core.trajectories import tree_stats  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--episodes", type=int, default=6)
    args = ap.parse_args()

    print("== collecting offline trajectories (training tasks only) ==")
    trees = collect_suite(tasks.train_tasks(),
                          CollectConfig(episodes_random=5,
                                        episodes_greedy=4))
    for name, tree in list(trees.items())[:5]:
        print(f"  {name}: {tree_stats(tree)}")
    print(f"  ... {len(trees)} trees total")

    print("\n== PPO training (offline tree env) ==")
    trainer = PPOTrainer(trees, cfg=PPOConfig(
        iters=args.iters, episodes_per_iter=args.episodes, lr=1e-3,
        max_candidates=32))
    policy = trainer.train()
    for log in trainer.log:
        print(f"  iter {log['iter']:3d} reward={log['mean_reward']:+.3f} "
              f"speedup={log['mean_final_speedup']:.2f} "
              f"entropy={log['entropy']:.2f}")

    print("\n== held-out evaluation (KB-L2-like suite) ==")
    suite = tasks.kb_level2()
    for name, pipe in [
            ("MTMC (ours)", MTMCPipeline(
                policy, config=OptimizeConfig(mode="policy"))),
            ("untrained LM", MTMCPipeline(
                MacroPolicy(), config=OptimizeConfig(mode="untrained"))),
            ("random", MTMCPipeline(
                None, config=OptimizeConfig(mode="random")))]:
        m = evaluate_suite(suite, pipe)
        print(f"  {name:14s} acc={m['accuracy']:.2f} "
              f"fast1={m['fast1']:.2f} speedup={m['mean_speedup']:.2f}")


if __name__ == "__main__":
    main()
