"""Train a small LM end-to-end with the full framework substrate:
synthetic sharded data pipeline, AdamW, microbatch accumulation, remat,
async checkpointing + resume, straggler monitoring.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--wide]

Default is a ~7M-param qwen-family model (CPU-friendly); --wide bumps it
to ~100M params (slower per step, same code path as the 34B configs).
"""
import argparse
import dataclasses
import sys
import tempfile

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.configs.base import RunConfig, ShapeConfig  # noqa: E402
from repro.configs.registry import get_config, reduced  # noqa: E402
from repro.ft import StragglerMonitor  # noqa: E402
from repro.train.trainer import Trainer  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--wide", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = reduced(get_config("qwen2_5_3b"))
    cfg = dataclasses.replace(
        cfg, n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
        head_dim=64, d_ff=1024, vocab_size=8192, true_vocab_size=8192,
        true_n_heads=4)
    if args.wide:
        cfg = dataclasses.replace(cfg, n_layers=8, d_model=768,
                                  n_heads=12, n_kv_heads=4, d_ff=3072,
                                  vocab_size=32768, true_vocab_size=32768,
                                  true_n_heads=12)
    shape = ShapeConfig("lm", seq_len=256, global_batch=8, kind="train")
    n = cfg.n_params()
    print(f"model: {n / 1e6:.1f}M params, {cfg.n_layers}L "
          f"d={cfg.d_model}, seq {shape.seq_len} x batch "
          f"{shape.global_batch}")

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="lm_ckpt_")
    monitor = StragglerMonitor()
    trainer = Trainer(cfg, shape, RunConfig(accum_steps=1, remat=True),
                      ckpt_dir=ckpt_dir, ckpt_every=20,
                      straggler_monitor=monitor)
    state = trainer.restore_or_init()
    print(f"starting at step {state.step} "
          f"(checkpoints -> {ckpt_dir})")
    state = trainer.run_steps(state, args.steps)
    first = trainer.metrics_log[0]["loss"]
    last = trainer.metrics_log[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({'improved' if last < first else 'NOT improved'})")
    if monitor.events:
        print(f"straggler events: {len(monitor.events)}")
    print("resume check: re-open trainer and restore ...")
    t2 = Trainer(cfg, shape, RunConfig(accum_steps=1), ckpt_dir=ckpt_dir)
    s2 = t2.restore_or_init()
    print(f"restored at step {s2.step}")


if __name__ == "__main__":
    main()
