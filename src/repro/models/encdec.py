"""Encoder-decoder transformer (seamless-m4t-medium backbone).

The speech frontend is a STUB per the assignment spec: ``input_specs()``
provides precomputed frame embeddings (B, enc_len, D) as ``enc_embeds``.
Encoder: bidirectional self-attention.  Decoder: causal self-attention +
cross-attention over encoder output.  RoPE on self-attention paths;
cross-attention is position-free (documented deviation from m4t's relative
positions — DESIGN.md §7).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import transformer
from repro.models.layers import (
    apply_rope, linear, normal_init, ones_init, zeros_init,
)


def param_tree(cfg: ModelConfig, make):
    V, D = cfg.vocab_size, cfg.d_model
    return {
        "embed": make("embed", (V, D), ("vocab", "embed"),
                      normal_init(0.02)),
        "enc_blocks": transformer.block_tree(
            cfg, make, prefix="enc_", n_layers=cfg.encoder_layers),
        "enc_norm": make("enc_norm", (D,), ("embed",), ones_init()),
        "blocks": transformer.block_tree(cfg, make, prefix="dec_",
                                         cross=True),
        "final_norm": make("final_norm", (D,), ("embed",), ones_init()),
        "lm_head": make("lm_head", (D, V), ("embed", "vocab"),
                        normal_init(0.02)),
    }


def _self_attn(cfg, p, x, *, causal, rules=None, positions=None):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = ops.rmsnorm(x, p["attn_norm"], eps=cfg.norm_eps)
    q = linear(h, p["wq"]).reshape(B, S, H, hd)
    k = linear(h, p["wk"]).reshape(B, S, KV, hd)
    v = linear(h, p["wv"]).reshape(B, S, KV, hd)
    if positions is None:
        positions = jnp.arange(S)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if rules is not None:
        from repro.models.transformer import _q_axes
        q = rules.constrain(q, _q_axes(cfg, rules))
        k = rules.constrain(k, ("batch", None, "kv_heads", None))
        v = rules.constrain(v, ("batch", None, "kv_heads", None))
    o = ops.attention(q, k, v, causal=causal)
    return linear(o.reshape(B, S, H * hd), p["wo"])


def _cross_attn(cfg, p, x, enc_kv, rules=None):
    """enc_kv: precomputed (k, v) each (B, enc_len, KV, hd)."""
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    h = ops.rmsnorm(x, p["cross_norm"], eps=cfg.norm_eps)
    q = linear(h, p["c_wq"]).reshape(B, S, H, hd)
    if rules is not None:
        q = rules.constrain(q, ("batch", None, "heads", None))
    k, v = enc_kv
    o = ops.attention(q, k, v, causal=False)
    return linear(o.reshape(B, S, H * hd), p["c_wo"])


def _enc_kv(cfg, p, enc_out):
    """Per-layer cross K/V from encoder output (p = one dec layer)."""
    B, S, _ = enc_out.shape
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    k = linear(enc_out, p["c_wk"]).reshape(B, S, KV, hd)
    v = linear(enc_out, p["c_wv"]).reshape(B, S, KV, hd)
    return k, v


def encode(cfg: ModelConfig, params: dict, enc_embeds: jax.Array, *,
           rules=None, remat: bool = True):
    x = enc_embeds.astype(jnp.dtype(cfg.compute_dtype))
    if rules is not None:
        x = rules.constrain(x, ("batch", None, None))

    def block(x, p):
        x = x + _self_attn(cfg, p, x, causal=False, rules=rules)
        delta, _ = transformer.mlp_block(cfg, p, x, rules)
        x = x + delta
        if rules is not None:
            x = rules.constrain(x, ("batch", None, None))
        return x, None

    if remat:
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(block, x, params["enc_blocks"])
    return ops.rmsnorm(x, params["enc_norm"], eps=cfg.norm_eps)


def forward(cfg: ModelConfig, params: dict, batch: dict, *, rules=None,
            remat: bool = True, collect_cache: bool = False):
    """batch: {'tokens': (B,S), 'enc_embeds': (B,enc_len,D)}."""
    enc_out = encode(cfg, params, batch["enc_embeds"], rules=rules,
                     remat=remat)
    tokens = batch["tokens"]
    cdt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"].astype(cdt)[tokens]
    if rules is not None:
        x = rules.constrain(x, ("batch", None, None))

    def block(x, p):
        x = x + _self_attn(cfg, p, x, causal=True, rules=rules)
        x = x + _cross_attn(cfg, p, x, _enc_kv(cfg, p, enc_out), rules)
        delta, _ = transformer.mlp_block(cfg, p, x, rules)
        x = x + delta
        if rules is not None:
            x = rules.constrain(x, ("batch", None, None))
        return x, None

    if remat:
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(block, x, params["blocks"])
    x = ops.rmsnorm(x, params["final_norm"], eps=cfg.norm_eps)
    logits = transformer.unembed(cfg, params, x, rules)
    return logits, jnp.float32(0)


# ---------------------------------------------------------------------------
# decode: self KV cache + precomputed cross K/V
# ---------------------------------------------------------------------------

def cache_tree(cfg: ModelConfig, make, batch: int, max_len: int):
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    E = cfg.enc_len
    return {
        "k": make("cache_k", (L, batch, max_len, KV, hd),
                  ("layers", "batch", "kv_seq", "kv_heads", None),
                  zeros_init()),
        "v": make("cache_v", (L, batch, max_len, KV, hd),
                  ("layers", "batch", "kv_seq", "kv_heads", None),
                  zeros_init()),
        "cross_k": make("cache_cross_k", (L, batch, E, KV, hd),
                        ("layers", "batch", None, "kv_heads", None),
                        zeros_init()),
        "cross_v": make("cache_cross_v", (L, batch, E, KV, hd),
                        ("layers", "batch", None, "kv_heads", None),
                        zeros_init()),
    }


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array, pos: jax.Array, *, rules=None):
    cdt = jnp.dtype(cfg.compute_dtype)
    B = tokens.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    x = params["embed"].astype(cdt)[tokens]
    positions = jnp.full((1,), pos)

    def block(x, scanned):
        p, ck, cv, cxk, cxv = scanned
        h = ops.rmsnorm(x, p["attn_norm"], eps=cfg.norm_eps)
        q = linear(h, p["wq"]).reshape(B, 1, H, hd)
        k = linear(h, p["wk"]).reshape(B, 1, KV, hd)
        v = linear(h, p["wv"]).reshape(B, 1, KV, hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, pos, 0, 0))
        o = ops.decode_attention(q, ck, cv, pos)
        x = x + linear(o.reshape(B, 1, H * hd), p["wo"])
        # cross attention against precomputed encoder K/V
        hc = ops.rmsnorm(x, p["cross_norm"], eps=cfg.norm_eps)
        qc = linear(hc, p["c_wq"]).reshape(B, 1, H, hd)
        oc = ops.decode_attention(qc, cxk, cxv, cxk.shape[1] - 1)
        x = x + linear(oc.reshape(B, 1, H * hd), p["c_wo"])
        delta, _ = transformer.mlp_block(cfg, p, x, rules)
        x = x + delta
        return x, (ck, cv)

    x, (new_k, new_v) = jax.lax.scan(
        block, x, (params["blocks"], cache["k"], cache["v"],
                   cache["cross_k"], cache["cross_v"]))
    x = ops.rmsnorm(x, params["final_norm"], eps=cfg.norm_eps)
    logits = transformer.unembed(cfg, params, x, rules)
    new_cache = dict(cache)
    new_cache.update({"k": new_k, "v": new_v})
    return logits, new_cache
