"""Per-kernel sweeps: Pallas (interpret=True) vs pure-jnp oracle.

Every kernel is swept over shapes and dtypes and asserted allclose against
ref.py, plus hypothesis property tests on the numerically risky pieces
(chunked decay algebra).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.grouped_matmul import grouped_matmul
from repro.kernels.matmul import matmul
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels.schedule import KernelSchedule
from repro.kernels.ssm_scan import ssm_scan
from repro.models import layers

KEY = jax.random.PRNGKey(42)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


def assert_close(a, b, dtype=jnp.float32):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), **tol(dtype))


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 512),
                                   (128, 512, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("epilogue", ["none", "bias", "bias_gelu",
                                      "relu", "row_max"])
def test_matmul_sweep(m, k, n, dtype, epilogue):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (m, k), dtype)
    w = jax.random.normal(k2, (k, n), dtype)
    b = jnp.linspace(-1, 1, n, dtype=dtype)
    y = matmul(x, w, epilogue=epilogue, bias=b, interpret=True)
    yr = ref.matmul(x, w, epilogue=epilogue, bias=b)
    assert_close(y, yr, dtype)


@pytest.mark.parametrize("order", [("m", "n", "k"), ("n", "m", "k"),
                                   ("k", "m", "n"), ("m", "k", "n")])
def test_matmul_loop_orders(order):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (256, 384))
    w = jax.random.normal(k2, (384, 256))
    s = KernelSchedule(blocks={"bm": 64, "bn": 128, "bk": 192},
                       loop_order=order)
    assert_close(matmul(x, w, schedule=s, interpret=True),
                 ref.matmul(x, w))


@pytest.mark.parametrize("bm,bn,bk", [(32, 32, 32), (64, 128, 64),
                                      (128, 64, 128)])
def test_matmul_tilings(bm, bn, bk):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (256, 256))
    w = jax.random.normal(k2, (256, 256))
    s = KernelSchedule(blocks={"bm": bm, "bn": bn, "bk": bk})
    assert_close(matmul(x, w, schedule=s, interpret=True),
                 ref.matmul(x, w))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sq,sk,h,kv,hd", [
    (256, 256, 4, 2, 64),     # GQA
    (128, 128, 4, 4, 32),     # MHA
    (256, 256, 8, 1, 64),     # MQA
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(sq, sk, h, kv, hd, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, sq, h, hd), dtype)
    k = jax.random.normal(ks[1], (2, sk, kv, hd), dtype)
    v = jax.random.normal(ks[2], (2, sk, kv, hd), dtype)
    o = flash_attention(q, k, v, causal=causal, interpret=True)
    orf = layers.attention(q, k, v, causal=causal)
    assert_close(o, orf, dtype)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_window(window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 32))
    k = jax.random.normal(ks[1], (1, 256, 2, 32))
    v = jax.random.normal(ks[2], (1, 256, 2, 32))
    o = flash_attention(q, k, v, causal=True, window=window,
                        interpret=True)
    orf = layers.attention(q, k, v, causal=True, window=window)
    assert_close(o, orf)


@pytest.mark.parametrize("bq,bk", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_tilings(bq, bk):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    s = KernelSchedule(blocks={"bq": bq, "bk": bk})
    o = flash_attention(q, k, v, schedule=s, interpret=True)
    assert_close(o, layers.attention(q, k, v))


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 37, 256), (128, 512), (2, 8, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, shape, dtype)
    s = jax.random.normal(k2, shape[-1:], dtype)
    assert_close(rmsnorm(x, s, interpret=True),
                 layers.rms_norm(x, s), dtype)


# ---------------------------------------------------------------------------
# rwkv6 scan
# ---------------------------------------------------------------------------

def _rwkv_inputs(B=2, T=128, H=3, dk=16, dv=16, dtype=jnp.float32,
                 decay_scale=1.0):
    ks = jax.random.split(KEY, 6)
    r = jax.random.normal(ks[0], (B, T, H, dk), dtype)
    k = jax.random.normal(ks[1], (B, T, H, dk), dtype)
    v = jax.random.normal(ks[2], (B, T, H, dv), dtype)
    w = jnp.exp(-jnp.exp(
        decay_scale * jax.random.normal(ks[3], (B, T, H, dk)))).astype(dtype)
    u = (0.5 * jax.random.normal(ks[4], (H, dk))).astype(dtype)
    s0 = jax.random.normal(ks[5], (B, H, dk, dv), jnp.float32)
    return r, k, v, w, u, s0


@pytest.mark.parametrize("chunk", [16, 32, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_kernel_sweep(chunk, dtype):
    r, k, v, w, u, s0 = _rwkv_inputs(dtype=dtype)
    o1, st1 = ref.rwkv6_scan(r, k, v, w, u, s0)
    o2, st2 = rwkv6_scan(r, k, v, w, u, s0,
                         schedule=KernelSchedule(blocks={"chunk": chunk}),
                         interpret=True)
    assert_close(o1, o2, dtype)
    assert_close(st1, st2, dtype)


@settings(max_examples=10, deadline=None)
@given(decay=st.floats(0.1, 4.0), chunk=st.sampled_from([16, 32]))
def test_rwkv6_chunked_extreme_decay_property(decay, chunk):
    """Chunked algebra must hold at any decay magnitude (exponents <= 0)."""
    r, k, v, w, u, s0 = _rwkv_inputs(T=64, decay_scale=decay)
    o1, st1 = ref.rwkv6_scan(r, k, v, w, u, s0)
    o2, st2 = ref.rwkv6_chunked(r, k, v, w, u, s0, chunk=chunk)
    # looser tolerance: exp(cumsum) vs sequential products differ in the
    # last bits at extreme decay magnitudes
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=5e-3, atol=5e-3)
    assert bool(jnp.all(jnp.isfinite(o2)))


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------

def _ssm_inputs(B=2, T=128, H=2, P=48, N=8, dtype=jnp.float32):
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (B, T, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B_ = jax.random.normal(ks[3], (B, T, N), dtype)
    C = jax.random.normal(ks[4], (B, T, N), dtype)
    h0 = jax.random.normal(ks[5], (B, H, P, N), jnp.float32)
    return x, dt, A, B_, C, h0


@pytest.mark.parametrize("chunk", [16, 32, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_kernel_sweep(chunk, dtype):
    x, dt, A, B_, C, h0 = _ssm_inputs(dtype=dtype)
    y1, h1 = ref.ssm_scan_step(x, dt, A, B_, C, h0)
    y2, h2 = ssm_scan(x, dt, A, B_, C, h0,
                      schedule=KernelSchedule(blocks={"chunk": chunk}),
                      interpret=True)
    assert_close(y1, y2, dtype)
    assert_close(h1, h2, dtype)


@settings(max_examples=10, deadline=None)
@given(chunk=st.sampled_from([8, 16, 32]),
       t_mult=st.integers(2, 4))
def test_ssm_chunked_matches_scan_property(chunk, t_mult):
    x, dt, A, B_, C, h0 = _ssm_inputs(T=chunk * t_mult)
    y1, h1 = ref.ssm_scan_step(x, dt, A, B_, C, h0)
    y2, h2 = ref.ssm_chunked(x, dt, A, B_, C, h0, chunk=chunk)
    assert_close(y1, y2)
    assert_close(h1, h2)


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("e,c,d,f", [(4, 128, 256, 384), (2, 256, 128, 128),
                                     (8, 128, 128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_sweep(e, c, d, f, dtype):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (e, c, d), dtype)
    w = jax.random.normal(k2, (e, d, f), dtype)
    y = grouped_matmul(x, w, interpret=True)
    yr = jnp.einsum("ecd,edf->ecf", x, w)
    assert_close(y, yr, dtype)


# ---------------------------------------------------------------------------
# state chaining property: splitting T in half and carrying state is exact
# ---------------------------------------------------------------------------

def test_rwkv6_state_chaining():
    r, k, v, w, u, s0 = _rwkv_inputs(T=64)
    o_full, st_full = ref.rwkv6_scan(r, k, v, w, u, s0)
    o1, st1 = ref.rwkv6_chunked(*(a[:, :32] for a in (r, k, v, w)), u, s0,
                                chunk=16)
    o2, st2 = ref.rwkv6_chunked(*(a[:, 32:] for a in (r, k, v, w)), u, st1,
                                chunk=16)
    assert_close(jnp.concatenate([o1, o2], 1), o_full)
    assert_close(st2, st_full)


def test_ssm_state_chaining():
    x, dt, A, B_, C, h0 = _ssm_inputs(T=64)
    y_full, h_full = ref.ssm_scan_step(x, dt, A, B_, C, h0)
    y1, h1 = ref.ssm_chunked(x[:, :32], dt[:, :32], A, B_[:, :32],
                             C[:, :32], h0, chunk=16)
    y2, h2 = ref.ssm_chunked(x[:, 32:], dt[:, 32:], A, B_[:, 32:],
                             C[:, 32:], h1, chunk=16)
    assert_close(jnp.concatenate([y1, y2], 1), y_full)
    assert_close(h2, h_full)
