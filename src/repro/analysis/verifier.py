"""Well-formedness verifier over ``KernelProgram`` IR (pass 1).

Proves — statically, with no oracle evaluation and no lowering — that a
program is structurally sound: every referenced tensor is defined
before use (the node tuple is the execution order, so a forward or self
reference IS a cycle), every op has the operand count and operand
shapes/dtypes its evaluator semantics require, outputs exist, the
fusion groups partition the node set into dataflow-connected kernels
whose multi-node patterns the kernel library can actually emit, and
schedules key on real group roots.

Shape/dtype inference mirrors ``kernel_ir.infer_shape`` and the
``_eval_op`` reference semantics EXACTLY — a diagnostic here means the
evaluator would either crash or silently disagree with the IR's own
``shapes()`` (the cost model and the lowerers trust those specs).

Dead nodes and unused inputs are WARNINGS, not errors: in this IR an
unconsumed node is still executed and priced (several committed
network-block tasks model layout breaks by splitting dataflow through
fresh inputs on purpose), so the verifier flags them for the linter
without failing the gate.
"""
from __future__ import annotations

import numpy as np

from repro.analysis.diagnostics import Diagnostic, error, warning
from repro.core.kernel_ir import (ELEMENTWISE, KernelProgram, OpNode,
                                  TensorSpec)

# op -> operand count (the evaluator indexes exactly these)
ARITY: dict[str, int] = {
    "matmul": 2, "grouped_matmul": 2,
    "bias": 2, "add": 2, "mul": 2,
    "relu": 1, "gelu": 1, "silu": 1, "square": 1,
    "softmax": 1, "row_max": 1, "row_sum": 1,
    "rmsnorm": 2,
    "attention": 3, "qk_scores": 2, "av": 2,
    "rwkv_chunk": 5, "ssm_chunk": 5,
}

# dtypes the oracle / input generators / hardware tables understand
KNOWN_DTYPES = ("float32", "bfloat16", "float16")


def _broadcastable(a: tuple[int, ...], b: tuple[int, ...]) -> bool:
    try:
        np.broadcast_shapes(a, b)
        return True
    except ValueError:
        return False


def _check_shapes(n: OpNode, specs: list[TensorSpec],
                  out: list[Diagnostic]) -> None:
    """Operand-shape validity per op, mirroring ``_eval_op``."""
    sh = [s.shape for s in specs]
    bad = None
    hint = ""
    if n.op == "matmul":
        if len(sh[0]) < 2 or len(sh[1]) < 2:
            bad = "matmul operands must be at least rank-2"
        elif sh[0][-1] != sh[1][-2]:
            bad = (f"matmul contraction mismatch: "
                   f"{sh[0]} @ {sh[1]} (K {sh[0][-1]} != {sh[1][-2]})")
            hint = "the lhs last dim must equal the rhs second-to-last"
    elif n.op == "grouped_matmul":
        if len(sh[0]) != 3 or len(sh[1]) != 3:
            bad = "grouped_matmul operands must be (E,C,D) and (E,D,F)"
        elif sh[0][0] != sh[1][0] or sh[0][2] != sh[1][1]:
            bad = (f"grouped_matmul mismatch: {sh[0]} x {sh[1]} "
                   "(expert or contraction dims differ)")
    elif n.op in ("bias", "add", "mul"):
        if not _broadcastable(sh[0], sh[1]):
            bad = f"operands {sh[0]} and {sh[1]} do not broadcast"
        elif np.broadcast_shapes(sh[0], sh[1]) != sh[0]:
            bad = (f"broadcast of {sh[0]} and {sh[1]} widens the first "
                   "operand (shape inference keeps the first operand's "
                   "shape)")
            hint = "put the full-shape operand first"
    elif n.op == "rmsnorm":
        if len(sh[1]) != 1 or not sh[0] or sh[1][0] != sh[0][-1]:
            bad = (f"rmsnorm scale must be ({sh[0][-1] if sh[0] else '?'},)"
                   f", got {sh[1]}")
    elif n.op in ("softmax", "row_max", "row_sum"):
        if len(sh[0]) < 1:
            bad = f"{n.op} needs at least rank-1 input"
    elif n.op == "attention":
        q, k, v = sh
        if len(q) != 4 or len(k) != 4 or len(v) != 4:
            bad = "attention operands must be rank-4 (B,S,H,hd)"
        elif k != v:
            bad = f"attention K {k} and V {v} shapes differ"
        elif q[0] != k[0] or q[3] != k[3]:
            bad = f"attention Q {q} incompatible with K {k}"
        elif k[2] == 0 or q[2] % k[2] != 0:
            bad = (f"attention Q heads {q[2]} not a multiple of KV "
                   f"heads {k[2]}")
            hint = "GQA needs H % KV == 0"
    elif n.op == "qk_scores":
        q, k = sh
        if len(q) != 4 or len(k) != 4:
            bad = "qk_scores operands must be rank-4 (B,S,H,hd)"
        elif q[0] != k[0] or q[2] != k[2] or q[3] != k[3]:
            bad = f"qk_scores Q {q} incompatible with K {k}"
    elif n.op == "av":
        p, v = sh
        if len(p) != 4 or len(v) != 4:
            bad = "av operands must be rank-4"
        elif p[0] != v[0] or p[1] != v[2] or p[3] != v[1]:
            bad = (f"av probs {p} incompatible with V {v} "
                   "(expect (B,H,Sq,Sk) x (B,Sk,H,hd))")
    elif n.op == "rwkv_chunk":
        r = sh[0]
        if len(r) != 4:
            bad = "rwkv_chunk r must be rank-4 (B,T,H,dk)"
        elif any(s != r for s in sh[1:4]):
            bad = f"rwkv_chunk r/k/v/w shapes differ: {sh[:4]}"
        elif tuple(sh[4]) != (r[2], r[3]):
            bad = f"rwkv_chunk u must be (H,dk)={r[2:]}; got {sh[4]}"
    elif n.op == "ssm_chunk":
        x, dt, a, b, c = sh
        if len(x) != 4:
            bad = "ssm_chunk x must be rank-4 (B,T,H,P)"
        elif tuple(dt) != tuple(x[:3]):
            bad = f"ssm_chunk dt must be (B,T,H)={x[:3]}; got {dt}"
        elif tuple(a) != (x[2],):
            bad = f"ssm_chunk A must be (H,)=({x[2]},); got {a}"
        elif len(b) != 3 or b != c or tuple(b[:2]) != tuple(x[:2]):
            bad = f"ssm_chunk B/C must be (B,T,N) matching x; got {b}/{c}"
    if bad:
        out.append(error("MT005", bad, span=(n.name,)))


def _check_dtypes(n: OpNode, specs: list[TensorSpec],
                  out: list[Diagnostic]) -> None:
    """Dtype consistency where the evaluator and ``infer_shape`` could
    diverge.  Elementwise mixes are fine (the evaluator casts to the
    first operand's dtype, which is what inference records); a mixed
    matmul WITHOUT the dtype rule's attrs is not — jnp would promote
    while inference keeps the lhs dtype, so pricing and lowering would
    disagree with execution."""
    if n.op in ("matmul", "grouped_matmul") \
            and specs[0].dtype != specs[1].dtype \
            and not n.attr("compute_dtype"):
        # the evaluator promotes; inference keeps the lhs dtype — a
        # real divergence, but one the dtype rule's downstream
        # consumers carry legitimately (the oracle's marker-tainted
        # tolerances absorb it), so this is lint signal, not a gate
        out.append(warning(
            "MT006",
            f"{n.op} operand dtypes differ ({specs[0].dtype} vs "
            f"{specs[1].dtype}) without a compute_dtype attr",
            span=(n.name,),
            hint="apply the dtype rule (compute_dtype/out_dtype attrs) "
                 "or cast the operands to one dtype"))
    for key in ("compute_dtype", "out_dtype"):
        v = n.attr(key)
        if v is not None and v not in KNOWN_DTYPES:
            out.append(error(
                "MT015", f"{key}={v!r} on {n.name} is not a known "
                f"dtype {KNOWN_DTYPES}", span=(n.name,)))


def _infer(n: OpNode, env: dict[str, TensorSpec]) -> TensorSpec:
    """``kernel_ir.infer_shape`` on pre-validated operands."""
    from repro.core.kernel_ir import infer_shape
    return infer_shape(n, env)


def _group_connected(group: tuple[str, ...],
                     nodes: dict[str, OpNode]) -> bool:
    """Weak dataflow connectivity over the group's internal edges."""
    members = [m for m in group if m in nodes]
    if len(members) <= 1:
        return True
    adj: dict[str, set[str]] = {m: set() for m in members}
    mset = set(members)
    for m in members:
        for i in nodes[m].inputs:
            if i in mset:
                adj[m].add(i)
                adj[i].add(m)
    seen = {members[0]}
    stack = [members[0]]
    while stack:
        for nb in adj[stack.pop()]:
            if nb not in seen:
                seen.add(nb)
                stack.append(nb)
    return len(seen) == len(members)


def verify_program(prog: KernelProgram) -> list[Diagnostic]:
    """Run the well-formedness pass; returns diagnostics (worst first
    is NOT guaranteed — callers sort or filter by severity)."""
    out: list[Diagnostic] = []
    env: dict[str, TensorSpec] = {}
    broken: set[str] = set()       # names whose spec is unknown

    # inputs: unique names, known dtypes, positive shapes
    for name, spec in prog.inputs:
        if name in env:
            out.append(error("MT001", f"duplicate input {name!r}",
                             span=(name,)))
        try:
            _ = np.dtype(spec.dtype) if spec.dtype != "bfloat16" else None
            known = spec.dtype in KNOWN_DTYPES
        except TypeError:
            known = False
        if not known:
            out.append(error(
                "MT015", f"input {name!r} has unsupported dtype "
                f"{spec.dtype!r}", span=(name,),
                hint=f"use one of {KNOWN_DTYPES}"))
        env[name] = spec

    # nodes in execution order: def-before-use IS acyclicity here
    node_names = set()
    for n in prog.nodes:
        if n.name in env:
            out.append(error(
                "MT001", f"node {n.name!r} redefines an existing tensor",
                span=(n.name,)))
        node_names.add(n.name)
        ok = True
        if n.op not in ARITY:
            out.append(error(
                "MT003", f"unknown op {n.op!r} on node {n.name!r}",
                span=(n.name,),
                hint="the op vocabulary is listed in core/kernel_ir.py"))
            ok = False
        elif len(n.inputs) != ARITY[n.op]:
            out.append(error(
                "MT004", f"{n.op} takes {ARITY[n.op]} operand(s); node "
                f"{n.name!r} has {len(n.inputs)}", span=(n.name,)))
            ok = False
        for i in n.inputs:
            if i not in env:
                later = i == n.name or any(m.name == i
                                           for m in prog.nodes)
                code = "MT013" if later else "MT002"
                what = ("itself" if i == n.name else
                        f"{i!r} before its definition" if later
                        else f"undefined tensor {i!r}")
                out.append(error(
                    code, f"node {n.name!r} reads {what}",
                    span=(n.name, i),
                    hint=("nodes execute in tuple order; a backward "
                          "edge is a cycle" if code == "MT013" else "")))
                ok = False
        if ok and not any(i in broken for i in n.inputs):
            specs = [env[i] for i in n.inputs]
            before = len(out)
            _check_shapes(n, specs, out)
            _check_dtypes(n, specs, out)
            if any(d.is_error for d in out[before:]):
                broken.add(n.name)
            try:
                env[n.name] = _infer(n, env)
            except Exception:
                broken.add(n.name)
        else:
            broken.add(n.name)
        env.setdefault(n.name, TensorSpec(()))

    # outputs
    for o in prog.outputs:
        if o not in env:
            out.append(error(
                "MT007", f"program output {o!r} is not produced by any "
                "node or input", span=(o,)))

    # liveness: a node no node reads and no output names is dead code
    used: set[str] = set(prog.outputs)
    for n in prog.nodes:
        used.update(n.inputs)
    for n in prog.nodes:
        if n.name not in used:
            out.append(warning(
                "MT008", f"node {n.name!r} feeds no node and no output",
                span=(n.name,),
                hint="drop it or add it to outputs if intended"))
    for name, _ in prog.inputs:
        if name not in used:
            out.append(warning(
                "MT009", f"input {name!r} is never read", span=(name,)))

    # fusion groups: exact partition, connected, templates exist
    seen: set[str] = set()
    for g in prog.fusion_groups:
        for m in g:
            if m not in node_names:
                out.append(error(
                    "MT010", f"fusion group member {m!r} is not a node",
                    span=g))
            elif m in seen:
                out.append(error(
                    "MT010", f"node {m!r} appears in more than one "
                    "fusion group", span=g))
            seen.add(m)
        if not _group_connected(g, prog.node_map):
            out.append(error(
                "MT014", f"fusion group {g} is not dataflow-connected",
                span=g,
                hint="fusion may only merge dataflow-adjacent kernels"))
        if len(g) > 1 and all(m in node_names for m in g):
            from repro.core import rules
            try:
                rules.check_fusion_pattern(prog, g)
            except rules.CompileError as e:
                d = getattr(e, "diagnostic", None)
                out.append(d if d is not None else error(
                    "MT011", str(e), span=g))
    missing = node_names - seen
    if missing:
        out.append(error(
            "MT010", f"nodes {sorted(missing)} belong to no fusion "
            "group", span=tuple(sorted(missing))))

    # schedules key on group roots
    roots = {g[0] for g in prog.fusion_groups}
    for root, _sched in prog.schedules:
        if root not in roots:
            out.append(error(
                "MT012", f"schedule keyed on {root!r}, which is not a "
                "fusion-group root", span=(root,),
                hint="schedules attach to the first node of a group"))
    return out
