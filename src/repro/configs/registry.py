"""Architecture registry: --arch <id> -> ModelConfig.

Exact assigned configs (see DESIGN.md §4).  Reduced configs of the same
family for CPU smoke tests are produced by ``reduced()``.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ModelConfig

ARCH_IDS = [
    "qwen2_5_3b",
    "yi_34b",
    "qwen3_14b",
    "qwen2_5_32b",
    "rwkv6_3b",
    "paligemma_3b",
    "phi3_5_moe_42b",
    "dbrx_132b",
    "hymba_1_5b",
    "seamless_m4t_medium",
]

# accept the hyphenated spec spelling too
ALIASES = {
    "qwen2.5-3b": "qwen2_5_3b",
    "yi-34b": "yi_34b",
    "qwen3-14b": "qwen3_14b",
    "qwen2.5-32b": "qwen2_5_32b",
    "rwkv6-3b": "rwkv6_3b",
    "paligemma-3b": "paligemma_3b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "dbrx-132b": "dbrx_132b",
    "hymba-1.5b": "hymba_1_5b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}


def get_config(arch: str) -> ModelConfig:
    arch = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    return dataclasses.replace(
        cfg,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        swa_window=min(cfg.swa_window, 16) if cfg.swa_window else 0,
        global_layers=(0,) if cfg.global_layers else (),
        prefix_len=8 if cfg.prefix_len else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        enc_len=16,
        true_n_heads=4,
        true_vocab_size=256,
    )
