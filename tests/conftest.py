"""Shared fixtures.  NOTE: no XLA device-count forcing here — smoke tests
and benches must see the real (single-CPU) device; only launch/dryrun.py
sets --xla_force_host_platform_device_count (per spec)."""
import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")
