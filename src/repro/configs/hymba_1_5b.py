"""hymba-1.5b [hybrid] — parallel attention + mamba heads in every layer,
sliding-window attention except 3 global layers, ssm_state=16.
[arXiv:2411.13676]  SSM path carries O(1) state => long-context OK.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    swa_window=1024,
    global_layers=(0, 16, 31),
    supports_long_context=True,
)
