"""Serve a small LM with token-level continuous batching.

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys

sys.path.insert(0, "src")

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.registry import get_config, reduced  # noqa: E402
from repro.models import api  # noqa: E402
from repro.serve.engine import Engine  # noqa: E402

cfg = reduced(get_config("qwen2_5_3b"))
cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, vocab_size=512,
                          true_vocab_size=512)
params = api.init_params(cfg, jax.random.PRNGKey(0))
engine = Engine(cfg, params, max_len=64, batch_slots=4)

prompts = [jnp.array(p, jnp.int32) for p in
           [[1, 5, 3], [2, 2], [9, 8, 7, 6], [4], [10, 11, 12],
            [3, 1, 4, 1, 5]]]
print(f"serving {len(prompts)} requests through 4 slots ...")
outs = engine.generate(prompts, max_new_tokens=8)
for p, o in zip(prompts, outs):
    print(f"  prompt {list(map(int, p))} -> {o}")
occ = engine.stats["occupancy_sum"] / max(engine.stats["decode_steps"], 1)
print(f"done (continuous batching: freed slots refill every step; "
      f"mean occupancy {occ:.2f})")
