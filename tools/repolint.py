#!/usr/bin/env python3
"""repolint — the repo's own source-level lint gates.

One reusable home for the project-specific invariants that used to
live as ad-hoc assertions inside two test files:

  kind-literal   no layer outside ``core/rules.py`` dispatches on
                 ``act.kind`` string literals (the PR-5 registry
                 contract: behavior differences live in RewriteRule
                 methods, not caller switches)
  config-kwargs  no in-repo call site constructs ``MTMCPipeline`` /
                 ``EvalEngine`` / ``KernelService`` / ``Fleet`` /
                 ``tune_model_kernels`` through the deprecated flat
                 optimizer kwargs — everything passes
                 ``config=OptimizeConfig(...)`` (the PR-7 contract;
                 only tests exercise the shims)
  coder-backend  no module outside ``src/repro/llmcoder/`` imports or
                 references a concrete ``CoderBackend`` class
                 (``TemplateBackend``/``ReplayBackend``/
                 ``RecordingBackend``) — the rest of the repo selects
                 coders by ``OptimizeConfig.coder`` spec string or the
                 ``make_coder`` factory (the PR-9 protocol-only seam,
                 mirroring the kind-literal gate)

Walks ``src/``, ``benchmarks/`` and ``examples/``.  Both CI and
``tests/test_repolint.py`` call ``run_lints``; the CLI prints one
``path:line: message`` per finding and exits 1 when any exist.

  python tools/repolint.py [--repo DIR]

No third-party dependencies — stdlib ``ast`` + ``re`` only, so it runs
in any CI job before the package environment is even installed.
"""
from __future__ import annotations

import argparse
import ast
import os
import re
import sys

ROOTS = ("src", "benchmarks", "examples")

# -- kind-literal gate -------------------------------------------------------

# action-ish receivers whose ``.kind`` must not be compared to literals
KIND_LITERAL = re.compile(
    r"\b(?:act|action|a|c|cand)\.kind\s*(?:==|!=)\s*['\"]"
    r"|\b(?:act|action|a|c|cand)\.kind\s+in\s*[(\[]")

# the registry itself is the one legitimate home of kind dispatch
KIND_EXEMPT_FILES = ("rules.py",)

# -- config-kwargs gate ------------------------------------------------------

DEPRECATED_KWARGS: dict[str, set[str]] = {
    "MTMCPipeline": {"mode", "curated", "extended_rules", "max_steps",
                     "seed", "validate", "target", "strategy",
                     "cost_model_override", "measurer", "rerank_top_k"},
    "EvalEngine": {"mode", "curated", "extended", "max_steps", "seed",
                   "validate", "target", "strategy", "rerank_top_k",
                   "measurer", "cost_model"},
    "KernelService": {"mode", "max_steps", "target", "strategy",
                      "rerank_top_k"},
    "Fleet": {"mode", "max_steps", "target", "strategy",
              "rerank_top_k"},
    "tune_model_kernels": {"target", "strategy", "measurer",
                           "rerank_top_k"},
}


def _py_files(repo: str):
    for root in ROOTS:
        top = os.path.join(repo, root)
        for dirpath, _, files in os.walk(top):
            for fn in sorted(files):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def lint_kind_literals(repo: str) -> list[str]:
    """Registered-rule dispatch must go through the registry."""
    offenders = []
    for path in _py_files(repo):
        if os.path.basename(path) in KIND_EXEMPT_FILES:
            continue
        with open(path) as f:
            for i, line in enumerate(f, 1):
                if KIND_LITERAL.search(line):
                    offenders.append(
                        f"{os.path.relpath(path, repo)}:{i}: "
                        f"action-kind literal dispatch: {line.strip()}")
    return offenders


def _call_name(node: ast.Call):
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def lint_config_kwargs(repo: str) -> list[str]:
    """In-repo construction goes through config=OptimizeConfig(...)."""
    offenders = []
    for path in _py_files(repo):
        with open(path) as f:
            tree = ast.parse(f.read(), filename=path)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            bad = DEPRECATED_KWARGS.get(_call_name(node))
            if not bad:
                continue
            used = {k.arg for k in node.keywords} & bad
            if used:
                offenders.append(
                    f"{os.path.relpath(path, repo)}:{node.lineno}: "
                    f"deprecated optimizer kwargs "
                    f"{_call_name(node)}({sorted(used)}) — pass "
                    "config=OptimizeConfig(...)")
    return offenders


# -- coder-backend gate ------------------------------------------------------

BACKEND_CLASSES = {"TemplateBackend", "ReplayBackend",
                   "RecordingBackend"}
BACKEND_EXEMPT_DIR = os.path.join("src", "repro", "llmcoder")


def lint_backend_imports(repo: str) -> list[str]:
    """Concrete coder backends stay behind the ``MicroCoder`` seam."""
    offenders = []
    for path in _py_files(repo):
        rel = os.path.relpath(path, repo)
        if rel.startswith(BACKEND_EXEMPT_DIR + os.sep):
            continue
        with open(path) as f:
            tree = ast.parse(f.read(), filename=path)
        for node in ast.walk(tree):
            used: set[str] = set()
            if isinstance(node, ast.ImportFrom):
                used = {a.name for a in node.names} & BACKEND_CLASSES
            elif isinstance(node, ast.Attribute):
                if node.attr in BACKEND_CLASSES:
                    used = {node.attr}
            elif isinstance(node, ast.Name):
                if node.id in BACKEND_CLASSES:
                    used = {node.id}
            if used:
                offenders.append(
                    f"{rel}:{node.lineno}: concrete coder backend "
                    f"{sorted(used)} outside llmcoder/ — select via "
                    "OptimizeConfig.coder or llmcoder.make_coder")
    return offenders


LINTS = (lint_kind_literals, lint_config_kwargs, lint_backend_imports)


def run_lints(repo: str) -> list[str]:
    """All findings across every gate, ``path:line: message`` form."""
    out: list[str] = []
    for lint in LINTS:
        out.extend(lint(repo))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repolint")
    ap.add_argument("--repo", default=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))),
        help="repository root (default: this script's parent)")
    args = ap.parse_args(argv)
    findings = run_lints(args.repo)
    for f in findings:
        print(f)
    print(f"repolint: {len(findings)} finding(s) over "
          f"{'/'.join(ROOTS)}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
