"""Search strategies over the macro action space.

The paper's central claim is that Macro Thinking wins by *exploring* the
semantic optimization space; a single greedy descent (the seed's
``greedy_cost`` mode) commits to one rollout and stops at the first
local minimum where no single action improves the modeled cost.  This
module makes the search pluggable:

  greedy  — the baseline: best cost-model child each step, stop when no
            child improves by the relative tolerance (exactly the seed's
            ``greedy_cost`` descent, factored out).
  beam    — beam search over macro actions: a width-`w` frontier of
            distinct programs is expanded each depth and the global-best
            program is tracked.  The frontier keeps the best `w`
            children even when they are all worse than their parents, so
            beam traverses cost plateaus and sub-threshold improvements
            that stall greedy.  A greedy backbone run is folded in (the
            shared ``TranspositionStore`` makes it free — every edge the
            backbone walks is an edge the beam expands anyway), so beam
            can never return a worse program than greedy on the same
            store.
  anneal  — random-restart epsilon-greedy: restart 0 is exact greedy
            (same guarantee), later restarts follow the greedy child
            with probability 1-eps and a uniform valid child otherwise,
            with eps decaying per restart.
  policy  — the trained Macro policy PRUNES the frontier expansion:
            at each frontier node only the ``expand_k`` actions the LM
            ranks highest are materialized through the store, instead
            of beam's every-child sweep.  A greedy backbone keeps the
            never-worse-than-greedy guarantee; the point is the budget
            — the policy reaches beam-quality programs at a fraction
            of the node expansions (Table 7's budget-matched grid).

Strategies register themselves in a name -> factory registry
(``register_strategy``); ``get_strategy("beam")`` et al. consult it, so
out-of-tree strategies plug in without editing this module.

All strategies share transition/cost/oracle memos through the store, so
beam siblings and restarts never re-rewrite a visited (state, action)
edge and never re-price a visited (program, target) pair.  Strategies
only ever move along ``status == "ok"`` rewrites, so every returned
program is oracle-checkable against the task (property-tested in
``tests/test_search.py``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import actions as A
from repro.core import hardware, rules
from repro.core.kernel_ir import KernelProgram

# a child must beat the incumbent by this relative margin for greedy to
# descend (the seed's greedy_cost used the same 0.999); beam/anneal use
# it only for their embedded greedy backbone
GREEDY_REL_TOL = 0.999


# distinct candidate programs a strategy reports for measured reranking
# (the "top-K survivors" of the search); a small constant — candidates
# hold live program references, and measured rerankers only ever look
# at the cheapest handful
MAX_CANDIDATES = 32


@dataclasses.dataclass(frozen=True)
class SearchOutcome:
    program: KernelProgram
    cost_s: float                # modeled cost of ``program`` on target
    baseline_s: float            # modeled cost of the task itself
    steps: int                   # actions applied along the winning path
    n_expanded: int              # ok-children materialized
    n_failures: int              # compile/validation failures en route
    # cheapest-first distinct (modeled cost, program) survivors the
    # strategy visited — always includes ``program`` and the task
    # itself; the measured-execution reranking stage (DESIGN.md §11)
    # times the top K of these
    candidates: tuple = ()

    @property
    def speedup(self) -> float:
        return self.baseline_s / max(self.cost_s, 1e-12)


def top_candidates(entries, cap: int = MAX_CANDIDATES) -> tuple:
    """Cheapest-first distinct (cost, program), fingerprint-deduped."""
    best: dict[str, tuple[float, KernelProgram]] = {}
    for c, p in entries:
        fp = p.fingerprint()
        if fp not in best or c < best[fp][0]:
            best[fp] = (c, p)
    ranked = sorted(best.items(), key=lambda kv: (kv[1][0], kv[0]))
    return tuple(v for _, v in ranked[:cap])


class SearchStrategy:
    """Pluggable exploration over macro actions.

    ``search`` walks the (program, action) graph through a
    ``TranspositionStore`` (duck-typed: ``apply``/``cost``) so sibling
    states share rewrites and pricing, and returns the best-found
    program under ``target``'s cost model.
    """

    name = "base"

    def search(self, task: KernelProgram, *, coder, store,
               target=None, max_steps: int = 8, seed: int = 0,
               curated: bool = True, extended: bool = False,
               policy=None) -> SearchOutcome:
        """``policy`` (a ``MacroPolicy``) guides strategies that can use
        one (``PolicySearch``); the undirected strategies ignore it, so
        the pipeline can hand its policy to whatever strategy is
        configured."""
        raise NotImplementedError

    def _children(self, store, coder, prog: KernelProgram,
                  curated: bool, target=None,
                  extended: bool = False) -> tuple[list, int]:
        """All valid (action, child) successors of ``prog`` — candidate
        enumeration is target-aware (registry presets), legality and
        the store's transition memo are not (DESIGN.md §9)."""
        enum = (A.candidate_actions if curated
                else A.unrestricted_actions)
        ok, fails = [], 0
        for a in enum(prog, target=target, extended=extended):
            if rules.is_terminal(a):
                continue
            r = store.apply(coder, prog, a)
            if r.status == "ok":
                ok.append((a, r.program))
            else:
                fails += 1
        return ok, fails


class GreedySearch(SearchStrategy):
    """Best cost-model child each step; stop at the first local min."""

    name = "greedy"

    def search(self, task, *, coder, store, target=None, max_steps=8,
               seed=0, curated=True, extended=False,
               policy=None) -> SearchOutcome:
        tgt = hardware.resolve(target)
        cur, cur_c = task, store.cost(task, tgt)
        base = cur_c
        steps = n_exp = n_fail = 0
        visited = [(cur_c, cur)]
        for t in range(max_steps):
            children, fails = self._children(store, coder, cur, curated,
                                             tgt, extended)
            n_fail += fails
            n_exp += len(children)
            best, best_c = None, cur_c
            for _, ch in children:
                c = store.cost(ch, tgt)
                if c < best_c * GREEDY_REL_TOL:
                    best, best_c = ch, c
            if best is None:
                break
            cur, cur_c, steps = best, best_c, t + 1
            visited.append((cur_c, cur))
        return SearchOutcome(cur, cur_c, base, steps, n_exp, n_fail,
                             top_candidates(visited))


class BeamSearch(SearchStrategy):
    """Width-`w` frontier over macro actions with a greedy backbone.

    Each depth expands every frontier program and keeps the `width`
    cheapest *distinct* children (dedup by fingerprint within the depth
    — siblings frequently commute into the same program, and the
    store's transposition property makes the dedup exact).  Only
    programs the frontier actually admits (and therefore expands next
    depth) are marked consumed: a child dropped by the width or
    ``per_parent`` cap stays rediscoverable from a different parent at
    a later depth, where its subtree may hold the global best —
    marking every priced child used to foreclose those routes
    permanently (regression-tested cap-collision graph in
    ``tests/test_search.py``).  Children are
    kept even when no child beats its parent, so the beam walks through
    plateaus and sub-0.1% improvements where greedy stops.  At most
    ``per_parent`` children of the same frontier state survive a depth:
    without the cap the frontier collapses into `width` tile-variants of
    one leader and prunes exactly the branches beam exists for (the
    fusion-order traps — e.g. fusing a gelu upward into its producer
    matmul forecloses the globally-better downward fusion into its
    consumer matmul, which starts out looking worse).  The returned
    program is the best of {beam-best, greedy-backbone best}, making
    ``cost(beam) <= cost(greedy)`` an invariant rather than a hope.
    """

    name = "beam"

    def __init__(self, width: int = 4, per_parent: int = 2):
        self.width = width
        self.per_parent = per_parent

    def search(self, task, *, coder, store, target=None, max_steps=8,
               seed=0, curated=True, extended=False,
               policy=None) -> SearchOutcome:
        tgt = hardware.resolve(target)
        backbone = GreedySearch().search(
            task, coder=coder, store=store, target=tgt,
            max_steps=max_steps, seed=seed, curated=curated,
            extended=extended)
        base = backbone.baseline_s
        best, best_c = backbone.program, backbone.cost_s
        best_depth = backbone.steps
        n_exp, n_fail = backbone.n_expanded, backbone.n_failures
        frontier = [(base, task)]
        expanded = {task.fingerprint()}   # programs the beam has expanded
        visited = list(backbone.candidates) or [(base, task)]
        for depth in range(max_steps):
            pool, depth_fps = [], set()
            for pi, (_, prog) in enumerate(frontier):
                children, fails = self._children(store, coder, prog,
                                                 curated, tgt, extended)
                n_fail += fails
                for _, ch in children:
                    fp = ch.fingerprint()
                    if fp in expanded or fp in depth_fps:
                        continue
                    depth_fps.add(fp)
                    n_exp += 1
                    pool.append((store.cost(ch, tgt), fp, pi, ch))
            if not pool:
                break
            pool.sort(key=lambda e: (e[0], e[1]))   # cost, then fp tiebreak
            frontier, taken = [], {}
            for c, fp, pi, ch in pool:
                if taken.get(pi, 0) >= self.per_parent:
                    continue
                taken[pi] = taken.get(pi, 0) + 1
                frontier.append((c, ch))
                visited.append((c, ch))
                # only frontier-admitted programs are consumed; children
                # the caps dropped may re-enter later via another parent
                expanded.add(fp)
                if len(frontier) >= self.width:
                    break
            if frontier[0][0] < best_c:
                best_c, best = frontier[0]
                best_depth = depth + 1
        return SearchOutcome(best, best_c, base, best_depth, n_exp,
                             n_fail, top_candidates(visited))


class AnnealedSearch(SearchStrategy):
    """Random-restart epsilon-greedy descent with annealed epsilon.

    Restart 0 runs with eps=0 — an exact greedy replica, so the best
    across restarts can never be worse than greedy on the same store.
    Later restarts take a uniform valid child with probability eps
    (escaping greedy's local minima), eps decaying geometrically per
    restart; every visited state competes for the returned best.
    """

    name = "anneal"

    def __init__(self, restarts: int = 4, eps: float = 0.5,
                 decay: float = 0.6):
        self.restarts = restarts
        self.eps = eps
        self.decay = decay

    def search(self, task, *, coder, store, target=None, max_steps=8,
               seed=0, curated=True, extended=False,
               policy=None) -> SearchOutcome:
        tgt = hardware.resolve(target)
        rng = np.random.default_rng(seed)
        base = store.cost(task, tgt)
        best, best_c, best_steps = task, base, 0
        n_exp = n_fail = 0
        visited = [(base, task)]
        for r in range(self.restarts):
            eps = 0.0 if r == 0 else self.eps * self.decay ** (r - 1)
            cur, cur_c = task, base
            for t in range(max_steps):
                children, fails = self._children(store, coder, cur,
                                                 curated, tgt, extended)
                n_fail += fails
                n_exp += len(children)
                if not children:
                    break
                if eps > 0.0 and rng.random() < eps:
                    _, nxt = children[rng.integers(len(children))]
                    nxt_c = store.cost(nxt, tgt)
                else:
                    nxt, nxt_c = None, cur_c
                    for _, ch in children:
                        c = store.cost(ch, tgt)
                        if c < nxt_c * GREEDY_REL_TOL:
                            nxt, nxt_c = ch, c
                    if nxt is None:
                        break
                cur, cur_c = nxt, nxt_c
                visited.append((cur_c, cur))
                if cur_c < best_c:
                    best, best_c, best_steps = cur, cur_c, t + 1
        return SearchOutcome(best, best_c, base, best_steps, n_exp,
                             n_fail, top_candidates(visited))


# the default policy used when PolicySearch runs unbound (no trained
# policy handed in): an untrained MacroPolicy — deterministic (PRNGKey
# 0) and shared so its jitted scorer compiles once per process
_DEFAULT_POLICY = None


def _default_policy():
    global _DEFAULT_POLICY
    if _DEFAULT_POLICY is None:
        from repro.core.policy import MacroPolicy
        _DEFAULT_POLICY = MacroPolicy()
    return _DEFAULT_POLICY


class PolicySearch(SearchStrategy):
    """Policy-pruned beam: the Macro LM decides WHAT to expand.

    Beam's cost is its exhaustive frontier sweep — every candidate
    action of every frontier program is materialized through the store
    just to be priced.  Here the trained policy ranks each frontier
    node's candidate actions first (one batched LM forward, no rewrites)
    and only the top ``expand_k`` are materialized; admitted children
    then compete by modeled cost exactly like beam's (width cap,
    ``per_parent`` diversity cap, fingerprint dedup, dropped children
    stay rediscoverable).  A greedy backbone is folded in, so
    ``cost(policy) <= cost(greedy)`` is an invariant even under an
    UNTRAINED policy (property-tested), and only ``status == "ok"``
    edges are ever walked, so the returned program always passes the
    oracle.  The budget win is the point: Table 7's budget-matched grid
    gates that the trained policy reaches beam's solution quality at a
    fraction of beam's node expansions.
    """

    name = "policy"

    def __init__(self, policy=None, width: int = 3, expand_k: int = 6,
                 per_parent: int = 2):
        self.policy = policy
        self.width = width
        self.expand_k = expand_k
        self.per_parent = per_parent

    def _ranked_actions(self, pol, prog, target, curated, extended):
        """Candidate actions, LM-ranked best-first, terminals dropped."""
        enum = (A.candidate_actions if curated
                else A.unrestricted_actions)
        acts = [a for a in enum(prog, target=target, extended=extended)
                if not rules.is_terminal(a)]
        if len(acts) <= self.expand_k:
            return acts
        logp, _ = pol.action_dist(prog, acts)
        order = np.argsort(-np.asarray(logp), kind="stable")
        return [acts[i] for i in order[: self.expand_k]]

    def search(self, task, *, coder, store, target=None, max_steps=8,
               seed=0, curated=True, extended=False,
               policy=None) -> SearchOutcome:
        pol = policy if policy is not None else self.policy
        if pol is None:
            pol = _default_policy()
        tgt = hardware.resolve(target)
        backbone = GreedySearch().search(
            task, coder=coder, store=store, target=tgt,
            max_steps=max_steps, seed=seed, curated=curated,
            extended=extended)
        base = backbone.baseline_s
        best, best_c = backbone.program, backbone.cost_s
        best_depth = backbone.steps
        n_exp, n_fail = backbone.n_expanded, backbone.n_failures
        frontier = [(base, task)]
        expanded = {task.fingerprint()}
        visited = list(backbone.candidates) or [(base, task)]
        for depth in range(max_steps):
            pool, depth_fps = [], set()
            for pi, (_, prog) in enumerate(frontier):
                for a in self._ranked_actions(pol, prog, tgt, curated,
                                              extended):
                    r = store.apply(coder, prog, a)
                    if r.status != "ok":
                        n_fail += 1
                        continue
                    fp = r.program.fingerprint()
                    if fp in expanded or fp in depth_fps:
                        continue
                    depth_fps.add(fp)
                    n_exp += 1
                    pool.append((store.cost(r.program, tgt), fp, pi,
                                 r.program))
            if not pool:
                break
            pool.sort(key=lambda e: (e[0], e[1]))
            frontier, taken = [], {}
            for c, fp, pi, ch in pool:
                if taken.get(pi, 0) >= self.per_parent:
                    continue
                taken[pi] = taken.get(pi, 0) + 1
                frontier.append((c, ch))
                visited.append((c, ch))
                expanded.add(fp)
                if len(frontier) >= self.width:
                    break
            if frontier[0][0] < best_c:
                best_c, best = frontier[0]
                best_depth = depth + 1
        return SearchOutcome(best, best_c, base, best_depth, n_exp,
                             n_fail, top_candidates(visited))


# ---------------------------------------------------------------------------
# strategy registry
# ---------------------------------------------------------------------------

# name -> zero-arg factory (usually the class itself); strategies
# register themselves below and out-of-tree ones via register_strategy
STRATEGIES: dict[str, "type[SearchStrategy]"] = {}


def register_strategy(name: str, factory, *, replace: bool = False):
    """Register ``factory`` (class or zero-arg callable returning a
    ``SearchStrategy``) under ``name`` for ``get_strategy`` and every
    config surface that takes a strategy name (``OptimizeConfig``,
    serve/fleet).  Re-registering an existing name requires
    ``replace=True`` — a silent overwrite would re-route every config
    mentioning the name."""
    if not name or not isinstance(name, str):
        raise ValueError(f"strategy name must be a non-empty str, "
                         f"got {name!r}")
    if name in STRATEGIES and not replace:
        raise ValueError(f"strategy {name!r} already registered; pass "
                         f"replace=True to override")
    STRATEGIES[name] = factory
    return factory


def get_strategy(strategy: "SearchStrategy | str") -> SearchStrategy:
    """str -> default-configured instance; instances pass through."""
    if isinstance(strategy, SearchStrategy):
        return strategy
    try:
        factory = STRATEGIES[strategy]
    except KeyError:
        raise KeyError(f"unknown search strategy {strategy!r}; "
                       f"registered: {sorted(STRATEGIES)}") from None
    return factory()


register_strategy(GreedySearch.name, GreedySearch)
register_strategy(BeamSearch.name, BeamSearch)
register_strategy(AnnealedSearch.name, AnnealedSearch)
register_strategy(PolicySearch.name, PolicySearch)
