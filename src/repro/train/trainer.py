"""Training step assembly + Trainer loop.

``make_train_step`` builds the pjit-able (params, opt_state, batch) ->
(params, opt_state, metrics) function for any (arch x shape), with:
  * microbatch gradient accumulation (auto-sized from the per-device
    activation budget — see configs.base.auto_accum_steps),
  * remat (scan-over-layers block checkpointing) in the model forwards,
  * token 0 = padding (masked from the loss; VLM prefix positions),
  * MoE aux-loss folding.

The ``Trainer`` drives the loop on real devices (examples/tests); the
dry-run lowers the same train_step against abstract inputs.
"""
from __future__ import annotations

import dataclasses
import time
from collections.abc import Callable
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import (ModelConfig, RunConfig, ShapeConfig,
                                auto_accum_steps)
from repro.models import api
from repro.optim import adamw

AUX_WEIGHT = 0.01


def loss_fn(cfg: ModelConfig, params, batch, *, rules=None, remat=True):
    model = api.get_model(cfg)
    logits, aux = model.forward(cfg, params, batch, rules=rules,
                                remat=remat)
    targets = batch["targets"]
    mask = (targets > 0).astype(jnp.float32)
    vp = logits.shape[-1]
    lg = logits.astype(jnp.float32)
    if vp != cfg.true_vocab_size:
        col = jnp.arange(vp)
        lg = jnp.where(col < cfg.true_vocab_size, lg, -1e30)
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    xent = jnp.sum((lse - picked) * mask) / jnp.maximum(jnp.sum(mask), 1)
    loss = xent + AUX_WEIGHT * aux
    return loss, {"xent": xent, "aux": aux}


def _split_microbatches(batch: dict, accum: int) -> dict:
    return {k: v.reshape((accum, v.shape[0] // accum) + v.shape[1:])
            for k, v in batch.items()}


def make_prepare(cfg: ModelConfig, rules):
    """gather-once: cast params to the compute dtype under TP-only
    sharding (no data/FSDP axis) — one all-gather per step, hoisted out
    of the microbatch loop; its transpose is one reduce-scatter."""
    from repro.models import api
    rules_tp = rules.replace(embed=())
    shardings = api.param_shardings(cfg, rules_tp)
    cdt = jnp.dtype(cfg.compute_dtype)

    def prepare(params):
        def cast(p, sh):
            q = p.astype(cdt) if (p.dtype == jnp.float32 and
                                  p.ndim >= 2) else p
            return jax.lax.with_sharding_constraint(q, sh)
        return jax.tree.map(cast, params, shardings)
    return prepare


def make_train_step(cfg: ModelConfig, shape: ShapeConfig,
                    run: RunConfig | None = None, *, rules=None,
                    opt_cfg: adamw.AdamWConfig | None = None,
                    donate: bool = True) -> Callable:
    run = run if run is not None else RunConfig()
    opt_cfg = opt_cfg or adamw.AdamWConfig(
        lr=run.learning_rate, weight_decay=run.weight_decay,
        grad_clip=run.grad_clip, warmup_steps=run.warmup_steps)
    dp = rules.dp if rules is not None else 1
    accum = run.accum_steps or auto_accum_steps(
        cfg, shape, dp, run.microbatch_bytes_budget)
    gather_once = run.gather_once and rules is not None
    prepare = make_prepare(cfg, rules) if gather_once else (lambda p: p)

    def grads_of(params, mb):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, mb, rules=rules, remat=run.remat),
            has_aux=True)(params)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if gather_once:
            loss, metrics, grads = _gather_once_grads(params, batch)
        elif accum == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            mbs = _split_microbatches(batch, accum)

            def body(carry, mb):
                acc, = carry
                loss, metrics, grads = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc, grads)
                return (acc,), (loss, metrics)

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum,), (losses, metricss) = jax.lax.scan(
                body, (zero,), mbs)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metricss)
        new_params, new_opt, opt_metrics = adamw.update(
            opt_cfg, grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_params, new_opt, metrics

    def _gather_once_grads(params, batch):
        mb_loss = jax.checkpoint(
            lambda pc, mb: loss_fn(cfg, pc, mb, rules=rules,
                                   remat=run.remat))

        def total_loss(p):
            pc = prepare(p)          # gathered once, outside the loop
            if accum == 1:
                return mb_loss(pc, batch)
            mbs = _split_microbatches(batch, accum)

            def body(acc, mb):
                loss, metrics = mb_loss(pc, mb)
                return acc + loss, metrics
            total, ms = jax.lax.scan(body, jnp.float32(0), mbs)
            return total / accum, jax.tree.map(jnp.mean, ms)

        (loss, metrics), grads = jax.value_and_grad(
            total_loss, has_aux=True)(params)
        return loss, metrics, grads

    train_step.accum = accum      # introspection for dry-run reports
    train_step.opt_cfg = opt_cfg
    return train_step


# ---------------------------------------------------------------------------
# Trainer loop (real devices; fault-tolerance hooks from repro.ft)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrainerState:
    params: Any
    opt_state: Any
    step: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 run: RunConfig | None = None, *, rules=None,
                 ckpt_dir: str | None = None, ckpt_every: int = 50,
                 straggler_monitor=None):
        from repro import ckpt as ckpt_mod
        run = run if run is not None else RunConfig()
        self.cfg, self.shape, self.run, self.rules = cfg, shape, run, rules
        self.train_step = make_train_step(cfg, shape, run, rules=rules)
        self.jit_step = jax.jit(self.train_step, donate_argnums=(0, 1))
        self.ckpt_dir, self.ckpt_every = ckpt_dir, ckpt_every
        self.ckpt = ckpt_mod
        self.straggler_monitor = straggler_monitor
        self.metrics_log: list[dict] = []

    def init_state(self, seed: int = 0) -> TrainerState:
        params = api.init_params(self.cfg, jax.random.PRNGKey(seed))
        return TrainerState(params, adamw.init(params), 0)

    def restore_or_init(self, seed: int = 0) -> TrainerState:
        if self.ckpt_dir:
            latest = self.ckpt.latest_step(self.ckpt_dir)
            if latest is not None:
                params, opt_state, step = self.ckpt.restore(
                    self.ckpt_dir, latest)
                return TrainerState(params, opt_state, step)
        return self.init_state(seed)

    def run_steps(self, state: TrainerState, n_steps: int,
                  data=None) -> TrainerState:
        from repro.data.pipeline import Prefetcher
        own_data = data is None
        data = data or Prefetcher(self.cfg, self.shape,
                                  start_step=state.step)
        try:
            target = state.step + n_steps
            while state.step < target:
                step_id, hb = data.next()
                assert step_id == state.step, (step_id, state.step)
                t0 = time.monotonic()
                state.params, state.opt_state, metrics = self.jit_step(
                    state.params, state.opt_state, hb)
                jax.block_until_ready(metrics["loss"])
                dt = time.monotonic() - t0
                if self.straggler_monitor is not None:
                    self.straggler_monitor.record(state.step, dt)
                self.metrics_log.append(
                    {k: float(v) for k, v in metrics.items()}
                    | {"step": state.step, "sec": dt})
                state.step += 1
                if self.ckpt_dir and state.step % self.ckpt_every == 0:
                    self.ckpt.save(self.ckpt_dir, state.step,
                                   state.params, state.opt_state,
                                   async_=True)
        finally:
            if own_data:
                data.stop()
        if self.ckpt_dir:
            self.ckpt.wait_pending()
        return state
