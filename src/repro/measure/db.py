"""Persistent, content-addressed measurement database.

Every measured sample is keyed by the full provenance of the number:

    (task_fp, program_fp, target, env_fp)

``task_fp``/``program_fp`` are the kernel-IR fingerprints (what was
measured), ``target`` is the hardware target the *analytic* side was
priced against (which search produced the candidate and which
calibration bucket the sample feeds), and ``env_fp`` fingerprints the
execution environment the wall-clock number came from: jax backend +
version, measurement mode (compiled vs pallas-interpret), and the
target's frozen constants.  A sample is a pure function of its key —
the DB never invalidates entries; a changed environment simply hashes
to a different ``env_fp`` and misses (the same rule the
``TranspositionStore`` uses for cost-model changes, DESIGN.md §8/§11).

Layout on disk (JSON, one file per entry, atomic writes)::

    <root>/samples/<sha16>.json   — MeasureSample
    <root>/winners/<sha16>.json   — winning program per (task, target,
                                    env): the KernelService warm-start
                                    record (DESIGN.md §11)

The DB survives process restarts: a restarted ``KernelService`` pointed
at the same directory answers repeat requests from ``winners/`` without
re-running the search, and ``calibrate.fit_calibration`` fits correction
factors from ``samples/`` accumulated across sessions.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
from typing import Iterator


@dataclasses.dataclass(frozen=True)
class MeasureSample:
    """One measured program: robust wall time + analytic context."""

    task_fp: str
    prog_fp: str
    target: str               # hardware-target name the search priced on
    env_fp: str               # environment fingerprint (see env_fingerprint)
    time_s: float             # trimmed-median measured seconds
    samples: tuple[float, ...]   # raw repeat times (post-warmup)
    n_rejected: int           # MAD-outlier rejections
    mode: str                 # "xla" | "pallas" | "pallas_interpret"
    analytic_s: float         # cost_model.program_cost(...).total_s
    bottleneck: str           # dominant group bottleneck: compute|memory
    env: tuple[tuple[str, str], ...] = ()   # the fingerprinted env, readable

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["samples"] = list(self.samples)
        d["env"] = [list(kv) for kv in self.env]
        return d

    @classmethod
    def from_json(cls, d: dict) -> "MeasureSample":
        return cls(task_fp=d["task_fp"], prog_fp=d["prog_fp"],
                   target=d["target"], env_fp=d["env_fp"],
                   time_s=float(d["time_s"]),
                   samples=tuple(float(x) for x in d["samples"]),
                   n_rejected=int(d["n_rejected"]), mode=d["mode"],
                   analytic_s=float(d["analytic_s"]),
                   bottleneck=d["bottleneck"],
                   env=tuple((k, v) for k, v in d.get("env", [])))


# bump whenever kernel or lowering semantics change in a way that moves
# wall times without touching jax/backend/target (e.g. a rewritten
# Pallas kernel, a new group-lowering rule): old samples then miss
# instead of silently ranking today's programs by yesterday's timings
MEASURE_SCHEMA = 1


def env_fingerprint(target=None, mode: str = "auto",
                    rigor: tuple = ()
                    ) -> tuple[str, tuple[tuple[str, str], ...]]:
    """(12-hex fingerprint, readable env) of the measurement environment.

    Covers what changes what a wall-clock sample *means*: the jax
    backend actually executing (cpu/tpu/gpu), the jax version (compiler
    changes move timings), the measurement mode, the measurement-schema
    version (``MEASURE_SCHEMA`` — bumped on kernel/lowering semantic
    changes), the timing ``rigor`` (warmup/repeats/trim settings: a
    2-repeat spot sample must not masquerade as a 10-repeat one), and
    the target name AND a hash of its frozen constants (editing a
    registered target's numbers re-keys its samples instead of silently
    mixing them — same rule as the cost-memo invalidation, DESIGN.md
    §9).
    """
    import jax

    from repro.core import hardware
    tgt = hardware.resolve(target)
    env = (
        ("backend", str(jax.default_backend())),
        ("jax", str(jax.__version__)),
        ("mode", mode),
        ("rigor", repr(tuple(rigor))),
        ("schema", str(MEASURE_SCHEMA)),
        ("target", tgt.name),
        ("target_sha", hashlib.sha1(
            repr(tgt).encode()).hexdigest()[:8]),
    )
    fp = hashlib.sha1(repr(env).encode()).hexdigest()[:12]
    return fp, env


def _key16(*parts: str) -> str:
    return hashlib.sha1("|".join(parts).encode()).hexdigest()[:16]


class MeasureDB:
    """On-disk sample + winner store with an in-memory read cache.

    Thread-safe; writes are atomic (tmp file + ``os.replace``) so a
    crashed process never leaves a truncated JSON entry behind.
    """

    def __init__(self, path: str):
        self.path = str(path)
        self._samples_dir = os.path.join(self.path, "samples")
        self._winners_dir = os.path.join(self.path, "winners")
        os.makedirs(self._samples_dir, exist_ok=True)
        os.makedirs(self._winners_dir, exist_ok=True)
        self._lock = threading.RLock()
        # bounded read caches: entries always live on disk, so clearing
        # on overflow only costs a re-read — a long-lived service under
        # distinct-kernel traffic must not grow memory without bound
        self._cache_cap = 4096
        self._cache: dict[str, MeasureSample] = {}
        self._winner_cache: dict[str, dict] = {}

    # -- samples -------------------------------------------------------------
    def sample_key(self, task_fp: str, prog_fp: str, target: str,
                   env_fp: str) -> str:
        return _key16(task_fp, prog_fp, target, env_fp)

    def get(self, task_fp: str, prog_fp: str, target: str,
            env_fp: str) -> MeasureSample | None:
        key = self.sample_key(task_fp, prog_fp, target, env_fp)
        with self._lock:
            hit = self._cache.get(key)
            if hit is not None:
                return hit
        d = self._read(os.path.join(self._samples_dir, key + ".json"))
        if d is None:
            return None
        s = MeasureSample.from_json(d)
        with self._lock:
            self._cache_insert(self._cache, key, s)
        return s

    def put(self, sample: MeasureSample) -> None:
        key = self.sample_key(sample.task_fp, sample.prog_fp,
                              sample.target, sample.env_fp)
        self._write(os.path.join(self._samples_dir, key + ".json"),
                    sample.to_json())
        with self._lock:
            self._cache_insert(self._cache, key, sample)

    def iter_samples(self, *, target: str | None = None,
                     env_fp: str | None = None) -> Iterator[MeasureSample]:
        for fn in sorted(os.listdir(self._samples_dir)):
            if not fn.endswith(".json"):
                continue
            d = self._read(os.path.join(self._samples_dir, fn))
            if d is None:
                continue
            s = MeasureSample.from_json(d)
            if target is not None and s.target != target:
                continue
            if env_fp is not None and s.env_fp != env_fp:
                continue
            yield s

    # -- winners (KernelService warm-start records) --------------------------
    def winner_key(self, task_fp: str, target: str, env_fp: str) -> str:
        return _key16("winner", task_fp, target, env_fp)

    def put_winner(self, task_fp: str, target: str, env_fp: str,
                   record: dict) -> None:
        """``record`` must be JSON-safe and carry a ``program`` entry
        (``kernel_ir.program_to_json``) — enough to answer a repeat
        request in a fresh process without re-searching."""
        key = self.winner_key(task_fp, target, env_fp)
        self._write(os.path.join(self._winners_dir, key + ".json"),
                    record)
        with self._lock:
            self._cache_insert(self._winner_cache, key, record)

    def get_winner(self, task_fp: str, target: str,
                   env_fp: str) -> dict | None:
        key = self.winner_key(task_fp, target, env_fp)
        with self._lock:
            hit = self._winner_cache.get(key)
            if hit is not None:
                return hit
        d = self._read(os.path.join(self._winners_dir, key + ".json"))
        if d is not None:
            with self._lock:
                self._cache_insert(self._winner_cache, key, d)
        return d

    # -- bookkeeping ---------------------------------------------------------
    def _cache_insert(self, cache: dict, key: str, value) -> None:
        """Caller holds the lock.  Overflow clears: disk is canonical."""
        if len(cache) >= self._cache_cap:
            cache.clear()
        cache[key] = value

    @property
    def n_samples(self) -> int:
        return sum(fn.endswith(".json")
                   for fn in os.listdir(self._samples_dir))

    @property
    def n_winners(self) -> int:
        return sum(fn.endswith(".json")
                   for fn in os.listdir(self._winners_dir))

    def clear(self) -> None:
        with self._lock:
            self._cache.clear()
            self._winner_cache.clear()
            for d in (self._samples_dir, self._winners_dir):
                for fn in os.listdir(d):
                    if fn.endswith(".json"):
                        os.remove(os.path.join(d, fn))

    # -- file IO -------------------------------------------------------------
    @staticmethod
    def _read(path: str) -> dict | None:
        try:
            with open(path) as f:
                return json.load(f)
        except (OSError, json.JSONDecodeError):
            return None

    @staticmethod
    def _write(path: str, payload: dict) -> None:
        # unique tmp per writer: concurrent writers of the same key each
        # replace atomically (identical payloads — keys are content
        # addresses), never tripping over a shared tmp file
        tmp = f"{path}.{os.getpid()}.{threading.get_ident()}.tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
