"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The dry-run launcher
(`launch/dryrun.py`) sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
before any jax import; everything else sees the real (single) device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(*, data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over the actually-available devices (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))
