"""Cost-model calibration from measured samples.

The analytic roofline and a real backend disagree by systematic,
*bottleneck-shaped* factors: compute-bound kernels miss peak by one
ratio (issue width, MXU padding the efficiency table doesn't capture),
memory-bound ones by another (achievable vs datasheet bandwidth,
prefetch depth).  So the correction is fit **per target, per
bottleneck class**: for every DB sample whose dominant group bottleneck
is class ``b`` on target ``t``, model

    log(measured) = log(analytic) + log(c[t, b])

and solve the log-space least squares — which for a pure scale term is
the mean log-residual, ``c = exp(mean(log m - log a))``.  Scale-only by
construction: a monotone per-class correction can re-rank programs
*across* bottleneck classes (that is the point — the analytic model's
compute/memory balance is what measurement corrects) but never within
one, and when measurements equal analytic predictions every factor is
exactly 1.0 and the calibrated model is bit-identical to the analytic
one (property-tested in ``tests/test_measure.py``).

``CalibratedCostModel`` is a drop-in for the analytic pricing used by
``core/search.py``: hand it to ``TranspositionStore(cost_model=...)``
(or ``MTMCPipeline(config=OptimizeConfig(cost_model=...))`` for the
uncached path)
and every strategy searches under calibrated costs.  A store is bound to ONE cost
model for its lifetime — the cost memo keys ``(fp, target)`` do not
encode the model, so swapping models means a fresh store, exactly like
a cost-model code change (DESIGN.md §8/§11).
"""
from __future__ import annotations

import dataclasses
import json
import math
from collections.abc import Iterable

from repro.core import cost_model, hardware
from repro.core.cost_model import GroupCost, ProgramCost
from repro.core.kernel_ir import KernelProgram
from repro.measure.db import MeasureSample


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Per-(target, bottleneck) multiplicative corrections + fit stats."""

    factors: tuple[tuple[tuple[str, str], float], ...]
    n_samples: tuple[tuple[tuple[str, str], int], ...]
    residual_rms: float = 0.0      # log-space RMS after correction
    min_samples: int = 2           # the fit's evidence threshold

    @property
    def factor_map(self) -> dict[tuple[str, str], float]:
        return dict(self.factors)

    @property
    def count_map(self) -> dict[tuple[str, str], int]:
        return dict(self.n_samples)

    def factor(self, target: str, bottleneck: str) -> float:
        # identity for unseen buckets: the compute-vs-memory balance is
        # the one cross-class statement calibration corrects, so a
        # class with zero samples must keep the analytic value rather
        # than borrow the OTHER class's correction on no evidence
        return self.factor_map.get((target, bottleneck), 1.0)

    def fitted(self, target: str, bottleneck: str) -> bool:
        """True when this bucket's factor came from a real fit; False
        when it is the identity fallback (unseen bucket, or seen with
        fewer than ``min_samples`` samples).  A degenerate calibration
        — every bucket of a target a fallback — is a silent no-op the
        benchmarks must surface, not a fit."""
        return self.count_map.get((target, bottleneck), 0) \
            >= self.min_samples

    def bucket_report(self, target: str | None = None) -> list[str]:
        """One ``target/bottleneck: factor (n=.., fitted|fallback)``
        line per known bucket — what measure_bench prints so a no-op
        fit (the PR-4 gpu_a100 0.183->0.184 case) is visible."""
        lines = []
        for (tgt, bott), n in sorted(self.n_samples):
            if target is not None and tgt != target:
                continue
            c = self.factor(tgt, bott)
            tag = "fitted" if self.fitted(tgt, bott) else "fallback"
            lines.append(f"{tgt}/{bott}: x{c:.3f} (n={n}, {tag})")
        return lines

    # -- persistence (lives next to the MeasureDB it was fit from) ----------
    def to_json(self) -> dict:
        return {"factors": [[list(k), v] for k, v in self.factors],
                "n_samples": [[list(k), n] for k, n in self.n_samples],
                "residual_rms": self.residual_rms,
                "min_samples": self.min_samples}

    @classmethod
    def from_json(cls, d: dict) -> Calibration:
        return cls(
            factors=tuple((tuple(k), float(v))
                          for k, v in d["factors"]),
            n_samples=tuple((tuple(k), int(n))
                            for k, n in d["n_samples"]),
            residual_rms=float(d.get("residual_rms", 0.0)),
            min_samples=int(d.get("min_samples", 2)))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> Calibration:
        with open(path) as f:
            return cls.from_json(json.load(f))


def fit_calibration(samples: Iterable[MeasureSample], *,
                    min_samples: int = 2,
                    allow_mixed_envs: bool = False) -> Calibration:
    """Log-space least-squares scale fit per (target, bottleneck).

    Buckets with fewer than ``min_samples`` valid samples keep the
    identity factor (too little evidence to move the model).  Samples
    with non-positive analytic or measured time are skipped (a log
    model cannot express them; they indicate a broken measurement).

    Samples spanning more than one environment fingerprint are refused
    unless ``allow_mixed_envs=True``: wall times from incomparable
    environments (interpret-mode CPU vs compiled TPU, different jax
    versions, different timing rigor) differ by regime, and averaging
    their log-residuals into one factor would mis-price everything —
    filter with ``MeasureDB.iter_samples(env_fp=...)`` first.
    """
    buckets: dict[tuple[str, str], list[float]] = {}
    envs: set[str] = set()
    for s in samples:
        if s.analytic_s <= 0.0 or s.time_s <= 0.0:
            continue
        envs.add(s.env_fp)
        if len(envs) > 1 and not allow_mixed_envs:
            raise ValueError(
                f"samples span {len(envs)} environment fingerprints "
                f"({sorted(envs)}); filter by env_fp (MeasureDB."
                "iter_samples(env_fp=...)) or pass "
                "allow_mixed_envs=True")
        buckets.setdefault((s.target, s.bottleneck), []).append(
            math.log(s.time_s) - math.log(s.analytic_s))
    factors, counts, sq = [], [], []
    for key in sorted(buckets):
        resid = buckets[key]
        counts.append((key, len(resid)))
        if len(resid) < min_samples:
            factors.append((key, 1.0))
            sq.extend(r * r for r in resid)
            continue
        mean = sum(resid) / len(resid)
        factors.append((key, math.exp(mean)))
        sq.extend((r - mean) ** 2 for r in resid)
    rms = math.sqrt(sum(sq) / len(sq)) if sq else 0.0
    return Calibration(tuple(factors), tuple(counts), rms,
                       min_samples=int(min_samples))


class CalibratedCostModel:
    """Analytic roofline with measured per-bottleneck corrections.

    Drop-in for ``cost_model.program_cost`` wherever pricing is
    pluggable (``TranspositionStore(cost_model=...)``,
    ``OptimizeConfig(cost_model=...)``): each group's time is
    scaled by the calibration factor of its (target, bottleneck) bucket
    and the program total re-summed.  Identity calibration reproduces
    the analytic model exactly.
    """

    def __init__(self, calibration: Calibration):
        self.calibration = calibration

    def program_cost(self, prog: KernelProgram,
                     target=None) -> ProgramCost:
        tgt = hardware.resolve(target)
        base = cost_model.program_cost(prog, tgt)
        groups = tuple(self._scale(g, tgt.name) for g in base.groups)
        return ProgramCost(sum(g.time_s for g in groups), groups,
                           tgt.name)

    def total_s(self, prog: KernelProgram, target=None) -> float:
        return self.program_cost(prog, target).total_s

    def _scale(self, g: GroupCost, target: str) -> GroupCost:
        c = self.calibration.factor(target, g.bottleneck)
        if c == 1.0:
            return g
        return dataclasses.replace(g, time_s=g.time_s * c,
                                   compute_s=g.compute_s * c,
                                   memory_s=g.memory_s * c)


# ---------------------------------------------------------------------------
# rank statistics (measure_bench, tests)
# ---------------------------------------------------------------------------

def spearman(xs: Iterable[float], ys: Iterable[float]) -> float:
    """Spearman rank correlation (average ranks for ties)."""
    xs, ys = list(xs), list(ys)
    if len(xs) != len(ys):
        raise ValueError("length mismatch")
    n = len(xs)
    if n < 2:
        return 0.0
    rx, ry = _ranks(xs), _ranks(ys)
    mx = sum(rx) / n
    my = sum(ry) / n
    num = sum((a - mx) * (b - my) for a, b in zip(rx, ry))
    dx = math.sqrt(sum((a - mx) ** 2 for a in rx))
    dy = math.sqrt(sum((b - my) ** 2 for b in ry))
    if dx == 0.0 or dy == 0.0:
        return 0.0
    return num / (dx * dy)


def _ranks(xs: list[float]) -> list[float]:
    order = sorted(range(len(xs)), key=lambda i: xs[i])
    ranks = [0.0] * len(xs)
    i = 0
    while i < len(order):
        j = i
        while j + 1 < len(order) and xs[order[j + 1]] == xs[order[i]]:
            j += 1
        r = (i + j) / 2.0 + 1.0
        for k in range(i, j + 1):
            ranks[order[k]] = r
        i = j + 1
    return ranks
