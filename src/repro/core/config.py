"""One optimizer-configuration surface for every entry point.

``OptimizeConfig`` is the single frozen record of *how to optimize a
kernel* — mode, search strategy, action space, step budget, pricing
(cost model), and measured reranking.  Every entry point accepts it
under a ``config=`` keyword:

    MTMCPipeline(policy, config=OptimizeConfig(strategy="policy"))
    EvalEngine(policy, config=..., workers=8)
    tune_model_kernels(model_cfg, shape, config=...)
    KernelService(policy, config=..., measure=True)
    Fleet(db_dir, config=...)

Engine-/service-specific knobs that are not *optimizer* semantics
(worker counts, store capacity, measurement plumbing) stay explicit
keyword arguments on their owners.

The pre-existing kwargs sprawl (``mode=``, ``strategy=``,
``max_steps=``, ..., ``cost_model_override=``) keeps working for one
release as **deprecation shims**: each entry point folds the legacy
keywords into an ``OptimizeConfig`` and emits a single
``DeprecationWarning`` per entry point per process — the resulting
config drives the exact same code path, so legacy calls produce
byte-identical outcomes (shim-tested in ``tests/test_optimize_config``).
An in-repo call site outside this shim layer must use ``config=``; an
AST gate in the test suite enforces it.

``cost_model`` collapses the former ``cost_model_override`` vs
``TranspositionStore(cost_model=...)`` duality: it is THE field naming
the pricing model, and the existing consistency check still refuses a
store bound to a different model (a store's ``(fp, target)`` cost memo
does not encode the model — DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses
import threading
import warnings

# sentinel distinguishing "caller passed this legacy kwarg" from "left
# at default" — a legacy default must neither warn nor override config
UNSET = object()


@dataclasses.dataclass(frozen=True)
class OptimizeConfig:
    """How to optimize a kernel — shared by every entry point.

    ``strategy`` may be a registered strategy name (``"greedy"``,
    ``"beam"``, ``"anneal"``, ``"policy"``) or a ``SearchStrategy``
    instance; ``None`` keeps the mode-driven rollout loop.
    ``cost_model`` is the pluggable pricing model (duck-typed
    ``program_cost``/``total_s``, e.g. ``measure.CalibratedCostModel``);
    ``measurer`` a ``measure.ExecutionHarness`` for measured reranking
    of the search's top-``rerank_top_k`` survivors.
    ``coder`` selects the Micro Coding implementation: the default
    ``"structured"`` registry engine, an ``"llm*"`` spec string resolved
    by ``repro.llmcoder.make_coder`` (``"llm-template"``, ``"llm-adapt"``,
    ``"llm-replay:DIR"``), or a ``MicroCoder`` instance shared across
    engines (``micro_coding.get_coder`` dispatches).
    """

    mode: str = "policy"
    curated: bool = True
    extended_rules: bool = False
    max_steps: int = 8
    seed: int = 0
    validate: bool = True
    target: object = None          # target name | HardwareTarget | None
    strategy: object = None        # name | SearchStrategy | None
    cost_model: object = None
    measurer: object = None
    rerank_top_k: int = 0
    coder: object = "structured"   # spec string | MicroCoder instance

    def replace(self, **kw) -> OptimizeConfig:
        return dataclasses.replace(self, **kw)


_warned: set[str] = set()
_warn_lock = threading.Lock()


def reset_deprecation_warnings() -> None:
    """Forget which entry points already warned (tests only)."""
    with _warn_lock:
        _warned.clear()


def _warn_once(entry_point: str, names: list[str]) -> None:
    with _warn_lock:
        if entry_point in _warned:
            return
        _warned.add(entry_point)
    warnings.warn(
        f"{entry_point}({', '.join(sorted(names))}=...) keyword options "
        f"are deprecated; pass config=OptimizeConfig(...) instead "
        f"(repro.core.OptimizeConfig). The shim will be removed next "
        f"release.", DeprecationWarning, stacklevel=3)


def resolve_config(entry_point: str,
                   config: OptimizeConfig | None,
                   legacy: dict,
                   *, defaults: OptimizeConfig | None = None
                   ) -> OptimizeConfig:
    """Fold legacy kwargs into one ``OptimizeConfig``.

    ``legacy`` maps OptimizeConfig field names to the caller-supplied
    values, ``UNSET`` marking "not passed".  Passing both ``config``
    and any legacy kwarg is an error (the two would silently shadow
    each other); legacy kwargs emit one ``DeprecationWarning`` per
    ``entry_point`` per process.  ``defaults`` seeds entry points whose
    historical defaults differ from ``OptimizeConfig()`` (e.g. the
    service's ``mode="greedy_cost"``), keeping shimmed calls
    byte-identical to their pre-config behavior.
    """
    passed = {k: v for k, v in legacy.items() if v is not UNSET}
    if config is not None:
        if passed:
            raise TypeError(
                f"{entry_point}: pass either config=OptimizeConfig(...) "
                f"or legacy keyword options "
                f"({', '.join(sorted(passed))}), not both")
        return config
    base = defaults if defaults is not None else OptimizeConfig()
    if not passed:
        return base
    _warn_once(entry_point, sorted(passed))
    return dataclasses.replace(base, **passed)
