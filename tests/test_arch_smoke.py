"""Per-architecture smoke tests (deliverable f).

Each assigned arch is instantiated at a REDUCED config of the same family
and runs one forward + one train step on CPU, asserting output shapes and
no NaNs; decode smoke runs one serve_step against a fresh cache.
The FULL configs are exercised only via the dry-run (abstract lowering).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import RunConfig, ShapeConfig
from repro.configs.registry import ARCH_IDS, get_config, reduced
from repro.models import api
from repro.optim import adamw
from repro.train.trainer import make_train_step
from repro.data.pipeline import host_batch

SHAPE = ShapeConfig("smoke", 32, 2, "train")


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = reduced(get_config(request.param))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return request.param, cfg, params


def test_full_config_matches_spec():
    """The exact assigned numbers (guards against config drift)."""
    spec = {
        "qwen2_5_3b": (36, 2048, 16, 2, 11008, 151936),
        "yi_34b": (60, 7168, 56, 8, 20480, 64000),
        "qwen3_14b": (40, 5120, 40, 8, 17408, 151936),
        "qwen2_5_32b": (64, 5120, 40, 8, 27648, 152064),
        "rwkv6_3b": (32, 2560, 40, 40, 8960, 65536),
        "paligemma_3b": (18, 2048, 8, 1, 16384, 257216),
        "phi3_5_moe_42b": (32, 4096, 32, 8, 6400, 32064),
        "dbrx_132b": (40, 6144, 48, 8, 10752, 100352),
        "hymba_1_5b": (32, 1600, 25, 5, 5504, 32001),
        "seamless_m4t_medium": (12, 1024, 16, 16, 4096, 256206),
    }
    for arch, (L, D, H, KV, FF, V) in spec.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
                c.d_ff, c.vocab_size) == (L, D, H, KV, FF, V), arch


def test_moe_configs():
    assert get_config("phi3_5_moe_42b").n_experts == 16
    assert get_config("phi3_5_moe_42b").top_k == 2
    assert get_config("dbrx_132b").top_k == 4
    assert get_config("hymba_1_5b").ssm_state == 16


def test_forward_smoke(arch_setup):
    arch, cfg, params = arch_setup
    batch = api.concrete_batch(cfg, SHAPE, jax.random.PRNGKey(1))
    model = api.get_model(cfg)
    logits, aux = model.forward(cfg, params, batch)
    assert logits.shape == (SHAPE.global_batch, SHAPE.seq_len,
                            cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"


def test_train_step_smoke(arch_setup):
    arch, cfg, params = arch_setup
    step_fn = make_train_step(cfg, SHAPE, RunConfig(accum_steps=1))
    opt = adamw.init(params)
    batch = host_batch(cfg, SHAPE, 0, process_index=0, process_count=1)
    new_params, new_opt, metrics = jax.jit(step_fn)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"])), arch
    assert float(metrics["loss"]) > 0
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()),
                     params, new_params))
    assert delta > 0, f"{arch}: optimizer made no update"


def test_decode_smoke(arch_setup):
    arch, cfg, params = arch_setup
    model = api.get_model(cfg)
    cache = api.init_cache(cfg, 2, 64)
    logits, new_cache = model.decode_step(
        cfg, params, cache, jnp.ones((2, 1), jnp.int32), jnp.int32(3))
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


def test_grad_accum_equivalence():
    """accum=2 must give (numerically) the same update as accum=1."""
    cfg = reduced(get_config("qwen2_5_3b"))
    shape = ShapeConfig("s", 16, 4, "train")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = host_batch(cfg, shape, 0, process_index=0, process_count=1)
    outs = []
    for accum in (1, 2):
        step = make_train_step(cfg, shape, RunConfig(accum_steps=accum))
        p2, _, m = jax.jit(step)(params, adamw.init(params), batch)
        outs.append((p2, float(m["loss"])))
    d = jax.tree.reduce(
        lambda a, b: max(a, b),
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                     outs[0][0], outs[1][0]))
    assert d < 5e-5, d
