"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent on the production meshes
(16x16 single-pod, 2x16x16 multi-pod = 512 chips) without hardware:
inputs/params/optimizer state are ShapeDtypeStructs, ``.lower().compile()``
must succeed, and the compiled artifact yields memory_analysis /
cost_analysis / the partitioned HLO for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2_5_3b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
# The VERY FIRST lines, before ANY other import (jax locks device count
# on first init):
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import traceback     # noqa: E402

# jax-free by design (measure/timing.py) — safe before jax init
from repro.measure.timing import stopwatch  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import (RunConfig, SHAPES, normalize_for_mesh,
                                shape_applicable)  # noqa: E402
from repro.configs.registry import ARCH_IDS, get_config  # noqa: E402
from repro.dist.sharding import ShardingRules  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import api, makers  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.roofline import analysis  # noqa: E402
from repro.serve.engine import make_serve_step  # noqa: E402
from repro.train.trainer import make_train_step  # noqa: E402


def rules_for(mesh, kind: str, fsdp: bool = True,
              opts: tuple[str, ...] = ()) -> ShardingRules:
    rules = ShardingRules(mesh)
    if kind == "train":
        rules = rules.with_fsdp() if fsdp else rules
    elif kind == "decode":
        # KV-cache sequence axis takes whatever mesh axes the batch axis
        # leaves free (flash-decode style partitioned softmax)
        rules = rules.replace(kv_seq=("data", "model"))
    if "seq_shard" in opts:
        # §Perf H1: shard attention over the query-sequence axis when
        # heads are unshardable (hymba) — see transformer._q_axes
        rules = rules.replace(seq=("model",))
    if "bf16_reduce" in opts:
        # §Perf H2: pin TP activation all-reduces to bf16
        rules = rules.with_flags("bf16_reduce")
    for o in opts:
        if o.startswith("qchunk"):
            # §Perf H3: Tiling action — bigger attention q-chunks
            from repro.kernels import ops as kops
            kops.set_default_chunk(int(o[len("qchunk"):]))
    return rules


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               fsdp: bool = True, run: RunConfig | None = None,
               rules_override=None, opts: tuple[str, ...] = ()):
    """Returns (lowered, compiled, meta) for one cell."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = mesh.devices.size
    shape = SHAPES[shape_name]
    cfg0 = get_config(arch)
    ok, reason = shape_applicable(cfg0, shape)
    if not ok:
        return None, None, {"skipped": reason, "arch": arch,
                            "shape": shape_name, "mesh": mesh_name}
    rules = rules_override or rules_for(mesh, shape.kind, fsdp, opts)
    cfg = normalize_for_mesh(cfg0, rules.tp)
    run = run or RunConfig(gather_once=("gather_once" in opts))
    sw = stopwatch().start()

    if shape.kind == "train":
        params = api.abstract_params(cfg)
        opt = jax.eval_shape(adamw.init, params)
        batch = api.batch_struct(cfg, shape)
        step = make_train_step(cfg, shape, run, rules=rules)
        p_sh = api.param_shardings(cfg, rules)
        o_sh = {"mu": p_sh, "nu": p_sh,
                "step": jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec())}
        b_sh = {k: jax.sharding.NamedSharding(
            mesh, v) for k, v in api.batch_pspecs(
                cfg, shape, rules, batch).items()}
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh))
        lowered = jitted.lower(params, opt, batch)
        extra = {"accum": step.accum}
    elif shape.kind == "prefill":
        params = api.abstract_params(cfg, jnp.bfloat16)
        batch = api.batch_struct(cfg, shape, with_targets=False)
        model = api.get_model(cfg)

        def prefill(params, batch):
            logits, aux = model.forward(cfg, params, batch, rules=rules,
                                        remat=False)
            return logits

        p_sh = api.param_shardings(cfg, rules)
        b_sh = {k: jax.sharding.NamedSharding(mesh, v)
                for k, v in api.batch_pspecs(cfg, shape, rules,
                                             batch).items()}
        lowered = jax.jit(prefill,
                          in_shardings=(p_sh, b_sh)).lower(params, batch)
        extra = {}
    else:  # decode
        params = api.abstract_params(cfg, jnp.bfloat16)
        spec = api.decode_input_specs(cfg, shape)
        serve_step = make_serve_step(cfg, rules=rules)
        p_sh = api.param_shardings(cfg, rules)
        c_sh = jax.tree.map(
            lambda s: jax.sharding.NamedSharding(mesh, s),
            api.cache_pspecs(cfg, shape.global_batch, shape.seq_len,
                             rules))
        t_sh = jax.sharding.NamedSharding(
            mesh, api.batch_pspecs(cfg, shape, rules,
                                   {"tokens": spec["tokens"]})["tokens"])
        pos_sh = jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec())
        jitted = jax.jit(serve_step,
                         in_shardings=(p_sh, c_sh, t_sh, pos_sh))
        lowered = jitted.lower(params, spec["cache"], spec["tokens"],
                               spec["pos"])
        extra = {}

    t_lower = sw.lap()
    compiled = lowered.compile()
    t_compile = sw.lap()
    meta = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "chips": chips, "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1), **extra}
    return lowered, compiled, meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             want_roofline: bool = True,
             opts: tuple[str, ...] = ()) -> dict:
    try:
        lowered, compiled, meta = lower_cell(arch, shape_name,
                                             multi_pod=multi_pod,
                                             opts=opts)
    except Exception as e:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "FAIL",
                "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}
    if compiled is None:
        return {**meta, "status": "SKIP"}
    mem = compiled.memory_analysis()
    out = {**meta, "status": "OK",
           "arg_gb": round(mem.argument_size_in_bytes / 2**30, 3),
           "out_gb": round(mem.output_size_in_bytes / 2**30, 3),
           "temp_gb": round(mem.temp_size_in_bytes / 2**30, 3)}
    if want_roofline:
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        rl = analysis.analyze(
            compiled, arch=arch, shape=shape,
            mesh_name=meta["mesh"], chips=meta["chips"],
            cfg=normalize_for_mesh(cfg, 16), kind=shape.kind)
        out["roofline"] = rl.row()
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--opt", default="",
                    help="comma list: seq_shard,gather_once (§Perf)")
    args = ap.parse_args()
    opts = tuple(o for o in args.opt.split(",") if o)

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))

    results = []
    for arch, shape in cells:
        r = run_cell(arch, shape, multi_pod=args.multi_pod, opts=opts)
        if opts:
            r["opts"] = list(opts)
        results.append(r)
        line = {k: v for k, v in r.items()
                if k in ("arch", "shape", "mesh", "status", "compile_s",
                         "arg_gb", "temp_gb", "error")}
        print(json.dumps(line), flush=True)
        if r["status"] == "OK" and "roofline" in r:
            rl = r["roofline"]
            print(f"  terms: compute={rl['compute_s']*1e3:.2f}ms "
                  f"memory={rl['memory_s']*1e3:.2f}ms "
                  f"collective={rl['collective_s']*1e3:.2f}ms "
                  f"dominant={rl['dominant']} "
                  f"roofline_frac={rl['roofline_fraction']:.3f}",
                  flush=True)
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1, default=str)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\n{len(results)} cells: "
          f"{sum(r['status'] == 'OK' for r in results)} ok, "
          f"{sum(r['status'] == 'SKIP' for r in results)} skip, "
          f"{n_fail} fail")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
