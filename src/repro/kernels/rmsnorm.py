"""Row-tiled RMSNorm (Pallas TPU).

Rows are flattened and blocked; the full feature dim stays resident in
VMEM (d_model <= 8k => <= 4MB f32 per 128-row tile).  Schedule: rows tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams
from repro.kernels.schedule import KernelSchedule, default_schedule


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "schedule",
                                             "interpret"))
def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
            schedule: KernelSchedule | None = None,
            interpret: bool = False) -> jax.Array:
    s = schedule or default_schedule("rmsnorm")
    orig_shape = x.shape
    D = x.shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    br = min(s.block("rows", 256), R)
    if R % br != 0:
        br = 1
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xf, scale)
    return out.reshape(orig_shape)
