"""Measured-execution subsystem (measure/*, DESIGN.md §11).

Covers: timing statistics, program JSON round-trip, MeasureDB
round-trip + env-fingerprint invalidation + cross-instance persistence,
the calibration identity property (fit on measurements that equal the
analytic predictions must never reorder programs), harness lowering
fidelity and DB caching, measured reranking through MTMCPipeline with
an injected runner, and the KernelService restart warm start.
"""
import os

import pytest

from repro.core import cost_model, tasks as T
from repro.core.engine import TranspositionStore
from repro.core.kernel_ir import (chain_program, program_from_json,
                                  program_to_json)
from repro.core.micro_coding import StructuredMicroCoder
from repro.core.pipeline import MTMCPipeline
from repro.core.search import BeamSearch
from repro.measure.calibrate import (CalibratedCostModel, Calibration,
                                     fit_calibration, spearman)
from repro.measure.db import MeasureDB, MeasureSample, env_fingerprint
from repro.measure.harness import (ExecutionHarness, MeasureConfig,
                                   MeasureError, lower_program)
from repro.measure.timing import robust_time_s, stopwatch, time_thunk

from tests._hyp import given, settings, strategies as st

FIXTURE_DB = os.path.join(os.path.dirname(__file__), "fixtures",
                          "measure_db")


def _tiny_matmul(name="tiny_mm"):
    return chain_program(name, {"a": (256, 256), "b": (256, 256)},
                         [("y", "matmul", ("a", "b"))])


def _tiny_fused():
    return chain_program("tiny_fused",
                         {"a": (256, 256), "b": (256, 256),
                          "bias0": (256,)},
                         [("y0", "matmul", ("a", "b")),
                          ("y1", "bias", ("y0", "bias0")),
                          ("y", "relu", ("y1",))])


# ---------------------------------------------------------------------------
# timing
# ---------------------------------------------------------------------------

def test_robust_time_rejects_outliers_and_trims():
    clean = [1.0, 1.01, 0.99, 1.02, 0.98]
    t, n_rej = robust_time_s(clean + [50.0])
    assert n_rej == 1
    assert 0.98 <= t <= 1.02
    # all-equal samples: MAD is 0, nothing rejected, exact median
    t2, n2 = robust_time_s([2.0, 2.0, 2.0])
    assert (t2, n2) == (2.0, 0)


def test_stopwatch_and_laps_are_monotonic():
    with stopwatch() as sw:
        pass
    assert sw.s >= 0.0
    sw = stopwatch().start()
    a = sw.lap()
    b = sw.lap()
    assert a >= 0.0 and b >= 0.0


def test_time_thunk_counts_calls():
    calls = []
    samples = time_thunk(lambda: calls.append(1), warmup=2, repeats=3)
    assert len(samples) == 3 and len(calls) == 5


def test_spearman_basics():
    assert spearman([1, 2, 3], [10, 20, 30]) == pytest.approx(1.0)
    assert spearman([1, 2, 3], [30, 20, 10]) == pytest.approx(-1.0)
    assert abs(spearman([1, 1, 1], [1, 2, 3])) < 1e-12   # ties


# ---------------------------------------------------------------------------
# program JSON round-trip
# ---------------------------------------------------------------------------

def test_program_json_roundtrip_preserves_fingerprint():
    coder = StructuredMicroCoder()
    progs = [T.kb_level1()[0], T.kb_level2()[0],
             T._attn_program("rt_attn", 2, 256, 4, 64)]
    # include a schedule-rewritten program so non-default schedules and
    # history survive the trip too
    from repro.core import actions as A
    r = coder.apply(progs[0], A.Action(
        "tiling", progs[0].fusion_groups[0][0],
        (("bk", 128), ("bm", 256), ("bn", 128))))
    assert r.status == "ok"
    progs.append(r.program)
    for p in progs:
        q = program_from_json(program_to_json(p))
        assert q.fingerprint() == p.fingerprint()
        assert q.eval_fingerprint() == p.eval_fingerprint()
        assert q.history == p.history


# ---------------------------------------------------------------------------
# MeasureDB
# ---------------------------------------------------------------------------

def _sample(task_fp="t0", prog_fp="p0", target="tpu_v5e",
            env_fp="e0", time_s=1e-3, analytic_s=2e-3,
            bottleneck="compute"):
    return MeasureSample(task_fp=task_fp, prog_fp=prog_fp,
                         target=target, env_fp=env_fp, time_s=time_s,
                         samples=(time_s, time_s * 1.01), n_rejected=0,
                         mode="xla", analytic_s=analytic_s,
                         bottleneck=bottleneck,
                         env=(("backend", "cpu"),))


def test_db_roundtrip_and_env_invalidation(tmp_path):
    db = MeasureDB(str(tmp_path / "db"))
    s = _sample()
    db.put(s)
    assert db.get("t0", "p0", "tpu_v5e", "e0") == s
    # a changed environment fingerprint is a MISS, not a stale hit
    assert db.get("t0", "p0", "tpu_v5e", "DIFFERENT") is None
    assert db.get("t0", "p0", "gpu_a100", "e0") is None
    # a second instance on the same directory sees the entry (restart)
    db2 = MeasureDB(str(tmp_path / "db"))
    assert db2.get("t0", "p0", "tpu_v5e", "e0") == s
    assert db2.n_samples == 1


def test_db_winner_roundtrip(tmp_path):
    db = MeasureDB(str(tmp_path / "db"))
    task = _tiny_matmul()
    rec = {"task": task.name, "program": program_to_json(task),
           "speedup": 1.5, "steps": 2, "measured_s": 1e-3,
           "measured_baseline_s": 2e-3, "reranked": True}
    db.put_winner(task.fingerprint(), "tpu_v5e", "e0", rec)
    db2 = MeasureDB(str(tmp_path / "db"))
    got = db2.get_winner(task.fingerprint(), "tpu_v5e", "e0")
    assert got is not None
    assert program_from_json(got["program"]).fingerprint() == \
        task.fingerprint()
    assert db2.get_winner(task.fingerprint(), "tpu_v5e", "e1") is None


def test_env_fingerprint_keys_on_mode_and_target():
    fp_a, env = env_fingerprint("tpu_v5e", "auto")
    fp_x, _ = env_fingerprint("tpu_v5e", "xla")
    fp_g, _ = env_fingerprint("gpu_a100", "auto")
    assert len({fp_a, fp_x, fp_g}) == 3
    assert dict(env)["target"] == "tpu_v5e"


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------

def test_calibration_fixture_db_fits_exact_factors():
    """The committed CI fixture DB carries 2x compute / 3x memory
    residuals; the log-space fit must recover them exactly."""
    db = MeasureDB(FIXTURE_DB)
    fit = fit_calibration(db.iter_samples(target="tpu_v5e"))
    f = fit.factor_map
    assert f[("tpu_v5e", "compute")] == pytest.approx(2.0, rel=1e-9)
    assert f[("tpu_v5e", "memory")] == pytest.approx(3.0, rel=1e-9)
    assert fit.residual_rms == pytest.approx(0.0, abs=1e-9)


def test_calibration_json_roundtrip(tmp_path):
    fit = Calibration(factors=((("tpu_v5e", "compute"), 2.0),
                               (("tpu_v5e", "memory"), 0.5)),
                      n_samples=((("tpu_v5e", "compute"), 4),
                                 (("tpu_v5e", "memory"), 3)),
                      residual_rms=0.1)
    path = str(tmp_path / "cal.json")
    fit.save(path)
    assert Calibration.load(path) == fit


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 12))
def test_identity_calibration_never_reorders(seed, n):
    """Property: fit on samples where measured == analytic yields the
    identity correction, so CalibratedCostModel ranks programs exactly
    like the analytic model (the §11 safety property: measurement that
    agrees with the model must not change any search decision)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    samples = []
    for i in range(n):
        a = float(10.0 ** rng.uniform(-6, -2))
        samples.append(_sample(
            task_fp=f"t{i}", prog_fp=f"p{i}", time_s=a, analytic_s=a,
            bottleneck=rng.choice(["compute", "memory"])))
    fit = fit_calibration(samples)
    assert all(v == pytest.approx(1.0, rel=1e-12)
               for _, v in fit.factors)
    cal = CalibratedCostModel(fit)
    progs = [T.kb_level1()[0], T.kb_level2()[0],
             _tiny_matmul(), _tiny_fused()]
    for tgt in ("tpu_v5e", "gpu_a100"):
        analytic = [cost_model.program_cost(p, tgt).total_s
                    for p in progs]
        calibrated = [cal.total_s(p, tgt) for p in progs]
        assert calibrated == pytest.approx(analytic, rel=1e-12)
        assert sorted(range(len(progs)), key=lambda i: analytic[i]) == \
            sorted(range(len(progs)), key=lambda i: calibrated[i])


def test_calibrated_model_rescales_per_bottleneck():
    fit = Calibration(factors=((("tpu_v5e", "compute"), 2.0),
                               (("tpu_v5e", "memory"), 1.0)),
                      n_samples=())
    cal = CalibratedCostModel(fit)
    prog = _tiny_matmul()
    base = cost_model.program_cost(prog, "tpu_v5e")
    got = cal.program_cost(prog, "tpu_v5e")
    for g0, g1 in zip(base.groups, got.groups):
        want = 2.0 if g0.bottleneck == "compute" else 1.0
        assert g1.time_s == pytest.approx(g0.time_s * want)
    # unseen target falls back to identity
    other = cal.program_cost(prog, "gpu_a100")
    assert other.total_s == pytest.approx(
        cost_model.program_cost(prog, "gpu_a100").total_s)


def test_store_accepts_calibrated_cost_model():
    fit = Calibration(factors=((("tpu_v5e", "compute"), 2.0),
                               (("tpu_v5e", "memory"), 2.0)),
                      n_samples=())
    cal = CalibratedCostModel(fit)
    store = TranspositionStore(cost_model=cal)
    prog = _tiny_matmul()
    assert store.cost(prog, "tpu_v5e") == pytest.approx(
        2.0 * cost_model.program_cost(prog, "tpu_v5e").total_s)
    # a pipeline wired with a DIFFERENT model than its store must refuse
    with pytest.raises(ValueError):
        MTMCPipeline(store=TranspositionStore(),
                     cost_model_override=cal)


# ---------------------------------------------------------------------------
# harness lowering + measurement
# ---------------------------------------------------------------------------

def test_lowering_covers_pallas_groups_and_matches_oracle():
    h = ExecutionHarness(cfg=MeasureConfig(repeats=2, warmup=1))
    fused = _tiny_fused()
    low = lower_program(fused, mode="auto")
    assert low.n_pallas == 1          # the matmul group
    s = h.measure(fused, fused)
    assert s.time_s > 0.0 and s.mode.startswith("pallas")
    assert h.stats["verify_fallbacks"] == 0   # lowering == oracle
    assert s.analytic_s == pytest.approx(
        cost_model.program_cost(fused).total_s)
    assert s.bottleneck in ("compute", "memory")


def test_lowering_xla_mode_and_pallas_mode_errors():
    soft = chain_program("soft", {"x": (64, 64)},
                         [("y", "softmax", ("x",))])
    low = lower_program(soft, mode="xla")
    assert low.mode == "xla" and low.n_pallas == 0
    with pytest.raises(MeasureError):
        lower_program(soft, mode="pallas")   # nothing pallas-eligible


def test_harness_db_caching_and_env_keying(tmp_path):
    db = MeasureDB(str(tmp_path / "db"))
    h = ExecutionHarness(db=db, cfg=MeasureConfig(repeats=2, warmup=0))
    task = _tiny_matmul()
    s1 = h.measure(task, task)
    s2 = h.measure(task, task)
    assert s2 == s1
    assert h.stats["measured"] == 1
    assert h.stats["db_hits"] == 1 and h.stats["db_misses"] == 1
    # a fresh harness on the same DB (same env) also hits
    h2 = ExecutionHarness(db=db,
                          cfg=MeasureConfig(repeats=2, warmup=0))
    assert h2.measure(task, task) == s1
    assert h2.stats == {"measured": 0, "db_hits": 1, "db_misses": 0,
                        "verify_fallbacks": 0, "analysis_rejects": 0}
    # a different MODE fingerprints differently -> fresh measurement
    h3 = ExecutionHarness(db=db, cfg=MeasureConfig(repeats=2, warmup=0,
                                                   mode="xla"))
    h3.measure(task, task)
    assert h3.stats["db_misses"] == 1 and h3.stats["measured"] == 1


def test_injected_runner_bypasses_execution():
    h = ExecutionHarness(runner=lambda task, prog, tgt: 42.0)
    s = h.measure(_tiny_matmul(), _tiny_matmul())
    assert s.time_s == 42.0 and s.mode == "injected"


# ---------------------------------------------------------------------------
# measured reranking through the pipeline
# ---------------------------------------------------------------------------

def test_pipeline_reranks_to_measured_winner():
    task = _tiny_fused()
    store = TranspositionStore()
    coder = StructuredMicroCoder()
    out = BeamSearch().search(task, coder=coder, store=store)
    assert len(out.candidates) >= 3
    # force a specific non-best candidate to "run fastest"
    want = out.candidates[2][1]
    want_fp = want.fingerprint()

    def runner(task_, prog, tgt):
        return 1e-3 if prog.fingerprint() == want_fp else 1e-2

    h = ExecutionHarness(runner=runner)
    pipe = MTMCPipeline(strategy="beam", store=store, measurer=h,
                        rerank_top_k=4)
    res = pipe.optimize(task)
    assert res.reranked
    assert res.program.fingerprint() == want_fp
    assert res.correct
    assert res.measured_s == pytest.approx(1e-3)
    assert res.measured_baseline_s == pytest.approx(1e-2)
    assert res.measured_speedup == pytest.approx(10.0)
    # candidates of every strategy include the analytic winner + task
    fps = {p.fingerprint() for _, p in out.candidates}
    assert out.program.fingerprint() in fps
    assert task.fingerprint() in fps


def test_rerank_noop_without_measurer():
    task = _tiny_fused()
    store = TranspositionStore()
    a = MTMCPipeline(strategy="beam", store=store).optimize(task)
    assert not a.reranked and a.measured_s is None \
        and a.measured_speedup is None


# ---------------------------------------------------------------------------
# KernelService: measured mode + restart warm start
# ---------------------------------------------------------------------------

def test_service_measured_warm_start_across_restart(tmp_path):
    from repro.serve.engine import KernelService
    task = _tiny_fused()
    db_dir = str(tmp_path / "svc_db")
    cfg = MeasureConfig(repeats=2, warmup=0)
    svc = KernelService(strategy="beam", measure=True,
                        measure_db=db_dir, rerank_top_k=3,
                        measure_cfg=cfg, max_steps=3)
    r1 = svc.optimize(task)
    st1 = svc.stats()
    svc.close()
    assert r1.correct and r1.measured_s is not None
    assert st1["measured"] > 0 and st1["warm_starts"] == 0

    # "restart": a fresh service (fresh store, fresh engine) on the
    # same DB directory answers the repeat request WITHOUT re-running
    # the search or any measurement
    svc2 = KernelService(strategy="beam", measure=True,
                         measure_db=db_dir, rerank_top_k=3,
                         measure_cfg=cfg, max_steps=3)
    r2 = svc2.optimize(task)
    st2 = svc2.stats()
    svc2.close()
    assert r2.correct
    assert r2.program.fingerprint() == r1.program.fingerprint()
    assert st2["warm_starts"] == 1
    assert st2["fresh_applies"] == 0      # no search ran
    assert st2["measured"] == 0           # no timing ran
    assert r2.speedup == pytest.approx(r1.speedup)


def test_warm_start_is_seed_scoped(tmp_path):
    """A winner recorded for seed=0 must not answer a seed=7 request:
    seeds are distinct questions (the coalescing key already refuses to
    merge them, and anneal-style strategies are seed-dependent)."""
    from repro.serve.engine import KernelService
    task = _tiny_fused()
    db_dir = str(tmp_path / "svc_db")
    cfg = MeasureConfig(repeats=2, warmup=0)
    svc = KernelService(strategy="beam", measure=True,
                        measure_db=db_dir, rerank_top_k=2,
                        measure_cfg=cfg, max_steps=2)
    svc.optimize(task, seed=0)
    svc.close()
    svc2 = KernelService(strategy="beam", measure=True,
                         measure_db=db_dir, rerank_top_k=2,
                         measure_cfg=cfg, max_steps=2)
    svc2.optimize(task, seed=7)       # different question: fresh search
    st = svc2.stats()
    svc2.close()
    assert st["warm_starts"] == 0 and st["fresh_applies"] > 0
    # ... while the SAME seed does warm-start
    svc3 = KernelService(strategy="beam", measure=True,
                         measure_db=db_dir, rerank_top_k=2,
                         measure_cfg=cfg, max_steps=2)
    svc3.optimize(task, seed=0)
    assert svc3.stats()["warm_starts"] == 1
    svc3.close()


def test_warm_start_is_search_config_scoped(tmp_path):
    """A winner recorded at max_steps=2 must not answer a max_steps=4
    restart: a deeper search is a different question, and env_fp only
    covers the MEASUREMENT configuration."""
    from repro.serve.engine import KernelService
    task = _tiny_fused()
    db_dir = str(tmp_path / "svc_db")
    cfg = MeasureConfig(repeats=2, warmup=0)
    svc = KernelService(strategy="beam", measure=True,
                        measure_db=db_dir, rerank_top_k=2,
                        measure_cfg=cfg, max_steps=2)
    svc.optimize(task)
    svc.close()
    svc2 = KernelService(strategy="beam", measure=True,
                         measure_db=db_dir, rerank_top_k=2,
                         measure_cfg=cfg, max_steps=4)
    svc2.optimize(task)
    st = svc2.stats()
    svc2.close()
    assert st["warm_starts"] == 0 and st["fresh_applies"] > 0


def test_fit_calibration_refuses_mixed_envs():
    a = _sample(task_fp="ta", prog_fp="pa", env_fp="env_one")
    b = _sample(task_fp="tb", prog_fp="pb", env_fp="env_two")
    with pytest.raises(ValueError):
        fit_calibration([a, b])
    fit = fit_calibration([a, b], allow_mixed_envs=True)
    assert fit.factors        # explicit opt-in still fits


def test_program_json_refuses_non_scalar_attrs():
    from repro.core.kernel_ir import KernelProgram, OpNode, TensorSpec
    bad = KernelProgram(
        name="bad", inputs=(("x", TensorSpec((4, 4))),),
        nodes=(OpNode("y", "relu", ("x",), (("perm", (0, 1)),)),),
        outputs=("y",), fusion_groups=(("y",),), schedules=())
    with pytest.raises(TypeError):
        program_to_json(bad)


def test_service_ignores_stale_winner_that_fails_oracle(tmp_path):
    """A winners/ record that no longer passes the live oracle (repo
    semantics changed under an unchanged env fingerprint) must fall
    through to a fresh search, not be served as correct=False forever."""
    from repro.serve.engine import KernelService
    task = _tiny_matmul()
    db_dir = str(tmp_path / "svc_db")
    cfg = MeasureConfig(repeats=2, warmup=0)
    svc = KernelService(strategy="beam", measure=True,
                        measure_db=db_dir, rerank_top_k=2,
                        measure_cfg=cfg, max_steps=2)
    # poison the winner record with a program computing something else
    wrong = chain_program("tiny_mm", {"a": (256, 256), "b": (256, 256)},
                          [("y", "relu", ("a",))])
    key = svc._winner_db_key(task, None, None)
    svc.harness.db.put_winner(*key, {
        "task": task.name, "program": program_to_json(wrong),
        "speedup": 9.9, "steps": 1, "measured_s": 1e-6,
        "measured_baseline_s": 1e-6, "reranked": True})
    res = svc.optimize(task)
    st = svc.stats()
    svc.close()
    assert res.correct
    assert res.program.eval_fingerprint() == task.eval_fingerprint()
    assert st["warm_starts"] == 0
    assert st["fresh_applies"] > 0        # a real search ran
    # ... and the fresh result overwrote the stale record
    db = MeasureDB(db_dir)
    fixed = db.get_winner(*key)
    assert program_from_json(fixed["program"]).eval_fingerprint() == \
        task.eval_fingerprint()


def test_service_stats_expose_measure_counters_without_measurer():
    from repro.serve.engine import KernelService
    svc = KernelService(max_steps=1)
    st = svc.stats()
    svc.close()
    assert st["measured"] == 0 and st["db_hits"] == 0 \
        and st["db_misses"] == 0 and st["warm_starts"] == 0


def test_calibration_bucket_report_and_fitted_flags():
    """Per-bucket sample counts make a degenerate fit VISIBLE: a bucket
    under min_samples reports 'fallback' and fitted() is False, so a
    whole-target no-op calibration (the gpu_a100 case) cannot pass for
    a real fit in measure_bench output."""
    samples = [_sample(task_fp=f"t{i}", prog_fp=f"p{i}",
                       time_s=2e-3 * (i + 1), analytic_s=1e-3 * (i + 1),
                       bottleneck="memory") for i in range(3)]
    samples.append(_sample(task_fp="tc", prog_fp="pc", time_s=1e-3,
                           analytic_s=1e-3, bottleneck="compute"))
    fit = fit_calibration(samples)
    assert fit.fitted("tpu_v5e", "memory")
    assert not fit.fitted("tpu_v5e", "compute")      # n=1 < min_samples
    assert not fit.fitted("gpu_a100", "memory")      # unseen bucket
    assert fit.count_map[("tpu_v5e", "memory")] == 3
    report = fit.bucket_report("tpu_v5e")
    assert any("memory" in ln and "(n=3, fitted)" in ln
               for ln in report)
    assert any("compute" in ln and "(n=1, fallback)" in ln
               for ln in report)
    # a single-sample bucket keeps the identity factor, not a 1-point fit
    assert fit.factor("tpu_v5e", "compute") == 1.0


def test_calibration_from_json_backward_compat():
    """Pre-min_samples JSON (older committed calibrations) loads with
    the default threshold instead of KeyError."""
    fit = Calibration(factors=((("tpu_v5e", "memory"), 2.0),),
                      n_samples=((("tpu_v5e", "memory"), 4),))
    d = fit.to_json()
    del d["min_samples"]
    loaded = Calibration.from_json(d)
    assert loaded.min_samples == 2
    assert loaded.fitted("tpu_v5e", "memory")


def test_iter_samples_deterministic_and_counts_corrupt(tmp_path):
    db = MeasureDB(str(tmp_path / "db"))
    for i in range(4):
        db.put(_sample(task_fp=f"t{i}", prog_fp=f"p{i}",
                       env_fp="e0" if i < 2 else "e1"))
    # one torn file and one well-formed JSON missing sample fields
    sdir = os.path.join(db.path, "samples")
    with open(os.path.join(sdir, "aaaa.json"), "w") as f:
        f.write("{not json")
    with open(os.path.join(sdir, "bbbb.json"), "w") as f:
        f.write('{"task_fp": "orphan"}')
    got = [s.task_fp for s in db.iter_samples()]
    assert sorted(got) == ["t0", "t1", "t2", "t3"]
    assert db.stats_dict()["corrupt_records"] == 2
    assert got == [s.task_fp for s in db.iter_samples()]   # stable order
    assert [s.task_fp for s in db.iter_samples(env_fp="e1")] \
        == sorted(["t2", "t3"])
    assert db.env_fps() == ["e0", "e1"]
    assert db.env_fps(target="gpu_a100") == []


def test_sample_json_omits_absent_program():
    """Byte-stability for pre-§17 fixtures: a program-less sample's JSON
    has no 'program' key at all (old committed files round-trip
    unchanged), while an embedded program survives the round trip."""
    bare = _sample()
    assert "program" not in bare.to_json()
    assert MeasureSample.from_json(bare.to_json()).program is None
    prog = _tiny_matmul()
    rich = MeasureSample(
        task_fp="t", prog_fp=prog.fingerprint(), target="tpu_v5e",
        env_fp="e", time_s=1e-3, samples=(1e-3,), n_rejected=0,
        mode="xla", analytic_s=1e-3, bottleneck="compute",
        program=program_to_json(prog))
    back = MeasureSample.from_json(rich.to_json())
    assert program_from_json(back.program).fingerprint() == \
        prog.fingerprint()


def test_harness_embeds_program_in_samples(tmp_path):
    """measure() writes self-contained training data: the sample's
    embedded program round-trips to the measured program's
    fingerprint (DESIGN.md §17)."""
    db = MeasureDB(str(tmp_path / "db"))
    h = ExecutionHarness(db=db, runner=lambda task, prog, tgt: 1e-3)
    task = _tiny_matmul()
    s = h.measure(task, task, target="tpu_v5e")
    assert s.program is not None
    assert program_from_json(s.program).fingerprint() == \
        task.fingerprint()
    # and it persists through the DB round trip
    (stored,) = list(db.iter_samples())
    assert program_from_json(stored.program).fingerprint() == \
        task.fingerprint()


def test_fixture_db_winner_loads():
    """The committed fixture's winner record round-trips into a program
    with the live task's fingerprint (serialization stability)."""
    db = MeasureDB(FIXTURE_DB)
    task = T.kb_level1()[0]
    rec = db.get_winner(task.fingerprint(), "tpu_v5e", "fixture000000")
    assert rec is not None
    assert program_from_json(rec["program"]).fingerprint() == \
        task.fingerprint()
