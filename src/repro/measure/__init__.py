"""Measured execution: profile-guided reranking, cost-model calibration,
and a persistent measurement DB (DESIGN.md §11).

``timing`` is dependency-free (imported by ``launch/dryrun.py`` BEFORE
jax initializes, so it must stay jax-clean); the jax-importing
submodules are loaded lazily on attribute access.
"""
from repro.measure.timing import (robust_time_s, stopwatch,  # noqa: F401
                                  time_thunk)

_LAZY = {
    "ExecutionHarness": "repro.measure.harness",
    "LoweredProgram": "repro.measure.harness",
    "MeasureConfig": "repro.measure.harness",
    "MeasureError": "repro.measure.harness",
    "lower_program": "repro.measure.harness",
    "MeasureDB": "repro.measure.db",
    "MeasureSample": "repro.measure.db",
    "env_fingerprint": "repro.measure.db",
    "Calibration": "repro.measure.calibrate",
    "CalibratedCostModel": "repro.measure.calibrate",
    "fit_calibration": "repro.measure.calibrate",
    "spearman": "repro.measure.calibrate",
    "FEATURE_NAMES": "repro.measure.learned",
    "LearnedCostModel": "repro.measure.learned",
    "LearnedModel": "repro.measure.learned",
    "featurize": "repro.measure.learned",
    "fit_learned_model": "repro.measure.learned",
    "resolve_cost_model": "repro.measure.learned",
}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(name)
    import importlib
    return getattr(importlib.import_module(mod), name)
