"""Model/run configuration system.

One ``ModelConfig`` describes any architecture in the zoo (dense / moe /
rwkv / hybrid / encdec / vlm).  Exact assigned configs live in
``src/repro/configs/<id>.py``; each exposes ``CONFIG``.

``normalize_for_mesh`` applies the TP padding policy (q-heads and vocab are
padded up to multiples of the model-axis size; zero-padded rows/cols keep
the math exact — see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

Family = Literal["dense", "moe", "rwkv", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                      # 0 -> d_model // n_heads
    # dense-family options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # ssm / rwkv
    ssm_state: int = 0
    ssm_expand: int = 2
    # hybrid (hymba): sliding-window layers + a few global layers
    swa_window: int = 0
    global_layers: tuple[int, ...] = ()
    # vlm / audio: length of precomputed frontend embeddings (stub)
    prefix_len: int = 0
    # encdec
    encoder_layers: int = 0
    cross_attention: bool = False
    enc_len: int = 1536                    # encoder sequence for serve shapes
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    # book-keeping for padding (set by normalize_for_mesh)
    true_n_heads: int = 0
    true_vocab_size: int = 0
    # which shapes this arch supports (see configs/shapes.py)
    supports_long_context: bool = False    # sub-quadratic path exists
    has_decoder: bool = True

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.true_n_heads == 0:
            object.__setattr__(self, "true_n_heads", self.n_heads)
        if self.true_vocab_size == 0:
            object.__setattr__(self, "true_vocab_size", self.vocab_size)

    # ----- derived quantities -------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def group_size(self) -> int:
        return self.n_heads // math.gcd(self.n_heads, self.n_kv_heads) \
            if self.n_kv_heads else 0

    def n_params(self, active_only: bool = False) -> int:
        """Parameter count (true, unpadded).  MoE: total or active."""
        d, ff, v = self.d_model, self.d_ff, self.true_vocab_size
        hd = self.head_dim
        attn = d * self.true_n_heads * hd * 2 + d * self.kv_dim * 2
        if self.qkv_bias:
            attn += self.true_n_heads * hd + 2 * self.kv_dim
        mlp = 3 * d * ff
        if self.family == "moe":
            n_e = self.top_k if active_only else self.n_experts
            mlp = 3 * d * ff * n_e + d * self.n_experts  # + router
        if self.family == "rwkv":
            # time-mix projections r,k,v,g,o + decay lora + channel-mix
            attn = 5 * d * d + 2 * d * 64
            mlp = 2 * d * ff
        if self.family == "hybrid":
            d_in = self.ssm_expand * d
            attn += 2 * d * d_in + d_in * d + d_in * (2 * self.ssm_state + 1)
        norms = 2 * d
        per_layer = attn + mlp + norms
        n_l = self.n_layers + self.encoder_layers
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = per_layer * n_l + emb + d
        if self.cross_attention:
            total += self.n_layers * (attn + d)
        return int(total)


def normalize_for_mesh(cfg: ModelConfig, tp: int) -> ModelConfig:
    """Pad q-heads and vocab to multiples of the tensor-parallel degree.

    Zero-padded q-heads attend uniformly but their W_o rows are zero, so
    the output is exact; padded vocab logits are masked at the loss.

    GQA: the padded q-head count must stay a multiple of n_kv_heads
    (grouping correctness) — if it can't (hymba: 25 q / 5 kv with tp=16),
    heads are left unpadded and the attention projections replicate
    across the model axis instead (divisibility-aware ShardingRules);
    the MLP/embedding still shard.  MHA-style families (n_kv == n_heads,
    incl. rwkv) pad q and kv together.
    """
    n_heads = -(-cfg.n_heads // tp) * tp
    n_kv = cfg.n_kv_heads
    if n_kv and n_kv == cfg.n_heads:
        n_kv = n_heads                       # MHA / rwkv: pad together
    elif n_kv and n_heads % n_kv != 0:
        n_heads = cfg.n_heads                # GQA unsatisfiable: no pad
    vocab = -(-cfg.vocab_size // tp) * tp
    return dataclasses.replace(
        cfg, n_heads=n_heads, n_kv_heads=n_kv, vocab_size=vocab,
        true_n_heads=cfg.true_n_heads, true_vocab_size=cfg.true_vocab_size)


# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: training or serving geometry."""
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(applicable?, reason-if-not). Encodes the skip policy of the spec."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("full quadratic attention only; 500k KV cache is "
                       "out of scope per spec (sub-quadratic archs only)")
    if shape.kind == "decode" and not cfg.has_decoder:
        return False, "encoder-only architecture has no decode step"
    return True, ""


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Training/serving hyper-parameters attached to a model+shape."""
    microbatch_bytes_budget: float = 2.5e9   # per-device activation budget
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    fsdp: bool = True
    remat: bool = True
    accum_steps: int = 0   # 0 = auto from memory budget
    # §Perf: all-gather FSDP params ONCE per step (outside the microbatch
    # loop) instead of per microbatch; grads reduce-scatter once at the
    # end.  Trades a held bf16 param copy for ~accum x less ICI traffic.
    gather_once: bool = False


def auto_accum_steps(cfg: ModelConfig, shape: ShapeConfig, dp: int,
                     budget_bytes: float = 2.5e9) -> int:
    """Pick grad-accumulation so per-device live activations fit budget.

    Live set under scan+remat = one residual stream per layer
    (B_local, S, d) bf16 + logits for the live microbatch.
    """
    if shape.kind != "train":
        return 1
    b_local = max(1, shape.global_batch // dp)
    n_l = cfg.n_layers + cfg.encoder_layers
    per_batch_row = shape.seq_len * cfg.d_model * 2 * n_l
    accum = 1
    while b_local // accum > 1 and (b_local // accum) * per_batch_row > budget_bytes:
        accum *= 2
    return accum
