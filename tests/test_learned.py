"""Learned cost model (measure/learned.py, DESIGN.md §17).

Covers: the featurizer contract (golden vectors, permutation
invariance, never-raises over the committed suites x the legal action
space), the ridge fit + artifact round trip, fallback semantics (no
model = analytic identity; out-of-distribution = scaled analytic),
spec resolution into every entry point (``OptimizeConfig.cost_model``,
``get_reward_source``), the trainer CLI, and the committed
``tests/fixtures/learned_db`` training fixture.

Golden/fixture regeneration: ``REPRO_BLESS=1 pytest tests/test_learned.py``.
The fixture DB's wall times are a fixed log-linear function of the
feature vector — no clock is involved, so regeneration is deterministic
and the ridge can recover the function (fit rho ~ 1), which is exactly
what makes the fixture a meaningful CI training corpus.
"""
import json
import math
import os
import pickle

import numpy as np
import pytest

from repro.core import actions as A, cost_model, hardware, tasks as T
from repro.core.engine import TranspositionStore
from repro.core.env import LearnedRewardSource, get_reward_source
from repro.core.kernel_ir import chain_program, program_from_json, \
    program_to_json
from repro.core.micro_coding import StructuredMicroCoder
from repro.core.search import BeamSearch
from repro.measure.db import MeasureDB, MeasureSample
from repro.measure.learned import (FEATURE_NAMES, FEATURE_VERSION,
                                   LearnedCostModel, LearnedModel,
                                   featurize, fit_learned_model,
                                   grouped_spearman, resolve_cost_model)

HERE = os.path.dirname(__file__)
FIXTURE_DB = os.path.join(HERE, "fixtures", "learned_db")
GOLDEN = os.path.join(HERE, "golden", "learned", "features.json")

_FIXTURE_TASKS = ("L1_matmul_0", "L1_rmsnorm", "L2_gemm_bias_relu")
_FIXTURE_TARGETS = ("tpu_v5e", "gpu_a100")
# frozen fingerprints: the fixture must train on any machine/jax, so it
# never goes through env_fingerprint() (which hashes the live backend)
_FIXTURE_ENV_FP = {"tpu_v5e": "learnedfx-tpu0",
                   "gpu_a100": "learnedfx-gpu0"}
_FIXTURE_TOP_K = 4


def _round12(vec):
    return [float(f"{float(v):.12g}") for v in vec]


def _by_name():
    return {t.name: t for t in T.kb_level1() + T.kb_level2()}


def _synthetic_time_s(prog, target) -> float:
    """Deterministic stand-in wall clock: a fixed log-linear function
    of the feature vector (learnable by the ridge, stable across
    machines up to libm ulps, absorbed by the 9-sig-digit round)."""
    x = featurize(prog, target)
    i = {n: j for j, n in enumerate(FEATURE_NAMES)}
    # terms chosen to vary WITHIN a task's beam candidates (pipeline
    # depth, loop order, grid shape), so every fixture group carries
    # ranking signal instead of ties
    log_t = (float(x[i["log_analytic_s"]]) + 7.5
             + 0.35 * float(x[i["log_grid_cells"]])
             - 0.015 * float(x[i["min_eff_tile"]])
             + 0.4 * float(x[i["frac_divisible"]])
             + 0.15 * float(x[i["mean_pipeline_depth"]])
             + 0.3 * float(x[i["frac_reordered"]]))
    return float(f"{math.exp(log_t):.9g}")


def _fixture_samples(target: str) -> list[MeasureSample]:
    tgt = hardware.resolve(target)
    by_name = _by_name()
    store = TranspositionStore()
    coder = StructuredMicroCoder()
    out = []
    for name in _FIXTURE_TASKS:
        task = by_name[name]
        res = BeamSearch().search(task, coder=coder, store=store,
                                  target=target, max_steps=3)
        progs = [p for _, p in res.candidates[:_FIXTURE_TOP_K]]
        assert len(progs) >= 2, f"{name}: not enough candidates"
        for p in progs:
            t = _synthetic_time_s(p, tgt)
            pc = cost_model.program_cost(p, tgt)
            out.append(MeasureSample(
                task_fp=task.fingerprint(), prog_fp=p.fingerprint(),
                target=tgt.name, env_fp=_FIXTURE_ENV_FP[target],
                time_s=t, samples=(t,), n_rejected=0, mode="fixture",
                analytic_s=pc.total_s,
                bottleneck=pc.bottleneck.split(":")[-1],
                env=(("backend", "fixture"), ("mode", "fixture"),
                     ("target", tgt.name)),
                program=program_to_json(p)))
    return out


# ---------------------------------------------------------------------------
# featurizer contract
# ---------------------------------------------------------------------------

def test_feature_names_schema():
    assert len(FEATURE_NAMES) == len(set(FEATURE_NAMES))
    for t in T.kb_level1()[:2]:
        x = featurize(t, "tpu_v5e")
        assert x.shape == (len(FEATURE_NAMES),)
        assert np.all(np.isfinite(x))


def test_golden_feature_vectors():
    """Feature extraction is part of the artifact contract: a committed
    model's weights only mean something against the exact vectors they
    were fit on.  12 significant digits on both sides absorbs libm
    1-ulp drift while catching any real featurizer change."""
    by_name = _by_name()
    cases = {}
    for name, target in [("L1_matmul_0", "tpu_v5e"),
                         ("L1_rmsnorm", "tpu_v5e"),
                         ("L1_attention", "gpu_a100"),
                         ("L2_gemm_bias_relu", "gpu_a100")]:
        cases[f"{name}__{target}"] = _round12(
            featurize(by_name[name], target))
    if os.environ.get("REPRO_BLESS"):
        os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
        with open(GOLDEN, "w") as f:
            json.dump({"feature_version": FEATURE_VERSION,
                       "feature_names": list(FEATURE_NAMES),
                       "vectors": cases}, f, indent=1, sort_keys=True)
    with open(GOLDEN) as f:
        golden = json.load(f)
    assert golden["feature_version"] == FEATURE_VERSION
    assert golden["feature_names"] == list(FEATURE_NAMES)
    for key, vec in cases.items():
        assert golden["vectors"][key] == vec, \
            f"{key}: featurizer drifted (REPRO_BLESS=1 to re-bless " \
            f"AND retrain committed artifacts)"


def test_featurize_input_order_invariant():
    p1 = chain_program("perm", {"a": (128, 64), "b": (64, 32)},
                       [("y", "matmul", ("a", "b"))])
    p2 = chain_program("perm", {"b": (64, 32), "a": (128, 64)},
                       [("y", "matmul", ("a", "b"))])
    for target in _FIXTURE_TARGETS:
        assert np.array_equal(featurize(p1, target),
                              featurize(p2, target))


def test_featurize_parallel_chain_order_invariant():
    """Two independent fused chains contribute order-invariant
    aggregates: listing them in either order gives the same vector."""
    ops1 = [("u", "relu", ("a",)), ("v", "gelu", ("b",))]
    ops2 = [("v", "gelu", ("b",)), ("u", "relu", ("a",))]
    inputs = {"a": (256, 128), "b": (256, 128)}
    p1 = chain_program("par", inputs, ops1, outputs=("u", "v"))
    p2 = chain_program("par", inputs, ops2, outputs=("u", "v"))
    assert np.array_equal(featurize(p1, "tpu_v5e"),
                          featurize(p2, "tpu_v5e"))


_SUITE = None


def _suite():
    global _SUITE
    if _SUITE is None:
        _SUITE = T.kb_level1() + T.kb_level2()
    return _SUITE


def test_featurize_never_raises_over_action_space():
    """Featurization must accept anything the legal action space can
    produce on the committed suites — the cost model sits inside the
    search loop, where a throw would kill the whole optimization."""
    coder = StructuredMicroCoder()
    for task in _suite():
        acts = A.candidate_actions(task, target="tpu_v5e",
                                   extended=True)
        for act in acts[:6]:
            res = coder.apply(task, act)
            prog = res.program if res.status == "ok" else task
            for target in _FIXTURE_TARGETS:
                x = featurize(prog, target)
                assert x.shape == (len(FEATURE_NAMES),)
                assert np.all(np.isfinite(x))


# ---------------------------------------------------------------------------
# fixture DB + fit
# ---------------------------------------------------------------------------

def test_fixture_db_blessed_and_trainable():
    if os.environ.get("REPRO_BLESS"):
        db = MeasureDB(FIXTURE_DB)
        db.clear()
        for target in _FIXTURE_TARGETS:
            for s in _fixture_samples(target):
                db.put(s)
    db = MeasureDB(FIXTURE_DB)
    samples = list(db.iter_samples())
    assert len(samples) == (len(_FIXTURE_TASKS) * _FIXTURE_TOP_K
                            * len(_FIXTURE_TARGETS))
    assert all(s.program is not None for s in samples)
    # embedded programs round-trip to their recorded fingerprints
    for s in samples[:4]:
        assert program_from_json(s.program).fingerprint() == s.prog_fp
    model = fit_learned_model(samples, allow_mixed_envs=True)
    assert model is not None
    m = model.meta
    assert m["n_samples"] == len(samples)
    assert m["targets"] == sorted(_FIXTURE_TARGETS)
    assert sorted(m["env_fps"]) == sorted(_FIXTURE_ENV_FP.values())
    # the synthetic times are a log-linear feature function: the ridge
    # must essentially recover it (exact rho 1.0 is unreachable — some
    # beam candidates are feature-identical, so their synthetic times
    # tie and the untied spearman pays for it)
    assert m["spearman_fit"] > 0.8


def test_fixture_db_regeneration_matches_committed():
    """The generator in this file reproduces the committed fixture
    byte-for-byte (modulo nothing): candidates, synthetic times and
    serialization are all deterministic."""
    db = MeasureDB(FIXTURE_DB)
    committed = {(s.task_fp, s.prog_fp, s.target): s
                 for s in db.iter_samples()}
    for target in _FIXTURE_TARGETS:
        for s in _fixture_samples(target):
            got = committed.pop((s.task_fp, s.prog_fp, s.target))
            assert got.to_json() == s.to_json()
    assert not committed


def test_fixture_fit_single_env_needs_no_flag():
    db = MeasureDB(FIXTURE_DB)
    model = fit_learned_model(db.iter_samples(target="tpu_v5e"))
    assert model is not None
    assert model.meta["targets"] == ["tpu_v5e"]


def test_fit_refuses_mixed_envs_by_default():
    db = MeasureDB(FIXTURE_DB)
    with pytest.raises(ValueError, match="env"):
        fit_learned_model(db.iter_samples())


def test_fit_skips_program_less_and_returns_none_when_empty():
    db = MeasureDB(FIXTURE_DB)
    bare = [MeasureSample(
        task_fp=s.task_fp, prog_fp=s.prog_fp, target=s.target,
        env_fp=s.env_fp, time_s=s.time_s, samples=s.samples,
        n_rejected=0, mode=s.mode, analytic_s=s.analytic_s,
        bottleneck=s.bottleneck) for s in db.iter_samples()]
    assert fit_learned_model(bare, allow_mixed_envs=True) is None


def test_grouped_spearman_is_per_group():
    # two groups with opposite global trends but perfect internal rank
    preds = [1.0, 2.0, 3.0, 11.0, 12.0, 13.0]
    ys = [10.0, 20.0, 30.0, 1.0, 2.0, 3.0]
    groups = ["a", "a", "a", "b", "b", "b"]
    assert grouped_spearman(preds, ys, groups) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# model semantics: identity, prediction, fallback
# ---------------------------------------------------------------------------

def _fixture_model() -> LearnedModel:
    return fit_learned_model(MeasureDB(FIXTURE_DB).iter_samples(),
                             allow_mixed_envs=True)


def test_no_model_is_analytic_identity():
    lcm = LearnedCostModel()
    for task in _suite()[:3]:
        base = cost_model.program_cost(task, "tpu_v5e")
        got = lcm.program_cost(task, "tpu_v5e")
        assert got.total_s == base.total_s
        assert [g.time_s for g in got.groups] == \
            [g.time_s for g in base.groups]
    assert lcm.stats == {"predicted": 0, "fallbacks": 0}


def test_missing_artifact_loads_as_identity(tmp_path):
    lcm = LearnedCostModel.load(str(tmp_path / "nope.pkl"))
    assert lcm.model is None and lcm.meta == {}
    with pytest.raises(FileNotFoundError):
        LearnedCostModel.load(str(tmp_path / "nope.pkl"),
                              missing_ok=False)


def test_model_predicts_in_distribution_and_scales_groups():
    model = _fixture_model()
    lcm = LearnedCostModel(model)
    task = _by_name()["L1_matmul_0"]
    pc = lcm.program_cost(task, "tpu_v5e")
    assert lcm.stats["predicted"] == 1 and lcm.stats["fallbacks"] == 0
    pred = model.predict_log_s(featurize(task, "tpu_v5e"))
    assert pc.total_s == pytest.approx(math.exp(pred), rel=1e-9)
    # groups scale uniformly: their sum is the prediction
    assert sum(g.time_s for g in pc.groups) == pytest.approx(
        pc.total_s, rel=1e-9)


def test_ood_fallback_is_scaled_analytic():
    """A model whose training envelope excludes everything must fall
    back — to analytic LIFTED by fallback_log_scale, so an OOD program
    stays on the measured-seconds scale and rankable against predicted
    siblings (not ~e^8 cheaper)."""
    d = len(FEATURE_NAMES)
    model = LearnedModel(
        weights=np.zeros(d), intercept=0.0, mean=np.full(d, 1e9),
        std=np.ones(d), lo=np.zeros(d), hi=np.zeros(d),
        feature_names=FEATURE_NAMES, ridge_lambda=1.0,
        meta={"kind": "learned_cost_model"}, fallback_log_scale=2.0)
    lcm = LearnedCostModel(model)
    task = _by_name()["L1_matmul_0"]
    base = cost_model.program_cost(task, "tpu_v5e")
    got = lcm.program_cost(task, "tpu_v5e")
    assert lcm.stats["fallbacks"] == 1
    assert got.total_s == pytest.approx(base.total_s * math.exp(2.0),
                                        rel=1e-9)


def test_schema_drift_declines_prediction():
    model = _fixture_model()
    stale = LearnedModel(
        weights=model.weights, intercept=model.intercept,
        mean=model.mean, std=model.std, lo=model.lo, hi=model.hi,
        feature_names=("bogus",) + model.feature_names[1:],
        ridge_lambda=model.ridge_lambda, meta=model.meta)
    assert stale.predict_log_s(
        featurize(_by_name()["L1_matmul_0"], "tpu_v5e")) is None


def test_ood_tolerates_few_but_not_many_outliers():
    model = _fixture_model()
    x = featurize(_by_name()["L1_matmul_0"], "tpu_v5e").copy()
    assert model.predict_log_s(x) is not None
    # a couple of coordinates far out of range: still extrapolates
    x2 = x.copy()
    x2[:2] = 1e9
    assert model.predict_log_s(x2) is not None
    # an alien vector: declines
    assert model.predict_log_s(np.full(len(x), 1e9)) is None


# ---------------------------------------------------------------------------
# artifact persistence
# ---------------------------------------------------------------------------

def test_artifact_round_trip_is_deterministic(tmp_path):
    """Two independent fits of the same data serialize byte-identically
    (retraining in CI is reproducible), and load -> save is idempotent.
    The two halves are checked separately because a FIRST save may
    legally differ in pickle memo refs from a re-save: a freshly fit
    blob shares interned string objects the unpickled one does not."""
    p = [str(tmp_path / f"{i}.pkl") for i in range(4)]
    model = _fixture_model()
    model.save(p[0])
    _fixture_model().save(p[1])
    with open(p[0], "rb") as f0, open(p[1], "rb") as f1:
        assert f0.read() == f1.read()
    LearnedModel.load(p[0]).save(p[2])
    LearnedModel.load(p[2]).save(p[3])
    with open(p[2], "rb") as f2, open(p[3], "rb") as f3:
        assert f2.read() == f3.read()
    loaded = LearnedModel.load(p[2])
    x = featurize(_by_name()["L1_rmsnorm"], "tpu_v5e")
    assert loaded.predict_log_s(x) == model.predict_log_s(x)
    with open(p[0], "rb") as f:
        blob = pickle.load(f)
    assert blob["kind"] == "learned_cost_model"
    assert blob["meta"]["feature_version"] == FEATURE_VERSION


def test_resolve_cost_model_specs(tmp_path):
    from repro.measure.calibrate import (CalibratedCostModel,
                                         Calibration)
    assert resolve_cost_model(None) is None
    assert resolve_cost_model("analytic") is None
    lcm = LearnedCostModel()
    assert resolve_cost_model(lcm) is lcm
    path = str(tmp_path / "m.pkl")
    _fixture_model().save(path)
    got = resolve_cost_model(f"learned:{path}")
    assert isinstance(got, LearnedCostModel)
    assert got.meta["kind"] == "learned_cost_model"
    # missing artifact = analytic identity, never an error
    absent = resolve_cost_model(f"learned:{tmp_path}/absent.pkl")
    assert isinstance(absent, LearnedCostModel) and absent.model is None
    cal = Calibration(((("tpu_v5e", "memory"), 2.0),),
                      ((("tpu_v5e", "memory"), 4),))
    cal_path = str(tmp_path / "cal.json")
    cal.save(cal_path)
    got = resolve_cost_model(f"calibrated:{cal_path}")
    assert isinstance(got, CalibratedCostModel)
    with pytest.raises(ValueError, match="cost_model spec"):
        resolve_cost_model("bogus")


# ---------------------------------------------------------------------------
# entry points: OptimizeConfig / engine / pipeline / reward source
# ---------------------------------------------------------------------------

def test_engine_resolves_spec_once_store_and_config_share(tmp_path):
    from repro.core import EvalEngine, OptimizeConfig
    path = str(tmp_path / "m.pkl")
    _fixture_model().save(path)
    eng = EvalEngine(config=OptimizeConfig(
        mode="greedy_cost", max_steps=2, validate=False,
        cost_model=f"learned:{path}"))
    cm = eng.config.cost_model
    assert isinstance(cm, LearnedCostModel)
    assert eng.store.cost_model is cm
    task = _by_name()["L1_matmul_0"]
    r = eng.optimize(task)
    assert r.speedup > 0


def test_pipeline_resolves_spec(tmp_path):
    from repro.core import MTMCPipeline, OptimizeConfig
    path = str(tmp_path / "m.pkl")
    _fixture_model().save(path)
    pipe = MTMCPipeline(config=OptimizeConfig(
        mode="greedy_cost", max_steps=2, validate=False,
        cost_model=f"learned:{path}"))
    assert isinstance(pipe.config.cost_model, LearnedCostModel)


def test_reward_source_learned_specs(tmp_path):
    path = str(tmp_path / "m.pkl")
    _fixture_model().save(path)
    rs = get_reward_source(f"learned:{path}")
    assert isinstance(rs, LearnedRewardSource)
    task = _by_name()["L1_matmul_0"]
    assert rs.cost(task, task, "tpu_v5e") > 0
    # bare "learned": fit live from a DB
    rs2 = get_reward_source("learned", db=MeasureDB(FIXTURE_DB))
    assert isinstance(rs2, LearnedRewardSource)
    assert rs2.model.model is not None
    with pytest.raises(ValueError, match="db"):
        get_reward_source("learned")


# ---------------------------------------------------------------------------
# trainer CLI
# ---------------------------------------------------------------------------

def test_train_cli_fits_from_fixture(tmp_path, capsys):
    from repro.measure.train_cost_model import main
    out = str(tmp_path / "model.pkl")
    rc = main([FIXTURE_DB, "--out", out, "--allow-mixed-envs"])
    assert rc == 0
    lcm = LearnedCostModel.load(out, missing_ok=False)
    assert lcm.meta["dbs"] == [FIXTURE_DB]
    assert "samples" in capsys.readouterr().out


def test_train_cli_mixed_envs_refused_without_flag(tmp_path):
    from repro.measure.train_cost_model import main
    rc = main([FIXTURE_DB, "--out", str(tmp_path / "m.pkl")])
    assert rc == 2


def test_train_cli_target_filter_single_env(tmp_path):
    from repro.measure.train_cost_model import main
    out = str(tmp_path / "m.pkl")
    rc = main([FIXTURE_DB, "--out", out, "--target", "gpu_a100"])
    assert rc == 0
    assert LearnedCostModel.load(out).meta["targets"] == ["gpu_a100"]


def test_train_cli_empty_db_fails(tmp_path):
    from repro.measure.train_cost_model import main
    empty = str(tmp_path / "empty_db")
    MeasureDB(empty)
    rc = main([empty, "--out", str(tmp_path / "m.pkl")])
    assert rc == 1
    assert not os.path.exists(str(tmp_path / "m.pkl"))


# ---------------------------------------------------------------------------
# analysis lint --artifact sweep
# ---------------------------------------------------------------------------

def test_lint_accepts_good_artifact_and_rejects_stale(tmp_path):
    from repro.analysis.lint import main as lint_main
    path = str(tmp_path / "m.pkl")
    _fixture_model().save(path)
    assert lint_main(["--artifact", path, "-q"]) == 0
    # stale feature schema: must fail, loudly
    blob = pickle.load(open(path, "rb"))
    blob["meta"]["feature_version"] = FEATURE_VERSION + 1
    stale = str(tmp_path / "stale.pkl")
    with open(stale, "wb") as f:
        pickle.dump(blob, f)
    assert lint_main(["--artifact", stale, "-q"]) != 0
    # non-finite weights: must fail
    blob = pickle.load(open(path, "rb"))
    blob["weights"] = np.full_like(np.asarray(blob["weights"]), np.nan)
    bad = str(tmp_path / "bad.pkl")
    with open(bad, "wb") as f:
        pickle.dump(blob, f)
    assert lint_main(["--artifact", bad, "-q"]) != 0
    # unreadable: must fail
    trunc = str(tmp_path / "trunc.pkl")
    with open(trunc, "wb") as f:
        f.write(b"\x80")
    assert lint_main(["--artifact", trunc, "-q"]) != 0
