"""Fleet-scale serving: N ``KernelService`` replicas over one shared
winner/measurement store (DESIGN.md §13).

A single ``KernelService`` already amortizes search cost within one
process (transposition store, request coalescing) and across restarts
(the on-disk ``MeasureDB`` winner records).  This module scales the
story past one replica:

* **Replicas.**  N independent ``KernelService`` instances — each its
  own transposition store and thread pool, exactly what N processes
  would hold — share one ``MeasureDB`` directory.  All cross-replica
  state flows through that directory under the DB's cross-process
  protocol (atomic replaces, winner generations, stamp-revalidated
  reads), so the same ``Fleet`` wiring is safe whether the replicas
  live in one process (this class) or in N separate ones (each process
  runs its own service/fleet on the shared directory — what
  ``benchmarks/serve_bench.py`` measures).

* **Admission control.**  ``submit`` rejects with ``AdmissionError``
  once ``max_pending`` requests are queued or dispatched — a saturated
  fleet sheds load at the door instead of growing an unbounded queue.

* **Per-tenant fairness.**  Requests queue per tenant and dispatch
  round-robin across tenants with work pending, so one tenant flooding
  the queue cannot starve another's occasional request; within a
  tenant, order is FIFO.

* **Affinity routing.**  By default a request routes to the replica
  owned by its key hash: concurrent duplicates land on the SAME
  replica and coalesce in its futures map, and a hot kernel's search
  substrate warms ONE store instead of N copies.  ``route="spread"``
  picks the least-loaded replica instead (better for streams of
  all-distinct kernels).

* **Background measured refinement (hot-swap).**  Replicas answer from
  the analytic pick immediately (``rerank_top_k=0`` — no timing on the
  request path).  Every analytically-answered key is queued for a
  refiner service that re-runs the same question WITH measured
  reranking and upgrades the shared winner record (generation bump;
  the service merge policy keeps analytic picks from downgrading it).
  The next repeat request warm-starts from the measured record — the
  analytic answer is hot-swapped for the measured one mid-stream,
  with zero measurement latency on any serving path.
"""
from __future__ import annotations

import collections
import concurrent.futures as cf
import dataclasses
import threading

from repro.core.config import UNSET, resolve_config
from repro.serve.engine import KernelService


class AdmissionError(RuntimeError):
    """Rejected at admission: the fleet is saturated (``max_pending``)."""


class FleetClosed(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    replicas: int = 3
    max_pending: int = 1024   # admission cap: queued + dispatched
    refine: bool = True       # background measured refinement workers
    rerank_top_k: int = 3     # refiner measurement depth
    route: str = "affinity"   # "affinity" | "spread"


class Fleet:
    """N serving replicas + dispatcher + background refiner over one DB.

    ``submit(task, tenant=...)`` returns a Future exactly like
    ``KernelService.submit``; ``close()`` drains queued work, resolves
    every handed-out future, and shuts the replicas down.
    ``config=OptimizeConfig(...)`` (or the deprecated flat ``mode`` /
    ``strategy`` / ``max_steps`` / ``target`` kwargs) plus any extra
    service kwargs configure every replica identically — replicas
    answering the same question MUST share a search signature, or their
    winner records would answer nobody (see
    ``KernelService._winner_db_key``).
    """

    def __init__(self, db_dir: str, cfg: FleetConfig | None = None, *,
                 measure_cfg=None, auto_start: bool = True,
                 **service_kwargs):
        self.cfg = cfg or FleetConfig()
        if self.cfg.replicas < 1:
            raise ValueError("a fleet needs at least one replica")
        if self.cfg.route not in ("affinity", "spread"):
            raise ValueError(f"unknown route {self.cfg.route!r}")
        self.db_dir = str(db_dir)
        kw = dict(service_kwargs)
        kw.setdefault("serve_workers", 2)
        if "rerank_top_k" in kw:
            raise TypeError(
                "Fleet fixes rerank_top_k per role (replicas 0, the "
                "refiner FleetConfig.rerank_top_k) — set "
                "FleetConfig.rerank_top_k instead")
        # fold the optimizer surface — config=OptimizeConfig(...) or the
        # flat legacy kwargs — into ONE shared config: replicas and the
        # refiner must agree on the search signature (docstring above),
        # differing only in the reranking depth of their role
        opt = resolve_config(
            "Fleet", kw.pop("config", None),
            {k: kw.pop(k, UNSET)
             for k in ("mode", "max_steps", "target", "strategy")},
            defaults=KernelService.DEFAULTS)
        self.replicas = [
            KernelService(measure=True, measure_db=self.db_dir,
                          config=opt.replace(rerank_top_k=0),
                          measure_cfg=measure_cfg, **kw)
            for _ in range(self.cfg.replicas)]
        self.refiner = None
        if self.cfg.refine:
            kw_r = dict(kw, serve_workers=1)
            self.refiner = KernelService(
                measure=True, measure_db=self.db_dir,
                config=opt.replace(rerank_top_k=self.cfg.rerank_top_k),
                measure_cfg=measure_cfg, **kw_r)

        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._queues: dict[str, collections.deque] = {}
        self._tenant_rr: collections.deque[str] = collections.deque()
        self._pending = 0
        self._rr = 0                       # spread-routing tiebreak
        self._closed = False
        self._started = False
        self.dispatch_log: list[str] = []  # tenant per dispatch (tests)
        self.fleet_stats = {"admitted": 0, "rejected": 0,
                            "analysis_rejects": 0,
                            "dispatched": 0, "completed": 0,
                            "failed": 0, "refined": 0,
                            "refine_errors": 0, "hot_swaps": 0}
        self._tenant_served: collections.Counter = collections.Counter()
        # key -> was the last answer measured? (hot-swap detection)
        self._last_measured: dict[tuple, bool] = {}
        self._refine_cv = threading.Condition()
        self._refine_q: collections.deque = collections.deque()
        self._refine_keys: set = set()     # queued-or-running keys
        self._refine_busy = 0
        self._threads: list[threading.Thread] = []
        if auto_start:
            self.start()

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> None:
        """Start the dispatcher (and refiner) threads.  Constructed with
        ``auto_start=False``, a fleet queues submissions without
        dispatching until started — tests use this to stage
        deterministic queue contents."""
        with self._lock:
            if self._started or self._closed:
                return
            self._started = True
        t = threading.Thread(target=self._dispatch_loop,
                             name="fleet-dispatch", daemon=True)
        t.start()
        self._threads.append(t)
        if self.refiner is not None:
            r = threading.Thread(target=self._refine_loop,
                                 name="fleet-refine", daemon=True)
            r.start()
            self._threads.append(r)

    def close(self, drain: bool = True) -> None:
        """Deterministic shutdown.  ``drain=True`` dispatches everything
        still queued and waits for it; ``drain=False`` fails queued
        (undispatched) requests with ``FleetClosed``.  Either way every
        future ``submit`` handed out is resolved when close() returns,
        and refinement stops after the item in progress (refinement is
        best-effort by construction)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            # a never-started fleet has no dispatcher to drain through:
            # queued futures must still resolve (with FleetClosed)
            if not drain or not self._started:
                for q in self._queues.values():
                    while q:
                        fut = q.popleft()[0]
                        fut.set_exception(FleetClosed("fleet closed"))
                        self._pending -= 1
            self._work.notify_all()
        with self._refine_cv:
            self._refine_cv.notify_all()
        for t in self._threads:
            t.join()
        for r in self.replicas:
            r.close()                 # resolves all dispatched futures
        if self.refiner is not None:
            self.refiner.close()

    # -- request path --------------------------------------------------------
    def submit(self, task, *, tenant: str = "default",
               seed: int | None = None, target=None) -> cf.Future:
        # static-analysis admission: an ill-formed task never takes a
        # queue slot — reject synchronously with the diagnostics, the
        # same door ``max_pending`` saturation sheds load at.  The
        # verdict memo lives in the first replica's store, so the
        # steady state pays one dict lookup
        if not self.replicas[0].store.analysis_ok(task):
            with self._lock:
                self.fleet_stats["analysis_rejects"] += 1
            from repro.analysis.legality import check_program
            check_program(task, name=task.name)   # raises AnalysisError
        fut: cf.Future = cf.Future()
        with self._lock:
            if self._closed:
                raise FleetClosed("fleet is closed")
            if self._pending >= self.cfg.max_pending:
                self.fleet_stats["rejected"] += 1
                raise AdmissionError(
                    f"fleet saturated: {self._pending} pending >= "
                    f"max_pending {self.cfg.max_pending} "
                    f"(tenant {tenant!r})")
            q = self._queues.get(tenant)
            if q is None:
                q = self._queues[tenant] = collections.deque()
                self._tenant_rr.append(tenant)
            q.append((fut, task, seed, target, tenant))
            self._pending += 1
            self.fleet_stats["admitted"] += 1
            self._work.notify()
        return fut

    def optimize(self, task, *, tenant: str = "default",
                 seed: int | None = None, target=None):
        return self.submit(task, tenant=tenant, seed=seed,
                           target=target).result()

    # -- dispatcher ----------------------------------------------------------
    def _next_locked(self):
        """Round-robin across tenants with queued work (fair share per
        scheduling turn), FIFO within a tenant.  Caller holds _lock."""
        for _ in range(len(self._tenant_rr)):
            t = self._tenant_rr[0]
            self._tenant_rr.rotate(-1)
            q = self._queues.get(t)
            if q:
                return q.popleft()
        return None

    def _dispatch_loop(self) -> None:
        while True:
            with self._lock:
                item = self._next_locked()
                while item is None:
                    if self._closed:
                        return
                    self._work.wait()
                    item = self._next_locked()
                self.dispatch_log.append(item[4])
                self.fleet_stats["dispatched"] += 1
            fut, task, seed, target, tenant = item
            key = (task.fingerprint(),
                   None if seed is None else int(seed),
                   getattr(target, "name", target))
            svc = self._pick_replica(key)
            try:
                inner = svc.submit(task, seed, target)
            except BaseException as e:
                fut.set_exception(e)
                self._request_done(key, tenant, None, task, seed,
                                   target)
                continue
            inner.add_done_callback(
                lambda f, fut=fut, key=key, tenant=tenant, task=task,
                seed=seed, target=target: self._deliver(
                    f, fut, key, tenant, task, seed, target))

    def _pick_replica(self, key) -> KernelService:
        if self.cfg.route == "affinity":
            return self.replicas[int(key[0][:8], 16)
                                 % len(self.replicas)]
        loads = [r.load for r in self.replicas]
        lo = min(loads)
        ties = [i for i, x in enumerate(loads) if x == lo]
        with self._lock:
            self._rr += 1
            return self.replicas[ties[self._rr % len(ties)]]

    def _deliver(self, inner: cf.Future, fut: cf.Future, key, tenant,
                 task, seed, target) -> None:
        try:
            res = inner.result()
        except BaseException as e:
            fut.set_exception(e)
            self._request_done(key, tenant, None, task, seed, target)
            return
        self._request_done(key, tenant, res, task, seed, target)
        fut.set_result(res)

    def _request_done(self, key, tenant, res, task, seed,
                      target) -> None:
        refine = False
        with self._lock:
            self._pending -= 1
            self._tenant_served[tenant] += 1
            if res is None:
                self.fleet_stats["failed"] += 1
            else:
                self.fleet_stats["completed"] += 1
                measured = res.measured_s is not None
                if self._last_measured.get(key) is False and measured:
                    # an earlier answer for this key was the analytic
                    # pick and this one carries a measured record: the
                    # background refiner's winner hot-swapped in
                    self.fleet_stats["hot_swaps"] += 1
                if len(self._last_measured) > 65536:
                    self._last_measured.clear()
                self._last_measured[key] = measured
                refine = (not measured and res.correct
                          and self.refiner is not None)
        if refine:
            self._enqueue_refine(key, task, seed, target)

    # -- background refinement ----------------------------------------------
    def _enqueue_refine(self, key, task, seed, target) -> None:
        with self._refine_cv:
            if self._closed or key in self._refine_keys:
                return
            self._refine_keys.add(key)
            self._refine_q.append((key, task, seed, target))
            self._refine_cv.notify()

    def _refine_loop(self) -> None:
        while True:
            with self._refine_cv:
                while not self._refine_q and not self._closed:
                    self._refine_cv.wait()
                if self._closed:
                    return
                key, task, seed, target = self._refine_q.popleft()
                self._refine_busy += 1
            try:
                # the refiner's own _warm_start refuses unmeasured
                # records (it measures), re-runs the identical question
                # with rerank_top_k>0, and its _record_winner upgrades
                # the shared record; replicas pick the upgrade up via
                # the stamp-revalidated get_winner on their next repeat
                self.refiner.optimize(task, seed, target)
                with self._lock:
                    self.fleet_stats["refined"] += 1
            except Exception:
                # refinement is best-effort: the analytic answer stands
                with self._lock:
                    self.fleet_stats["refine_errors"] += 1
            finally:
                with self._refine_cv:
                    self._refine_busy -= 1
                    self._refine_keys.discard(key)
                    self._refine_cv.notify_all()

    def drain_refinement(self, timeout: float | None = None) -> bool:
        """Block until the refine queue is empty and no refinement is
        running (or ``timeout`` elapses); returns whether it drained.
        Benchmarks use this to make hot-swap observable at a known
        point in the stream."""
        with self._refine_cv:
            return self._refine_cv.wait_for(
                lambda: not self._refine_q and self._refine_busy == 0,
                timeout)

    # -- reporting -----------------------------------------------------------
    def stats(self) -> dict:
        """Fleet counters + summed replica counters (requests,
        coalesced, warm_starts, measured, db_*, ...) + per-tenant
        served counts."""
        agg: collections.Counter = collections.Counter()
        for r in self.replicas:
            st = r.stats()
            for k in ("requests", "coalesced", "warm_starts",
                      "measured", "db_hits", "db_misses",
                      "verify_fallbacks", "fresh_applies",
                      "db_corrupt_records", "db_tmp_reaped",
                      "db_lock_timeouts", "db_winner_refreshes",
                      "submit_analysis_rejects", "analysis_evals",
                      "analysis_hits",
                      "evictions", "evicted_programs", "inflight"):
                agg[k] += st.get(k, 0)
        with self._lock:
            out = dict(self.fleet_stats)
            out["tenants"] = dict(self._tenant_served)
            out["queued"] = sum(map(len, self._queues.values()))
            out["pending"] = self._pending
        out.update(agg)
        out["n_replicas"] = len(self.replicas)
        if self.refiner is not None:
            rst = self.refiner.stats()
            out["refiner_measured"] = rst["measured"]
            out["refiner_requests"] = rst["requests"]
        return out
