"""seamless-m4t-medium [audio] — enc-dec, multimodal; speech frontend is a
STUB (input_specs() provides precomputed frame embeddings per spec).
[arXiv:2308.11596]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,           # decoder layers
    encoder_layers=12,
    cross_attention=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    enc_len=1536,          # audio frames after frontend stub
    rope_theta=1e4,
)
