"""Flash attention (Pallas TPU): tiled online-softmax, GQA-aware.

Grid (B, H, nq, nk) with the KV dimension sequential; per-(b,h,q-block)
running max/denominator and an f32 output accumulator live in VMEM scratch
across the KV loop.  GQA indexes the KV block by h // group.  Causal and
sliding-window masking are applied per block; fully-masked KV blocks still
DMA (skipping them is a schedule flag the autotuner can enable — the cost
model prices the saved bandwidth).

Schedule: blocks bq/bk (Tiling), pipeline_depth (Pipeline).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.schedule import KernelSchedule, default_schedule

NEG_INF = -1e30
LANES = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            nk: int, bq: int, bk: int, causal: bool, window: int,
            q_offset: int, scale: float):
    kj = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + q_offset
    kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...][:, :1]                           # (bq,1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)           # (bq,1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                               # (bq,bk)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)                      # (bq,1)
    l_new = alpha * l_ref[...][:, :1] + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kj == nk - 1)
    def _fin():
        l = l_ref[...][:, :1]
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "schedule", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    q_offset: int = 0,
                    schedule: KernelSchedule | None = None,
                    interpret: bool = False) -> jax.Array:
    """q: (B,Sq,H,hd); k,v: (B,Sk,KV,hd) -> (B,Sq,H,hd)."""
    s = schedule or default_schedule("flash_attention")
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    g = H // KV
    bq = min(s.block("bq", 128), Sq)
    bk = min(s.block("bk", 128), Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, s.blocks)
    qt = q.transpose(0, 2, 1, 3)       # (B,H,Sq,hd)
    kt = k.transpose(0, 2, 1, 3)       # (B,KV,Sk,hd)
    vt = v.transpose(0, 2, 1, 3)
    grid = (B, H, Sq // bq, Sk // bk)
    scale = hd ** -0.5

    out = pl.pallas_call(
        functools.partial(_kernel, nk=grid[3], bq=bq, bk=bk,
                          causal=causal, window=window,
                          q_offset=q_offset, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
