"""Batched, cached MTMC evaluation engine.

The paper's MTMC loop is evaluated over whole benchmark suites
(KernelBench levels, TritonBench); the throughput of the *evaluate* loop
— not the policy — is what caps how many scenarios we can sweep per unit
time.  Two pieces fix that here:

``TranspositionStore``
    A fingerprint-keyed memo shared by the live ``KernelEnv``, the
    ``OfflineTree`` (which already interned by fingerprint, now against
    the same backing store) and ``MTMCPipeline``:

      * transitions — ``(state.fingerprint(), action_key(action))`` ->
        (status, child fingerprint).  ``StructuredMicroCoder.apply`` is
        deterministic and history-independent, so on a hit the child is
        reconstructed exactly (the cached child's structure + the actual
        parent's history + the action description) and a visited
        (state, action) edge is never re-rewritten — not by greedy_cost
        candidate scoring, not by env.step, not by tree expansion.
      * costs — ``(fingerprint, target.name)`` ->
        ``program_cost(..., target).total_s``: one store prices the same
        program against many ``HardwareTarget``s without invalidation
        (transitions and oracle entries are target-independent — only
        the cost memo is per-target; DESIGN.md §9).
      * oracle outputs / checks — ``evaluate`` is a pure function of
        (inputs, nodes, outputs) only (the ``eval_fingerprint``), so
        schedule-only rewrites are proven correct structurally with NO
        execution, and executed outputs are memoized by eval-fingerprint
        for everything else.

    Invalidation: there is none by design — every cached value is a pure
    function of its key (see DESIGN.md §8).  A store must be dropped
    wholesale if the coder, cost model, or oracle semantics change.

``EvalEngine``
    A drop-in, batched replacement for ``evaluate_suite``: a thread
    worker pool optimizes independent tasks concurrently (XLA compiles
    and executions release the GIL) with deterministic per-task seeds,
    all workers sharing one store.  With ``seed_stride=0`` (default)
    every task uses the pipeline's seed, exactly like the serial path.
    Metrics match the serial ``evaluate_suite`` (golden-tested on the
    shipped suites); the one semantic difference is that the store's
    oracle draws check inputs from a NumPy RNG stream rather than the
    serial path's threefry stream, so a node-changing rewrite whose
    error straddles the 2e-3 tolerance could in principle grade
    differently — rewrites are exact or badly broken in practice.
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import threading

import jax

from repro.core import cost_model, hardware, rules
from repro.core.config import UNSET, OptimizeConfig, resolve_config
from repro.core.env import action_key
from repro.core.kernel_ir import (KernelProgram, evaluate, evaluate_np,
                                  make_inputs_np)
from repro.core.micro_coding import ApplyResult, MicroCoder, get_coder
from repro.core.pipeline import (CHECK_ATOL, CHECK_RTOL, CHECK_SEED,
                                 MTMCPipeline, suite_metrics)


class TranspositionStore:
    """Fingerprint-keyed memo for transitions, costs and oracle checks.

    Thread-safe (one lock around table mutation; the expensive work —
    rewrites, cost pricing, oracle execution — runs outside it).  All
    entries are pure functions of their keys, so concurrent duplicate
    computation is benign: last-write-wins with identical values.
    """

    def __init__(self, cost_model=None):
        # optional pluggable pricing (duck-typed ``program_cost``, e.g.
        # measure.CalibratedCostModel).  The cost memo keys stay
        # ``(fp, target)`` — they do NOT encode the model — so a store
        # is bound to one cost model for its whole lifetime; swapping
        # models means a fresh store, exactly like a cost-model code
        # change (DESIGN.md §8/§11)
        self.cost_model = cost_model
        self._lock = threading.RLock()
        self.programs: dict[str, KernelProgram] = {}
        # (fp, target_name) -> program_cost(prog, target).total_s
        self.costs: dict[tuple[str, str], float] = {}
        # (fp, action_key) -> (status, child_fp | None, detail)
        self.edges: dict[tuple[str, str], tuple[str, str | None, str]] = {}
        # (task_fp, prog_fp, seed) -> bool
        self.checks: dict[tuple[str, str, int], bool] = {}
        # prog_fp -> static analysis verdict (error-free?) — the cheap
        # pre-oracle gate; a pure function of the program (portability
        # envelope), so it shares the no-invalidation contract
        self.analysis: dict[str, bool] = {}
        # (eval_fp, seed) -> oracle outputs
        self.outputs: dict[tuple[str, int], list[jax.Array]] = {}
        # (input-spec repr, seed) -> generated inputs: a task and its
        # rewrites share input specs, so inputs generate once per task
        self.inputs: dict[tuple[str, int], dict[str, jax.Array]] = {}
        self.stats = {"fresh_applies": 0, "apply_hits": 0,
                      "cost_evals": 0, "cost_hits": 0,
                      "check_evals": 0, "check_hits": 0,
                      "check_structural": 0,
                      "oracle_runs": 0, "oracle_hits": 0,
                      "analysis_evals": 0, "analysis_hits": 0,
                      "analysis_rejects": 0,
                      "evictions": 0, "evicted_programs": 0}
        # segmented-LRU bookkeeping for capacity eviction: a logical
        # clock of last use and a touch count per fingerprint (entries
        # touched more than once sit in the protected segment and are
        # evicted after the probationary, once-touched ones)
        self._clock = 0
        self._last_use: dict[str, int] = {}
        self._freq: dict[str, int] = {}
        # refcounts of oracle-memo keys reachable from live programs
        # (outputs key by eval-fingerprint, inputs by input-spec repr,
        # both shared across programs): maintained at register/evict
        # time so eviction never scans the surviving programs
        self._eval_live: dict[str, int] = {}
        self._input_live: dict[str, int] = {}

    def _bump(self, key: str) -> None:
        with self._lock:
            self.stats[key] += 1

    def _touch(self, fp: str) -> None:
        with self._lock:
            self._clock += 1
            self._last_use[fp] = self._clock
            self._freq[fp] = self._freq.get(fp, 0) + 1

    def _register(self, fp: str, prog: KernelProgram) -> None:
        """Intern ``prog`` under ``fp`` and refcount its oracle keys."""
        with self._lock:
            if fp in self.programs:
                return
            self.programs[fp] = prog
            e, i = prog.eval_fingerprint(), repr(prog.inputs)
            self._eval_live[e] = self._eval_live.get(e, 0) + 1
            self._input_live[i] = self._input_live.get(i, 0) + 1

    # -- fingerprints --------------------------------------------------------
    def fingerprint(self, prog: KernelProgram) -> str:
        return prog.fingerprint()    # memoized on the program itself

    def intern(self, prog: KernelProgram, target=None) -> str:
        """Register a program and price it; returns its fingerprint."""
        fp = self.fingerprint(prog)
        self._register(fp, prog)
        self.cost(prog, target)
        return fp

    def program(self, fp: str) -> KernelProgram:
        return self.programs[fp]

    # -- cost memo -----------------------------------------------------------
    def cost(self, prog: KernelProgram, target=None) -> float:
        tgt = hardware.resolve(target)
        key = (self.fingerprint(prog), tgt.name)
        self._touch(key[0])
        c = self.costs.get(key)
        if c is not None:
            self._bump("cost_hits")
            return c
        self._bump("cost_evals")
        model = self.cost_model if self.cost_model is not None \
            else cost_model
        c = model.program_cost(prog, tgt).total_s
        # register task roots too (apply() only interns children):
        # every priced fingerprint must live in ``programs`` so LRU
        # eviction can reclaim it — and its edges/bookkeeping —
        # instead of leaking root-keyed entries forever
        self._register(key[0], prog)
        with self._lock:
            self.costs[key] = c
        return c

    def cost_of(self, fp: str, target=None) -> float:
        return self.costs[(fp, hardware.resolve(target).name)]

    # -- transition memo -------------------------------------------------------
    def apply(self, coder: MicroCoder, prog: KernelProgram,
              action) -> ApplyResult:
        """Memoized ``coder.apply``.  The coder must be deterministic and
        history-independent (StructuredMicroCoder is); the child's
        ``history`` is reconstructed from the actual parent, so a cache
        hit is bit-identical to a live rewrite."""
        if rules.is_terminal(action):
            return ApplyResult("ok", prog, "terminal")
        key = (self.fingerprint(prog), action_key(action))
        self._touch(key[0])
        hit = self.edges.get(key)
        if hit is not None:
            status, child_fp, detail = hit
            if status != "ok":
                self._bump("apply_hits")
                return ApplyResult(status, None, detail)
            # rebuild what the live coder would have produced: cached
            # structure + the ACTUAL parent's identity and history (the
            # fingerprint excludes both, so the canonical program may
            # stem from a different task or route)
            base = self.programs.get(child_fp)
            if base is not None:
                self._bump("apply_hits")
                self._touch(child_fp)
                child = base.replace(
                    name=prog.name,
                    history=prog.history + (action.describe(),))
                return ApplyResult(status, child, detail)
            # the edge's child was LRU-evicted from under it (slab
            # eviction drops edges with their child, but a concurrent
            # reader can observe the gap) — fall through and recompute
        self._bump("fresh_applies")
        res = coder.apply(prog, action)
        child_fp = None
        if res.status == "ok":
            # register WITHOUT pricing: the caller prices against its
            # own target right after (memoized), so eager default-target
            # pricing here would only duplicate cost-model work for
            # non-default-target searches
            child_fp = self.fingerprint(res.program)
            self._touch(child_fp)
            self._register(child_fp, res.program)
        with self._lock:
            self.edges[key] = (res.status, child_fp, res.detail)
        return res

    # -- correctness-oracle memo ----------------------------------------------
    def oracle_outputs(self, prog: KernelProgram,
                       seed: int) -> list[jax.Array]:
        key = (prog.eval_fingerprint(), seed)
        outs = self.outputs.get(key)
        if outs is not None:
            self._bump("oracle_hits")
            return outs
        self._bump("oracle_runs")
        # XLA compilation of the oracle dominates fresh-suite wall clock
        # (the programs themselves are small): run the float32-faithful
        # NumPy mirror when the op vocabulary allows it, else jit the
        # WHOLE program once (1 compile instead of one per eager op)
        ikey = (repr(prog.inputs), seed)
        inputs = self.inputs.get(ikey)
        if inputs is None:
            inputs = make_inputs_np(prog, seed)
            with self._lock:
                self.inputs[ikey] = inputs
        try:
            outs = evaluate_np(prog, inputs)
        except NotImplementedError:
            outs = jax.jit(lambda i: evaluate(prog, i))(inputs)
        with self._lock:
            self.outputs[key] = outs
        return outs

    def analysis_ok(self, prog: KernelProgram) -> bool:
        """Memoized static-analysis verdict (``repro.analysis``,
        portability envelope): True when the program carries no ERROR
        diagnostics.  Milliseconds vs the oracle's compile+execute, so
        ``check`` consults it first and statically-rejected programs
        never cost an oracle evaluation."""
        fp = self.fingerprint(prog)
        hit = self.analysis.get(fp)
        if hit is not None:
            self._bump("analysis_hits")
            return hit
        self._bump("analysis_evals")
        from repro.analysis.legality import analyze_program
        try:
            ok = not any(d.is_error for d in analyze_program(prog))
        except Exception:
            # the analyzer must never turn a checkable program into an
            # unserved request: an analyzer crash means "no verdict"
            ok = True
        with self._lock:
            self.analysis[fp] = ok
        return ok

    def check(self, task: KernelProgram, prog: KernelProgram, *,
              seed: int = CHECK_SEED, rtol: float = CHECK_RTOL,
              atol: float = CHECK_ATOL) -> bool:
        """Memoized tier-2 validation of ``prog`` against ``task``.

        Static analysis gates first (memoized by fingerprint): a
        program the verifier/legality passes reject is failed
        immediately and never costs an oracle run.  Schedule-only
        rewrites (equal eval-fingerprints: same op graph, different
        tilings/pipelining/loop orders) are then accepted structurally
        — the oracle would compare an array with itself.  Everything
        else runs through the memoized oracle, at the per-output
        tolerances the program's rewrite rules declare (a
        reduced-precision rewrite relaxes only the outputs its marked
        nodes reach; the relaxation is a pure function of the program,
        so the memo key stays sound)."""
        per_tol = rules.output_tolerances(prog, rtol, atol)
        key = (self.fingerprint(task), self.fingerprint(prog), seed)
        self._touch(key[0])
        self._touch(key[1])
        hit = self.checks.get(key)
        if hit is not None:
            self._bump("check_hits")
            return hit
        if not self.analysis_ok(prog):
            self._bump("analysis_rejects")
            with self._lock:
                self.checks[key] = False
            return False
        self._bump("check_evals")
        if task.eval_fingerprint() == prog.eval_fingerprint():
            self._bump("check_structural")
            ok = True
        else:
            try:
                a = self.oracle_outputs(task, seed)
                b = self.oracle_outputs(prog, seed)
                ok = rules.outputs_match(a, b, rtol, atol,
                                         per_output=per_tol)
            except Exception:
                # report failure but do NOT cache it: a transient oracle
                # error (interrupted compile, resource exhaustion) must
                # not poison a long-lived store
                return False
        with self._lock:
            self.checks[key] = ok
        return ok

    # -- capacity: segmented-LRU slab eviction ----------------------------------
    def evict_lru(self, keep: int, *,
                  protect: set[str] | frozenset[str] = frozenset()
                  ) -> int:
        """Evict the coldest programs down to ``keep``, dropping their
        cost/edge/check/oracle entries in the same slab; returns the
        number of programs evicted.

        Order is segmented LRU: probationary entries (touched once)
        leave before protected ones (touched 2+ times), each segment
        oldest-last-use first — so a hot working set survives a stream
        of distinct one-shot kernels.  ``protect`` fingerprints (e.g.
        in-flight request roots) are never evicted.  The store's
        "pure function of key" invariant is untouched: eviction only
        *forgets* values, never mutates them, so a later request
        recomputes the identical entry (DESIGN.md §10).
        """
        with self._lock:
            n_over = len(self.programs) - keep
            if n_over <= 0:
                return 0
            victims = sorted(
                (fp for fp in self.programs if fp not in protect),
                key=lambda fp: (self._freq.get(fp, 0) > 1,
                                self._last_use.get(fp, 0)))
            drop = set(victims[:n_over])
            if not drop:
                return 0
            dead_eval, dead_inputs = set(), set()
            for fp in drop:
                prog = self.programs.pop(fp)
                self._last_use.pop(fp, None)
                self._freq.pop(fp, None)
                for refs, key, dead in (
                        (self._eval_live, prog.eval_fingerprint(),
                         dead_eval),
                        (self._input_live, repr(prog.inputs),
                         dead_inputs)):
                    refs[key] -= 1
                    if refs[key] == 0:
                        del refs[key]
                        dead.add(key)
            self.costs = {k: v for k, v in self.costs.items()
                          if k[0] not in drop}
            # an ok-edge hit reconstructs its child from
            # ``self.programs`` — edges from OR to an evicted program
            # go in the same slab (failure edges have no child and
            # survive with their parent)
            self.edges = {k: v for k, v in self.edges.items()
                          if k[0] not in drop and v[1] not in drop}
            self.checks = {k: v for k, v in self.checks.items()
                           if k[0] not in drop and k[1] not in drop}
            self.analysis = {k: v for k, v in self.analysis.items()
                             if k not in drop}
            # oracle outputs/inputs key by eval-fingerprint / input
            # spec, shared across programs: the refcounts maintained at
            # register time say which keys just became unreachable, so
            # no scan of the (much larger) surviving-program set runs
            # under the lock
            if dead_eval:
                self.outputs = {k: v for k, v in self.outputs.items()
                                if k[0] not in dead_eval}
            if dead_inputs:
                self.inputs = {k: v for k, v in self.inputs.items()
                               if k[0] not in dead_inputs}
            # LRU bookkeeping can hold fingerprints that were touched
            # but never interned (e.g. a checked-but-never-priced
            # task): sweep it down to live programs so it stays
            # bounded by the cap too
            self._last_use = {f: t for f, t in self._last_use.items()
                              if f in self.programs}
            self._freq = {f: c for f, c in self._freq.items()
                          if f in self.programs}
            self.stats["evictions"] += 1
            self.stats["evicted_programs"] += len(drop)
            return len(drop)

    # -- reporting -------------------------------------------------------------
    @property
    def size(self) -> int:
        return len(self.programs)

    def stats_dict(self) -> dict:
        return dict(self.stats, programs=len(self.programs),
                    edges=len(self.edges))


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EngineConfig:
    mode: str = "policy"
    curated: bool = True
    extended: bool = False  # include non-default registry rules
    max_steps: int = 8
    seed: int = 0
    validate: bool = True
    workers: int = 0       # <=1 serial; N>1 thread pool over tasks
    seed_stride: int = 0   # per-task seed = seed + stride * task_index
    target: str | None = None     # hardware target name (None = default)
    strategy: str | None = None   # search strategy name (None = mode loop)
    rerank_top_k: int = 0  # measured reranking depth (needs a measurer)
    coder: str = "structured"     # micro-coder name (serve keys stringify)

    @classmethod
    def from_optimize(cls, oc: OptimizeConfig, *, workers: int = 0,
                      seed_stride: int = 0) -> EngineConfig:
        """Project an OptimizeConfig onto the engine's legacy config
        record (kept because serve-side keys and logs stringify it).
        Instance-valued target/strategy/coder collapse to their names."""
        tgt = oc.target
        if tgt is not None and not isinstance(tgt, str):
            tgt = hardware.resolve(tgt).name
        strat = oc.strategy
        if strat is not None and not isinstance(strat, str):
            strat = getattr(strat, "name", str(strat))
        coder = oc.coder
        if not isinstance(coder, str):
            coder = getattr(coder, "name", "custom")
        return cls(mode=oc.mode, curated=oc.curated,
                   extended=oc.extended_rules, max_steps=oc.max_steps,
                   seed=oc.seed, validate=oc.validate, workers=workers,
                   seed_stride=seed_stride, target=tgt, strategy=strat,
                   rerank_top_k=oc.rerank_top_k, coder=coder)

    def to_optimize(self, *, measurer=None,
                    cost_model=None) -> OptimizeConfig:
        return OptimizeConfig(
            mode=self.mode, curated=self.curated,
            extended_rules=self.extended, max_steps=self.max_steps,
            seed=self.seed, validate=self.validate, target=self.target,
            strategy=self.strategy, cost_model=cost_model,
            measurer=measurer, rerank_top_k=self.rerank_top_k,
            coder=self.coder)


class EvalEngine:
    """Batched, cached replacement for the serial ``evaluate_suite``.

    One store is shared by every pipeline the engine builds, across
    tasks, suites and repeat runs — a second run of the same suite
    performs zero fresh micro-coder rewrites and zero oracle runs.

    Configure with ``config=OptimizeConfig(...)`` plus the engine-only
    ``workers``/``seed_stride`` knobs.  ``cfg=EngineConfig(...)`` and
    the flat optimizer kwargs remain as compatibility shims (the latter
    warn ``DeprecationWarning`` once per process).
    """

    def __init__(self, policy=None, *,
                 store: TranspositionStore | None = None,
                 cfg: EngineConfig | None = None,
                 config: OptimizeConfig | None = None,
                 workers=UNSET, seed_stride=UNSET,
                 mode=UNSET, curated=UNSET, extended=UNSET,
                 max_steps=UNSET, seed=UNSET, validate=UNSET,
                 target=UNSET, strategy=UNSET, rerank_top_k=UNSET,
                 measurer=UNSET, cost_model=UNSET):
        self.policy = policy
        legacy = {"mode": mode, "curated": curated,
                  "extended_rules": extended, "max_steps": max_steps,
                  "seed": seed, "validate": validate, "target": target,
                  "strategy": strategy, "rerank_top_k": rerank_top_k,
                  "cost_model": cost_model}
        if cfg is not None:
            if config is not None:
                raise TypeError("pass either cfg or config, not both")
            if (any(v is not UNSET for v in legacy.values())
                    or workers is not UNSET or seed_stride is not UNSET):
                raise TypeError(
                    "pass either cfg or keyword options, not both")
            # measurer was historically allowed alongside cfg (it never
            # lived in EngineConfig) — keep that pairing working
            oc = cfg.to_optimize(
                measurer=None if measurer is UNSET else measurer)
            self.cfg = cfg
        else:
            if measurer is not UNSET:
                legacy["measurer"] = measurer
            oc = resolve_config("EvalEngine", config, legacy)
            self.cfg = EngineConfig.from_optimize(
                oc, workers=0 if workers is UNSET else int(workers),
                seed_stride=(0 if seed_stride is UNSET
                             else int(seed_stride)))
        # ONE coder instance shared by every pipeline the engine builds:
        # repair-loop telemetry aggregates across tasks/suites, and the
        # store's edge memo stays coder-consistent (a store must never be
        # shared between coders with different rewrite behavior)
        self.coder = get_coder(oc.coder)
        # cost_model spec strings ("learned:PATH", "calibrated:PATH",
        # "analytic") resolve here, ONCE, and the resolved instance is
        # stored back into the config — every pipeline then shares the
        # identical model object with the store, satisfying the
        # store↔config consistency check (a spec resolved twice would
        # be two distinct instances pricing one shared cost memo)
        if isinstance(oc.cost_model, str):
            from repro.measure.learned import resolve_cost_model
            oc = oc.replace(cost_model=resolve_cost_model(oc.cost_model))
        # the resolved optimizer config every pipeline is built from
        self.config = oc.replace(coder=self.coder)
        if store is None:
            store = (TranspositionStore(cost_model=oc.cost_model)
                     if oc.cost_model is not None
                     else TranspositionStore())
        self.store = store
        # optional measure.ExecutionHarness: pipelines rerank their
        # top-K survivors by measured time (config.rerank_top_k)
        self.measurer = oc.measurer

    def pipeline(self, seed: int | None = None,
                 target=None) -> MTMCPipeline:
        oc = self.config
        over = {}
        if seed is not None:
            over["seed"] = seed
        if target is not None:
            over["target"] = target
        if over:
            oc = oc.replace(**over)
        return MTMCPipeline(self.policy, config=oc, store=self.store)

    def optimize(self, task: KernelProgram, seed: int | None = None,
                 target=None):
        """Single-task entry; ``target`` overrides the engine's default
        per request (the store shares transitions/oracle entries across
        targets, so mixed-target request streams stay cached)."""
        return self.pipeline(seed, target).optimize(task)

    def evaluate_suite(self, tasks: list[KernelProgram]) -> dict:
        """Same metrics dict as ``pipeline.evaluate_suite`` (Eqs. 3-4).

        Results are a deterministic function of (task, per-task seed)
        alone — the store only memoizes pure functions — so worker
        scheduling and cache warmth never change the metrics.
        """
        c = self.cfg
        seeds = [c.seed + c.seed_stride * i for i in range(len(tasks))]
        jobs = list(zip(tasks, seeds))
        if c.workers and c.workers > 1:
            with cf.ThreadPoolExecutor(max_workers=c.workers) as ex:
                results = list(ex.map(
                    lambda job: self.pipeline(job[1]).optimize(job[0]),
                    jobs))
        else:
            results = [self.pipeline(s).optimize(t) for t, s in jobs]
        return suite_metrics(results)

    def stats(self) -> dict:
        """Store counters plus, for an LLM-backed coder, the repair-loop
        telemetry (``coder_proposals``, ``coder_repairs``,
        ``coder_analysis_rejects``, ``coder_oracle_rejects``,
        ``coder_gave_up``, depth histogram) — ``coder_``-prefixed so the
        store's own ``analysis_rejects`` key stays unambiguous."""
        out = self.store.stats_dict()
        coder_stats = getattr(self.coder, "stats_dict", None)
        if callable(coder_stats):
            out.update(coder_stats())
        else:
            out["coder_name"] = getattr(self.coder, "name", "structured")
        return out
