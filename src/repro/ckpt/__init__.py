"""Checkpointing: topology-agnostic save/restore with async snapshots.

Arrays are stored per-leaf as raw .npy plus a msgpack manifest with tree
structure, dtypes and a CRC per leaf.  Restore reassembles the pytree on
whatever mesh the restoring job uses (shardings are applied by the caller
via device_put) — this is what makes elastic rescale work (ft/elastic.py).

Writes go to a temp dir + atomic rename, so a node failure mid-write never
corrupts the latest checkpoint.  ``async_=True`` snapshots to host memory
synchronously (cheap) and writes to disk on a background thread.
"""
from __future__ import annotations

import concurrent.futures as cf
import os
import re
import shutil
import zlib

import jax
import msgpack
import numpy as np

_EXECUTOR = cf.ThreadPoolExecutor(max_workers=2)
_PENDING: list[cf.Future] = []


def _flatten(tree) -> list[tuple[str, np.ndarray]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, np.asarray(leaf)))
    return out


def save(directory: str, step: int, params, opt_state=None, *,
         async_: bool = False, extra: dict | None = None) -> None:
    state = {"params": params}
    if opt_state is not None:
        state["opt"] = opt_state
    # snapshot to host memory now (donation-safe), write later if async
    named = [(n, np.array(a, copy=True)) for n, a in _flatten(state)]

    def write():
        tmp = os.path.join(directory, f".tmp_step_{step}")
        final = os.path.join(directory, f"step_{step:08d}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": [], "extra": extra or {}}
        for name, arr in named:
            fn = re.sub(r"[^A-Za-z0-9_.-]", "_", name) + ".npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"].append(
                {"name": name, "file": fn, "dtype": str(arr.dtype),
                 "shape": list(arr.shape),
                 "crc": zlib.crc32(np.ascontiguousarray(arr).tobytes())})
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_:
        _PENDING.append(_EXECUTOR.submit(write))
    else:
        write()


def wait_pending() -> None:
    global _PENDING
    for fut in _PENDING:
        fut.result()
    _PENDING = []


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", d))]
    return max(steps) if steps else None


def restore(directory: str, step: int, *, shardings=None):
    """Returns (params, opt_state_or_None, step).  Verifies CRCs."""
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    arrays = {}
    for leaf in manifest["leaves"]:
        arr = np.load(os.path.join(d, leaf["file"]))
        crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
        if crc != leaf["crc"]:
            raise OSError(f"checkpoint corruption in {leaf['name']}")
        arrays[leaf["name"]] = arr
    params = _unflatten_prefix(arrays, "params")
    opt = _unflatten_prefix(arrays, "opt") if any(
        n.startswith("opt/") for n in arrays) else None
    if shardings is not None:
        sh = shardings.get("params") if isinstance(shardings, dict) \
            else shardings
        params = jax.tree.map(jax.device_put, params, sh)
    return params, opt, manifest["step"]


def _unflatten_prefix(arrays: dict, prefix: str):
    """Rebuild a nested dict tree from name paths under ``prefix/``."""
    root: dict = {}
    for name, arr in arrays.items():
        parts = name.split("/")
        if parts[0] != prefix:
            continue
        node = root
        for p in parts[1:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return _intify(root)


def _intify(node):
    """Dict with contiguous int-string keys -> list (scan-stacked trees)."""
    if not isinstance(node, dict):
        return node
    node = {k: _intify(v) for k, v in node.items()}
    if node and all(re.fullmatch(r"\d+", k) for k in node):
        keys = sorted(node, key=int)
        if keys == [str(i) for i in range(len(keys))]:
            return [node[k] for k in keys]
    return node
