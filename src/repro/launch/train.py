"""Training launcher.

  python -m repro.launch.train --arch qwen2_5_3b --steps 20 --reduced

On a real fleet each host runs this under its own process index; the
mesh comes from launch.mesh and all state handling (checkpoint/restart,
elastic re-mesh, stragglers) is wired here.  On this CPU container use
--reduced for a runnable configuration.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.base import RunConfig, SHAPES, ShapeConfig
from repro.configs.registry import get_config, reduced
from repro.ft import StragglerMonitor
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--accum", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
        shape = ShapeConfig("reduced", 64, 4, "train")
    else:
        shape = SHAPES[args.shape]
    run = RunConfig(accum_steps=args.accum)
    monitor = StragglerMonitor()
    trainer = Trainer(cfg, shape, run, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every,
                      straggler_monitor=monitor)
    state = trainer.restore_or_init()
    print(f"[train] {cfg.name} {shape.name} from step {state.step} "
          f"on {len(jax.devices())} device(s)")
    state = trainer.run_steps(state, args.steps)
    for m in trainer.metrics_log[-5:]:
        print({k: round(v, 4) for k, v in m.items()})
    if monitor.replicas_to_evict():
        print(f"[ft] replicas flagged for eviction: "
              f"{monitor.replicas_to_evict()}")


if __name__ == "__main__":
    main()
