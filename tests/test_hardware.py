"""Hardware-target registry: pricing, store keying, schedule install."""
import pytest

from repro.core import (TranspositionStore, get_target, program_cost,
                        register_target, registered_targets)
from repro.core import hardware
from repro.core import tasks as T
from repro.kernels import ops
from repro.kernels.schedule import KernelSchedule


def test_three_targets_registered():
    names = registered_targets()
    for required in ("tpu_v5e", "tpu_v4", "gpu_a100"):
        assert required in names


def test_default_target_pricing_matches_v5e():
    """No-target pricing must stay bit-identical to explicit v5e (the
    seed model's constants) — default costs are the compatibility
    contract for every store built before targets existed."""
    for task in T.kb_level1() + T.kb_level2():
        assert program_cost(task).total_s == \
            program_cost(task, get_target("tpu_v5e")).total_s


def test_cost_model_constants_come_from_registry():
    from repro.core import cost_model
    v5e = get_target("tpu_v5e")
    assert cost_model.PEAK_FLOPS == v5e.matmul_flops("bf16")
    assert cost_model.HBM_BW == v5e.hbm_bw
    from repro.roofline import analysis
    assert analysis.PEAK_FLOPS == v5e.matmul_flops("bf16")
    assert analysis.HBM_BW == v5e.hbm_bw


def test_targets_price_differently():
    task = T.kb_level2()[0]
    costs = {n: program_cost(task, n).total_s
             for n in ("tpu_v5e", "tpu_v4", "gpu_a100")}
    assert len(set(costs.values())) == 3
    # v4 has strictly more FLOP/s and bandwidth than v5e at the same
    # geometry, so everything is cheaper there
    assert costs["tpu_v4"] < costs["tpu_v5e"]


def test_resolve_accepts_name_instance_none():
    v4 = get_target("tpu_v4")
    assert hardware.resolve("tpu_v4") is v4
    assert hardware.resolve(v4) is v4
    assert hardware.resolve(None).name == hardware.DEFAULT_TARGET
    with pytest.raises(KeyError):
        hardware.resolve("tpu_v9000")


def test_register_rejects_silent_overwrite():
    t = get_target("tpu_v4")
    with pytest.raises(ValueError):
        register_target(t)
    register_target(t, overwrite=True)   # explicit is allowed


def test_store_costs_keyed_per_target():
    store = TranspositionStore()
    task = T.kb_level2()[0]
    c_v5e = store.cost(task)
    c_a100 = store.cost(task, "gpu_a100")
    assert c_v5e != c_a100
    assert store.cost(task) == c_v5e                 # hit, not clobbered
    assert store.cost(task, "gpu_a100") == c_a100
    fp = task.fingerprint()
    assert store.cost_of(fp) == c_v5e
    assert store.cost_of(fp, "gpu_a100") == c_a100
    assert store.stats["cost_evals"] == 2


def test_env_rewards_priced_on_target():
    from repro.core import KernelEnv
    task = T._attn_program("attn", 1, 256, 4, 64)
    e1 = KernelEnv(task)
    e2 = KernelEnv(task, target="gpu_a100")
    assert e1.baseline_s != e2.baseline_s
    assert e1.baseline_s == program_cost(task).total_s
    assert e2.baseline_s == program_cost(task, "gpu_a100").total_s


def test_mxu_efficiency_geometry():
    v5e, a100 = get_target("tpu_v5e"), get_target("gpu_a100")
    tiles = {"bm": 64, "bn": 64, "bk": 64}
    # 64-tiles are lane-aligned on the GPU (lane 64) but only
    # sublane-aligned on the TPU (lane 128): per-target optimal tilings
    # genuinely differ
    assert a100.mxu_efficiency(tiles) > v5e.mxu_efficiency(tiles)
    assert v5e.mxu_efficiency({"bm": 128}) == \
        a100.mxu_efficiency({"bm": 128})


# ---------------------------------------------------------------------------
# schedule install keyed by target
# ---------------------------------------------------------------------------

def test_ops_schedule_registry_target_keyed():
    sched_v5e = KernelSchedule(blocks={"bm": 256})
    sched_a100 = KernelSchedule(blocks={"bm": 64})
    try:
        ops.set_schedule("matmul", "_t_test", sched_v5e)
        ops.set_schedule("matmul", "_t_test", sched_a100,
                         target="gpu_a100")
        assert ops.get_schedule("matmul", "_t_test") is sched_v5e
        assert ops.get_schedule("matmul", "_t_test",
                                target="gpu_a100") is sched_a100
        # default-target entries back-fill targets with no install
        assert ops.get_schedule("matmul", "_t_test",
                                target="tpu_v4") is sched_v5e
        # the active target steers no-argument dispatch lookups
        ops.set_active_target("gpu_a100")
        assert ops.get_schedule("matmul", "_t_test") is sched_a100
    finally:
        ops.set_active_target(None)
        for k in [k for k in ops._SCHEDULES if k[1] == "_t_test"]:
            del ops._SCHEDULES[k]


def test_kernel_service_optimize_install_per_target():
    from repro.serve.engine import KernelService
    svc = KernelService(max_steps=6)
    task = T.kb_level1()[0]          # L1_matmul_0: (512,512)x(512,512)
    try:
        res, sched = svc.optimize_install(task, "matmul", "_t_svc")
        assert res.correct and sched is not None
        assert ops.get_schedule("matmul", "_t_svc") is sched
        res2, sched2 = svc.optimize_install(task, "matmul", "_t_svc",
                                            target="gpu_a100")
        assert res2.correct and sched2 is not None
        assert ops.get_schedule("matmul", "_t_svc",
                                target="gpu_a100") is sched2
        assert svc.stats()["target"] == hardware.DEFAULT_TARGET
    finally:
        for k in [k for k in ops._SCHEDULES if k[1] == "_t_svc"]:
            del ops._SCHEDULES[k]


def test_service_mixed_target_requests_share_substrate():
    from repro.serve.engine import KernelService
    svc = KernelService(max_steps=6)
    task = T.kb_level2()[0]
    svc.optimize(task)
    fresh = svc.stats()["fresh_applies"]
    r = svc.optimize(task, target="gpu_a100")
    assert r.correct
    # candidate enumeration is target-aware (gpu_a100 proposes its own
    # lane-64 tile ladder, so SOME rewrites are necessarily new), but
    # the target-independent substrate is shared: tpu_v4 has the same
    # lane/sublane geometry as the default target, so a v4 request
    # after the v5e one re-uses every rewrite, and a REPEAT gpu_a100
    # request re-uses the gpu edges too
    fresh_gpu = svc.stats()["fresh_applies"]
    assert fresh_gpu > fresh
    svc.optimize(task, target="tpu_v4")
    assert svc.stats()["fresh_applies"] == fresh_gpu
    svc.optimize(task, target="gpu_a100")
    assert svc.stats()["fresh_applies"] == fresh_gpu
