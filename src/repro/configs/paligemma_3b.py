"""paligemma-3b [vlm] — SigLIP frontend (STUB embeddings per spec) + Gemma
backbone, MQA (kv=1), head_dim=256. [arXiv:2407.07726]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    rope_theta=1e4,
    tie_embeddings=True,
    prefix_len=256,      # SigLIP 224px/14 -> 256 patch embeddings (stub)
)
