"""RewardSource seam (core/env.py, DESIGN.md §14).

Covers: measured replay determinism against the committed fixture DB,
fallback routing + hit/miss accounting, the mixed-environment refusal,
the ``get_reward_source`` factory contract, and reward-source pricing
of ``OfflineTree`` node costs (what PPO's offline replay rewards
against).
"""
import os

import pytest

from repro.core import cost_model, tasks as T
from repro.core.env import (AnalyticRewardSource, CalibratedRewardSource,
                            MeasuredRewardSource, RewardSource,
                            get_reward_source)
from repro.core.trajectories import CollectConfig, collect
from repro.measure.db import MeasureDB, MeasureSample

FIXTURE_DB = os.path.join(os.path.dirname(__file__), "fixtures",
                          "measure_db")


class _Stub:
    """Duck-typed program: fingerprint() is all a replay source reads."""

    def __init__(self, fp):
        self._fp = fp

    def fingerprint(self):
        return self._fp


class _CountingSource(RewardSource):
    name = "counting"

    def __init__(self, value=123.0):
        self.value = value
        self.calls = 0

    def cost(self, task, prog, target=None):
        self.calls += 1
        return self.value


def _sample(task_fp, prog_fp, env_fp, t=1e-5):
    return MeasureSample(task_fp=task_fp, prog_fp=prog_fp,
                        target="tpu_v5e", env_fp=env_fp, time_s=t,
                        samples=(t,), n_rejected=0, mode="injected",
                        analytic_s=t / 2, bottleneck="compute",
                        env=(("mode", "injected"),))


# ---------------------------------------------------------------------------
# measured replay
# ---------------------------------------------------------------------------

def test_measured_replay_is_deterministic_on_fixture_db():
    """Two independent sources over the committed DB answer the same
    measured time for a known (task, prog) — replay, not re-timing."""
    db = MeasureDB(FIXTURE_DB)
    a = MeasuredRewardSource(db)
    b = MeasuredRewardSource(db)
    task, prog = _Stub("task00"), _Stub("prog00")
    ca = a.cost(task, prog, target="tpu_v5e")
    cb = b.cost(task, prog, target="tpu_v5e")
    assert ca == cb == pytest.approx(2e-05)
    assert a.hits == 1 and a.misses == 0
    # index covers every committed sample
    assert len(a.index) == 6


def test_measured_falls_back_on_unknown_program():
    db = MeasureDB(FIXTURE_DB)
    fb = _CountingSource(0.5)
    rs = MeasuredRewardSource(db, fallback=fb)
    got = rs.cost(_Stub("taskXX"), _Stub("progXX"), target="tpu_v5e")
    assert got == 0.5 and fb.calls == 1
    assert rs.misses == 1 and rs.hits == 0
    # a hit never consults the fallback
    rs.cost(_Stub("task01"), _Stub("prog01"), target="tpu_v5e")
    assert fb.calls == 1 and rs.hits == 1


def test_measured_target_mismatch_is_a_miss():
    db = MeasureDB(FIXTURE_DB)
    rs = MeasuredRewardSource(db, fallback=_CountingSource(7.0))
    assert rs.cost(_Stub("task00"), _Stub("prog00"),
                   target="gpu_a100") == 7.0
    assert rs.misses == 1


def test_mixed_environment_db_is_refused(tmp_path):
    db = MeasureDB(str(tmp_path / "db"))
    db.put(_sample("t0", "p0", "envAAAAAAAAA"))
    db.put(_sample("t1", "p1", "envBBBBBBBBB"))
    with pytest.raises(ValueError, match="environment"):
        MeasuredRewardSource(db)
    # selecting one env works and only indexes its samples
    rs = MeasuredRewardSource(db, env_fp="envAAAAAAAAA")
    assert len(rs.index) == 1


# ---------------------------------------------------------------------------
# the factory
# ---------------------------------------------------------------------------

def test_get_reward_source_factory():
    assert isinstance(get_reward_source(None), AnalyticRewardSource)
    assert isinstance(get_reward_source("analytic"),
                      AnalyticRewardSource)
    inst = _CountingSource()
    assert get_reward_source(inst) is inst
    with pytest.raises(ValueError, match="needs a"):
        get_reward_source("measured")
    with pytest.raises(ValueError, match="unknown reward source"):
        get_reward_source("wallclock")
    db = MeasureDB(FIXTURE_DB)
    cal = get_reward_source("calibrated", db=db)
    assert isinstance(cal, CalibratedRewardSource)
    meas = get_reward_source("measured", db=db)
    assert isinstance(meas, MeasuredRewardSource)
    # measured's fallback is the calibrated model, not the raw roofline
    assert isinstance(meas.fallback, CalibratedRewardSource)


def test_analytic_source_matches_cost_model():
    task = T.kb_level1()[0]
    rs = AnalyticRewardSource()
    assert rs.cost(task, task) == pytest.approx(
        cost_model.program_cost(task).total_s)


# ---------------------------------------------------------------------------
# tree pricing: the costs PPO replays against
# ---------------------------------------------------------------------------

def test_offline_tree_node_costs_come_from_reward_source():
    task = T.kb_level1()[0]
    rs = _CountingSource(42.0)
    tree = collect(task, CollectConfig(episodes_random=1,
                                       episodes_greedy=1, max_steps=2),
                   reward_source=rs)
    assert rs.calls >= tree.size
    assert all(n.cost_s == 42.0 for n in tree.nodes.values())


def test_offline_tree_default_pricing_is_analytic():
    task = T.kb_level1()[0]
    tree = collect(task, CollectConfig(episodes_random=1,
                                       episodes_greedy=0, max_steps=2))
    root = tree.nodes[tree.root]
    assert root.cost_s == pytest.approx(
        cost_model.program_cost(task).total_s)
