"""Paper Table 5 — target-language ablation.

The paper compares Triton vs CUDA generation targets on matmul-family
tasks.  Our analogue: the full Pallas schedule space (tiling + fusion +
pipeline + reorder) vs an XLA-fusion-only target (fusion actions only —
schedules stay at defaults), measuring modeled execution time per task.
"""
from __future__ import annotations

import numpy as np

from .common import STORE
from repro.core import MTMCPipeline, OptimizeConfig, program_cost, rules
from repro.core import tasks as T

_XLA_KINDS = (rules.FusionRule.kind, rules.StopRule.kind)


class _FusionOnlyPipeline(MTMCPipeline):
    def _select(self, prog, cands, key, rng):
        cands = [c for c in cands if c.kind in _XLA_KINDS] or cands
        return super()._select(prog, cands, key, rng)


def run(policy) -> list[str]:
    suite = [t for t in T.kb_level1() + T.kb_level2()
             if "matmul" in t.name or "gemm" in t.name
             or "mlp" in t.name]
    rows = []
    for name, pipe in [
            ("pallas_full", MTMCPipeline(
                config=OptimizeConfig(mode="greedy_cost", max_steps=8),
                store=STORE)),
            ("xla_fusion_only", _FusionOnlyPipeline(
                config=OptimizeConfig(mode="greedy_cost", max_steps=8),
                store=STORE))]:
        times = []
        for t in suite:
            r = pipe.optimize(t)
            times.append(program_cost(r.program).total_s * 1e6)
        rows.append(f"table5/{name},{np.mean(times):.1f},"
                    f"per_task_us={';'.join(f'{x:.1f}' for x in times)}")
    return rows
