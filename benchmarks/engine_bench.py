"""Wall-clock evidence for the batched/cached evaluation engine.

Runs the SAME workload — ``tasks.train_tasks()`` under greedy_cost with
fixed seeds — through (a) the serial ``evaluate_suite`` reference and
(b) the batched ``EvalEngine``; each side in its OWN subprocess so
neither benefits from the other's warm XLA jit cache.  Asserts the
metrics are bit-identical and reports the speedup (acceptance: >= 3x),
plus the marginal cost of a second, fully-cached suite sweep (the
"scenario sweep" case the engine exists for).

  PYTHONPATH=src python benchmarks/engine_bench.py [--out results/engine_bench.txt]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

SERIAL = r"""
import json, time
from repro.core import MTMCPipeline, OptimizeConfig, evaluate_suite
from repro.core import tasks as T
tasks = T.train_tasks()
cfg = OptimizeConfig(mode="greedy_cost", max_steps=8, seed=0)
t0 = time.time()
out = evaluate_suite(tasks, MTMCPipeline(config=cfg))
t1 = time.time() - t0
t0 = time.time()
out2 = evaluate_suite(tasks, MTMCPipeline(config=cfg))
t2 = time.time() - t0
m = {k: v for k, v in out.items() if k != "results"}
print("RESULT:" + json.dumps({"first_s": t1, "second_s": t2,
                              "metrics": m}))
"""

ENGINE = r"""
import json, time
from repro.core import EvalEngine, OptimizeConfig
from repro.core import tasks as T
tasks = T.train_tasks()
eng = EvalEngine(config=OptimizeConfig(mode="greedy_cost", max_steps=8,
                                       seed=0), workers=%d)
t0 = time.time()
out = eng.evaluate_suite(tasks)
t1 = time.time() - t0
t0 = time.time()
out2 = eng.evaluate_suite(tasks)
t2 = time.time() - t0
m = {k: v for k, v in out.items() if k != "results"}
print("RESULT:" + json.dumps({"first_s": t1, "second_s": t2,
                              "metrics": m,
                              "store": eng.store.stats_dict()}))
"""


def _run(script: str) -> dict:
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-3000:])
    line = next(ln for ln in proc.stdout.splitlines()
                if ln.startswith("RESULT:"))
    return json.loads(line[len("RESULT:"):])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "results", "engine_bench.txt"))
    ap.add_argument("--workers", type=int,
                    default=max(2, os.cpu_count() or 2))
    args = ap.parse_args()

    serial = _run(SERIAL)
    engine = _run(ENGINE % args.workers)
    assert serial["metrics"] == engine["metrics"], (
        "metrics diverged", serial["metrics"], engine["metrics"])
    sp_fresh = serial["first_s"] / engine["first_s"]
    sp_sweep = serial["second_s"] / engine["second_s"]
    lines = [
        "engine_bench: tasks.train_tasks() x greedy_cost(max_steps=8, "
        "seed=0), fresh process per side",
        f"serial evaluate_suite : first {serial['first_s']:.2f}s, "
        f"repeat {serial['second_s']:.2f}s",
        f"EvalEngine(workers={args.workers}): first "
        f"{engine['first_s']:.2f}s, repeat {engine['second_s']:.2f}s",
        f"speedup fresh  : {sp_fresh:.2f}x (acceptance >= 3x)",
        f"speedup repeat : {sp_sweep:.2f}x (cached scenario re-sweep)",
        f"metrics identical: {json.dumps(serial['metrics'])}",
        f"store: {json.dumps(engine['store'])}",
    ]
    text = "\n".join(lines) + "\n"
    print(text)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(text)
    if sp_fresh < 3.0:
        print("WARNING: fresh-run speedup below 3x on this host")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
