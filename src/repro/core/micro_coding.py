"""Micro Coding — stepwise implementation of semantic actions.

The paper uses a general-purpose LLM to implement ONE atomic optimization
at a time on the previous kernel.  Offline we realise the same contract
with a deterministic structured rewrite engine over the kernel IR
(DESIGN.md §2): ``apply(program, action) -> ApplyResult`` where failures
reproduce the LLM failure modes the paper's reward tiers grade:

  * compile_error  — illegal tile (does not divide / VMEM OOM / misaligned),
                     illegal fusion (no kernel template for the merged
                     pattern), bogus region, unknown action kind;
  * wrong_result   — the engine "miscompiles" nothing by construction, but
                     the validator still executes the rewritten program
                     against the original's outputs (belt & braces — this
                     is the tier-2 check an LLM-backed MicroCoder needs);
  * ok             — new program + validated.

The transformations themselves live in the rewrite-rule registry
(``core/rules.py``): the coder resolves ``act.kind`` there and never
dispatches on kind literals, so a rule registered tomorrow flows through
``apply`` — including its oracle-tolerance hook (a reduced-precision
rewrite is validated at the tolerance its rule declares) — with no edit
here.  An LLM-backed implementation can be slotted in behind
``MicroCoder``.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol

import jax

from repro.core import rules as R
from repro.core import actions as A
from repro.core.kernel_ir import KernelProgram, evaluate, make_inputs
from repro.core.rules import CompileError


@dataclasses.dataclass(frozen=True)
class ApplyResult:
    status: str                  # ok | compile_error | wrong_result
    program: KernelProgram | None = None
    detail: str = ""


class MicroCoder(Protocol):
    #: stable identity for telemetry and winner-db scoping
    name: str

    def apply(self, prog: KernelProgram, act: A.Action) -> ApplyResult: ...


# ---------------------------------------------------------------------------

class StructuredMicroCoder:
    """Deterministic rewrite engine: registry rules + tier-2 validation."""

    name = "structured"
    # tier-2 validation tolerances (opt-in via validate=True; the search
    # engines run the oracle themselves at the rules' declared tolerances)
    VALIDATE_RTOL = VALIDATE_ATOL = 1e-3

    def __init__(self, validate: bool = False, seed: int = 0):
        self.validate = validate
        self.seed = seed

    # -- entry point -------------------------------------------------------
    def apply(self, prog: KernelProgram, act: A.Action) -> ApplyResult:
        if R.is_terminal(act):
            return ApplyResult("ok", prog, "terminal")
        try:
            new = R.apply_rule(prog, act)
        except CompileError as e:
            return ApplyResult("compile_error", None, str(e))
        new = new.replace(history=prog.history + (act.describe(),))
        if self.validate and not self._check(prog, new):
            return ApplyResult("wrong_result", None, "validation mismatch")
        return ApplyResult("ok", new)

    # -- tier-2 validation ---------------------------------------------------
    def _check(self, old: KernelProgram, new: KernelProgram) -> bool:
        key = jax.random.PRNGKey(self.seed)
        inputs = make_inputs(old, key)
        per_tol = R.output_tolerances(new, self.VALIDATE_RTOL,
                                      self.VALIDATE_ATOL)
        try:
            outs_old = evaluate(old, inputs)
            outs_new = evaluate(new, inputs)
        except Exception:
            return False
        return R.outputs_match(outs_old, outs_new, self.VALIDATE_RTOL,
                               self.VALIDATE_ATOL, per_output=per_tol)


# ---------------------------------------------------------------------------

def get_coder(spec) -> MicroCoder:
    """Resolve ``OptimizeConfig.coder`` to a ``MicroCoder`` instance.

    ``None``/``"structured"`` is the deterministic registry engine;
    ``"llm*"`` specs dispatch to ``repro.llmcoder.make_coder`` (imported
    lazily — core stays importable without the subsystem and repolint's
    backend-import gate holds); an object that already implements the
    protocol passes through, so engines can share one coder instance
    and aggregate its repair telemetry."""
    if spec is None or spec == "structured":
        return StructuredMicroCoder()
    if hasattr(spec, "apply"):
        return spec
    if isinstance(spec, str) and spec.startswith("llm"):
        from repro.llmcoder import make_coder
        return make_coder(spec)
    raise ValueError(f"unknown coder spec {spec!r}")
