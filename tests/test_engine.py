"""Batched/cached evaluation engine: golden regression vs the serial
path, cache effectiveness, live/offline environment parity, service."""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import (Action, EvalEngine, KernelEnv, MTMCPipeline,
                        OfflineEnv, StructuredMicroCoder,
                        TranspositionStore, evaluate_suite)
from repro.core import tasks as T
from repro.core.env import action_key
from repro.core.trajectories import CollectConfig, collect


# ---------------------------------------------------------------------------
# golden-metrics regression: serial evaluate_suite == batched engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,steps", [("random", 6), ("single_pass", 4),
                                        ("greedy_cost", 4)])
def test_golden_metrics_serial_vs_engine(mode, steps):
    """Bit-identical accuracy/fast1/fast2/mean_speedup on the full
    training suite, fixed seeds, threaded engine vs serial reference."""
    tasks = T.train_tasks()
    serial = evaluate_suite(
        tasks, MTMCPipeline(mode=mode, max_steps=steps, seed=3))
    eng = EvalEngine(mode=mode, max_steps=steps, seed=3, workers=2)
    batched = eng.evaluate_suite(tasks)
    for k in ("n", "accuracy", "fast1", "fast2", "mean_speedup"):
        assert serial[k] == batched[k], (mode, k)
    for a, b in zip(serial["results"], batched["results"]):
        assert a.task == b.task
        assert a.correct == b.correct
        assert a.speedup == b.speedup
        assert a.steps == b.steps
        assert a.n_failures == b.n_failures
        assert a.trace == b.trace
        assert a.program.fingerprint() == b.program.fingerprint()


def test_second_suite_run_is_fully_cached():
    """Re-running the same suite performs ZERO fresh micro-coder
    rewrites, zero cost-model evaluations and zero oracle executions."""
    tasks = T.train_tasks()
    eng = EvalEngine(mode="greedy_cost", max_steps=3, seed=0)
    first = eng.evaluate_suite(tasks)
    before = dict(eng.store.stats)
    second = eng.evaluate_suite(tasks)
    after = eng.store.stats
    assert after["fresh_applies"] == before["fresh_applies"]
    assert after["cost_evals"] == before["cost_evals"]
    assert after["oracle_runs"] == before["oracle_runs"]
    assert after["check_evals"] == before["check_evals"]
    assert after["apply_hits"] > before["apply_hits"]
    assert first["mean_speedup"] == second["mean_speedup"]
    assert first["accuracy"] == second["accuracy"]


def test_structural_check_skips_oracle_for_schedule_only_rewrites():
    """Tiling/pipeline/reorder never change the op graph, so validation
    must be structural (no oracle execution); fusion must execute."""
    store = TranspositionStore()
    task = T.kb_level1()[0]                     # single matmul
    mc = StructuredMicroCoder()
    tiled = mc.apply(task, Action("tiling", "y",
                                  (("bm", 256), ("bn", 128),
                                   ("bk", 128)))).program
    assert store.check(task, tiled)
    assert store.stats["oracle_runs"] == 0
    assert store.stats["check_structural"] == 1
    # plain fusion only regroups kernels (nodes unchanged) -> still
    # structural; the flash rewrite REPLACES the op triple -> oracle
    fused_task = T.kb_level2()[0]               # gemm+bias+relu
    fused = mc.apply(fused_task,
                     Action("fusion", "y0", ("y1",))).program
    assert store.check(fused_task, fused)
    assert store.stats["oracle_runs"] == 0
    assert store.stats["check_structural"] == 2
    attn = T._attn_program("chk_attn", 1, 256, 4, 64)
    r = mc.apply(attn, Action("fusion", "scores", ("probs",)))
    flash = mc.apply(r.program, Action("fusion", "scores", ("out",)))
    assert [n.op for n in flash.program.nodes] == ["attention"]
    assert store.check(attn, flash.program)
    assert store.stats["oracle_runs"] == 2      # task + flash program


def test_store_reconstructs_history_on_hits():
    """A cache hit must return the child the live coder would have
    produced — including the history chained from the ACTUAL parent."""
    store = TranspositionStore()
    mc = StructuredMicroCoder()
    task = T.kb_level2()[0]
    a1 = Action("pipeline", "y0", (3,))
    a2 = Action("tiling", "y0", (("bm", 256), ("bn", 128), ("bk", 256)))
    # path A: a1 then a2 (both fresh)
    p1 = store.apply(mc, task, a1).program
    pa = store.apply(mc, p1, a2).program
    # path B: a2 directly from the root — (root, a2) is FRESH, then a1
    # from there; now replay path A, all hits
    q1 = store.apply(mc, task, a2).program
    qa = store.apply(mc, q1, a1).program
    r1 = store.apply(mc, task, a1).program          # hit
    ra = store.apply(mc, r1, a2).program            # hit
    assert r1.history == p1.history
    assert ra.history == pa.history
    assert ra.fingerprint() == pa.fingerprint() == qa.fingerprint()
    assert qa.history != pa.history                 # different route


def test_store_hit_preserves_caller_identity():
    """Two structurally identical tasks share a fingerprint; a cache hit
    must still return a child carrying the CALLER's task name."""
    store = TranspositionStore()
    mc = StructuredMicroCoder()
    t1 = T.kb_level1()[0]
    t2 = t1.replace(name="same_graph_other_task")
    a = Action("pipeline", "y", (3,))
    c1 = store.apply(mc, t1, a).program       # fresh
    c2 = store.apply(mc, t2, a).program       # hit (same fingerprint)
    assert c1.name == t1.name
    assert c2.name == "same_graph_other_task"
    assert c1.fingerprint() == c2.fingerprint()


def test_kernel_service_slab_eviction_keeps_hot_entries():
    """Past the cap the service evicts cold slabs, never the whole
    store: the hot fingerprint (and its cached search substrate)
    survives a sustained stream of distinct kernels."""
    from repro.serve.engine import KernelService
    svc = KernelService(mode="greedy_cost", max_steps=2,
                        max_programs=60, evict_slab=15, serve_workers=1)
    hot = T.kb_level2()[0]
    first = svc.optimize(hot)
    assert first.correct
    hot_fp = first.program.fingerprint()            # the hot winner
    for task in T.kb_level1() + T.kb_level3():      # distinct cold traffic
        svc.optimize(hot)                           # keep the hot set warm
        svc.optimize(task)
    st = svc.stats()
    assert st["evictions"] >= 1
    assert st["evicted_programs"] >= 1
    assert "store_resets" not in st                 # wholesale reset is gone
    assert hot_fp in svc.store.programs             # hot survived the slabs
    # the hot request's whole substrate survived too: a repeat is fully
    # cached (zero fresh rewrites), unlike the old drop-wholesale reset
    fresh = svc.stats()["fresh_applies"]
    again = svc.optimize(hot)
    assert svc.stats()["fresh_applies"] == fresh
    assert again.speedup == first.speedup
    # eviction (at request admission) keeps the store bounded: the cap
    # is re-imposed before each search, never the whole store dropped
    assert len(svc.store.programs) <= 60


def test_kernel_service_coalesces_concurrent_identical_requests():
    """N concurrent identical submits -> ONE fresh search, one shared
    result object, stats counting the joins."""
    import threading
    from repro.serve.engine import KernelService
    svc = KernelService(mode="greedy_cost", max_steps=3,
                        serve_workers=4)
    task = T.kb_level2()[0]
    gate = threading.Event()
    calls = []
    inner = svc._engine.optimize

    def gated_optimize(task, seed=None, target=None):
        calls.append(1)
        assert gate.wait(timeout=60)
        return inner(task, seed, target=target)

    svc._engine.optimize = gated_optimize
    futs = [svc.submit(task) for _ in range(6)]     # all while in-flight
    gate.set()
    results = [svc.result(f, timeout=120) for f in futs]
    assert len(calls) == 1                          # one fresh search
    assert len({id(r) for r in results}) == 1       # shared result
    assert results[0].correct
    st = svc.stats()
    assert st["coalesced"] == 5
    assert st["requests"] == 6
    assert st["inflight"] == 0
    # after the in-flight window closes, an identical request is a new
    # search against a warm store (cached substrate, not coalesced)
    r2 = svc.optimize(task)
    assert svc.stats()["coalesced"] == 5
    assert r2.speedup == results[0].speedup


def test_max_steps_zero_returns_baseline():
    """Regression: ``t`` was unbound when max_steps == 0."""
    task = T.kb_level1()[0]
    res = MTMCPipeline(mode="random", max_steps=0, seed=0).optimize(task)
    assert res.steps == 0 and res.speedup == 1.0 and res.correct
    assert res.trace == ()


def test_result_reports_best_program_history():
    """steps/trace describe the returned (best) program, not the last
    state the episode wandered to."""
    task = T._attn_program("attn", 1, 256, 4, 64)
    res = MTMCPipeline(mode="greedy_cost", max_steps=8, seed=0
                       ).optimize(task)
    assert res.trace == res.program.history
    assert res.steps >= len([h for h in res.trace])  # failures add steps
    assert res.steps <= 8


# ---------------------------------------------------------------------------
# live/offline environment parity (property)
# ---------------------------------------------------------------------------

def _walk(tree, seed, max_len=5):
    """Seeded random walk over materialized ok-edges, ending with stop;
    throws in one materialized FAILING action when available to cover
    the penalty branches."""
    rng = np.random.default_rng(seed)
    fp, acts = tree.root, []
    for _ in range(max_len):
        edges = tree.materialized_actions(fp)
        bad = [a for a, s in edges if s != "ok"]
        ok = [a for a, s in edges if s == "ok" and a.kind != "stop"]
        if bad and rng.random() < 0.3:
            acts.append(bad[int(rng.integers(len(bad)))])   # stays put
            continue
        if not ok:
            break
        a = ok[int(rng.integers(len(ok)))]
        acts.append(a)
        fp = tree.nodes[fp].children[action_key(a)][0]
    acts.append(Action("stop", ""))
    return acts


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10 ** 6), ti=st.integers(0, 2))
def test_live_offline_parity(seed, ti):
    """Replaying one action sequence through the live KernelEnv and the
    OfflineEnv (same OfflineTree) yields identical rewards, statuses and
    fingerprints at every step — including the stop-bonus and the
    step-proportional decay paths."""
    task = [T.kb_level2()[0], T.kb_level2()[1],
            T._attn_program("parity_attn", 1, 256, 4, 64)][ti]
    tree = collect(task, CollectConfig(episodes_random=3,
                                       episodes_greedy=1,
                                       seed=seed % 997))
    acts = _walk(tree, seed)
    live = KernelEnv(task)
    off = OfflineEnv(tree)
    live.reset()
    off.reset()
    for a in acts:
        rl = live.step(a)
        ro = off.step(a)
        assert rl.info["status"] == ro.info["status"], a
        np.testing.assert_allclose(rl.reward, ro.reward, rtol=1e-9)
        assert live.state.fingerprint() == \
            off.program().fingerprint(), a
        if "speedup" in rl.info:
            np.testing.assert_allclose(rl.info["speedup"],
                                       ro.info["speedup"], rtol=1e-9)
        if rl.done or a.kind == "stop":
            break
    assert live.t == off.t


def test_live_env_through_store_matches_plain():
    """KernelEnv with a shared store is behaviourally identical to the
    uncached env (rewards, states), even when the store is pre-warmed
    by a different traversal order."""
    task = T.kb_level2()[3]                     # swiglu chain
    store = TranspositionStore()
    warm = KernelEnv(task, store=store)
    warm.reset()
    for a in (Action("fusion", "g", ("gs",)), Action("pipeline", "y", (3,))):
        warm.step(a)
    seq = (Action("pipeline", "y", (3,)), Action("fusion", "g", ("gs",)),
           Action("tiling", "nope", (("bm", 8),)), Action("stop", ""))
    plain, cached = KernelEnv(task), KernelEnv(task, store=store)
    plain.reset()
    cached.reset()
    for a in seq:
        rp, rc = plain.step(a), cached.step(a)
        assert rp.info["status"] == rc.info["status"]
        np.testing.assert_allclose(rp.reward, rc.reward, rtol=1e-12)
        assert plain.state.fingerprint() == cached.state.fingerprint()
        assert plain.state.history == cached.state.history


# ---------------------------------------------------------------------------
# serving reuse
# ---------------------------------------------------------------------------

def test_kernel_service_reuses_cache_across_requests():
    from repro.serve.engine import KernelService
    svc = KernelService(mode="greedy_cost", max_steps=3)
    task = T.kb_level2()[0]
    r1 = svc.optimize(task)
    fresh = svc.stats()["fresh_applies"]
    r2 = svc.optimize(task)
    assert svc.stats()["fresh_applies"] == fresh   # 2nd request: all hits
    assert r1.speedup == r2.speedup and r1.correct == r2.correct
    assert svc.stats()["requests"] == 2


def test_kernel_service_close_resolves_inflight_and_rejects_new():
    """close() is deterministic: it drains the in-flight search (never
    cancels it), so a caller holding a coalesced future — handed out
    BEFORE close — resolves normally; new submissions are refused and
    a second close() is a no-op."""
    import threading
    from repro.serve.engine import KernelService
    svc = KernelService(mode="greedy_cost", max_steps=2,
                        serve_workers=2)
    task = T.kb_level2()[0]
    gate = threading.Event()
    inner = svc._engine.optimize

    def gated(task, seed=None, target=None):
        assert gate.wait(timeout=60)
        return inner(task, seed, target=target)

    svc._engine.optimize = gated
    f1 = svc.submit(task)
    f2 = svc.submit(task)                 # coalesced joiner
    assert f2 is f1
    closer = threading.Thread(target=svc.close)
    closer.start()
    assert not f1.done()                  # close is draining, not done
    gate.set()
    closer.join(120)
    assert not closer.is_alive()
    assert f1.result(10).correct          # the joined future resolved
    with pytest.raises(RuntimeError):
        svc.submit(task)                  # closed: refused, not queued
    svc.close()                           # idempotent


def test_kernel_service_counters_exact_under_contention():
    """Regression: ``optimize_batch`` bumped ``n_requests`` and
    ``stats()`` read ``_inflight`` without the lock, losing increments
    under concurrent traffic.  Distinct-seed submits (no coalescing)
    plus batch calls plus stats readers must account exactly."""
    import threading
    import types
    from repro.serve.engine import KernelService
    svc = KernelService(mode="greedy_cost", max_steps=1,
                        serve_workers=4)
    # counters are the subject, not the search: stub both entry points
    svc._engine.optimize = lambda task, seed=None, target=None: \
        types.SimpleNamespace(correct=True)
    svc._engine.evaluate_suite = lambda tasks: {}
    task = T.kb_level1()[0]
    N, M, B = 8, 25, 10
    futs, flock = [], threading.Lock()

    def submitter(i):
        for j in range(M):
            f = svc.submit(task, i * M + j)
            with flock:
                futs.append(f)

    def batcher():
        for _ in range(B):
            svc.optimize_batch([task, task])

    def reader():
        for _ in range(50):
            st = svc.stats()
            assert st["requests"] >= 0 and st["inflight"] >= 0

    ts = [threading.Thread(target=submitter, args=(i,))
          for i in range(N)]
    ts += [threading.Thread(target=batcher) for _ in range(2)]
    ts += [threading.Thread(target=reader) for _ in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    for f in futs:
        f.result(60)
    st = svc.stats()
    svc.close()
    assert st["requests"] == N * M + 2 * B * 2
    assert st["coalesced"] == 0
    assert st["inflight"] == 0
