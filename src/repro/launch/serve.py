"""Serving launcher.

  python -m repro.launch.serve --arch qwen2_5_3b --reduced --requests 8
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, reduced
from repro.models import api
from repro.serve.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if cfg.family not in ("dense", "moe", "vlm"):
        raise SystemExit(f"Engine demo supports transformer families; "
                         f"{cfg.family} decodes via its serve_step "
                         f"(see launch/dryrun.py decode cells)")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, max_len=64, batch_slots=4)
    key = jax.random.PRNGKey(1)
    prompts = [jax.random.randint(jax.random.fold_in(key, i),
                                  (3 + i % 4,), 1, 100, jnp.int32)
               for i in range(args.requests)]
    outs = engine.generate(prompts, max_new_tokens=args.max_new)
    for i, o in enumerate(outs):
        print(f"req{i}: {o}")


if __name__ == "__main__":
    main()
