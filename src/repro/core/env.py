"""RL environment over kernel programs (live + tree-structured offline).

Reward shaping follows the paper's three tiers, easy -> hard:
  (1) compiles        — failures penalised, penalty magnitude < tier-2/3
                        gains so exploration escapes the all-invalid zone;
  (2) runs correctly  — small positive baseline for any valid rewrite;
  (3) runs faster     — dominant reward, proportional to the speedup
                        delta over the previous step's kernel.
Positive rewards are scaled by a step-proportional decay (paper: "step-
proportional reward decay mechanism to mitigate degenerate looping"), so
re-applying no-op optimizations late in an episode earns ~nothing.

``OfflineTree`` caches (state, action) -> (child, status, cost): policy
training replays materialized transitions only (the paper's offline tree
built from pre-collected trajectories — no live Micro Coding latency in
the PPO loop).
"""
from __future__ import annotations

import dataclasses

from repro.core import actions as A
from repro.core import cost_model, hardware, rules
from repro.core.kernel_ir import KernelProgram
from repro.core.micro_coding import MicroCoder, StructuredMicroCoder


@dataclasses.dataclass
class EnvConfig:
    max_steps: int = 8
    penalty_compile: float = -0.4
    penalty_wrong: float = -0.8
    reward_valid: float = 0.1
    reward_speed_scale: float = 1.0
    decay_per_step: float = 0.1       # positive-reward decay
    decay_floor: float = 0.3
    curated_actions: bool = True      # False = "w/o AS" ablation
    extended_rules: bool = False      # True = non-default registry rules too


@dataclasses.dataclass
class StepResult:
    program: KernelProgram
    reward: float
    done: bool
    info: dict


class KernelEnv:
    """Live environment: applies actions through a MicroCoder.

    ``store`` (optional, a ``core.engine.TranspositionStore`` or anything
    with the same ``apply``/``cost`` duck type) memoizes rewrites and
    cost-model pricing by fingerprint, shared with ``OfflineTree`` and
    the pipeline — a visited (state, action) edge is never re-rewritten.
    """

    def __init__(self, task: KernelProgram, coder: MicroCoder | None = None,
                 cfg: EnvConfig | None = None, store=None, target=None):
        self.task = task
        self.coder = coder or StructuredMicroCoder()
        # None -> fresh config: a dataclass-instance default would be
        # one SHARED mutable object across every env ever constructed
        self.cfg = cfg if cfg is not None else EnvConfig()
        self.store = store
        # the chip rewards are priced against (None = registry default);
        # rewrite legality stays target-independent (DESIGN.md §9)
        self.target = hardware.resolve(target)
        self.baseline_s = self._cost(task)

    def _cost(self, prog: KernelProgram) -> float:
        if self.store is not None:
            return self.store.cost(prog, self.target)
        return cost_model.program_cost(prog, self.target).total_s

    def _apply(self, action: A.Action):
        if self.store is not None:
            return self.store.apply(self.coder, self.state, action)
        return self.coder.apply(self.state, action)

    def reset(self) -> KernelProgram:
        self.state = self.task
        self.t = 0
        self.prev_s = self.baseline_s
        return self.state

    def candidates(self, state: KernelProgram | None = None
                   ) -> list[A.Action]:
        state = state or self.state
        enum = (A.candidate_actions if self.cfg.curated_actions
                else A.unrestricted_actions)
        return enum(state, target=self.target,
                    extended=self.cfg.extended_rules)

    def _decay(self) -> float:
        return max(self.cfg.decay_floor,
                   1.0 - self.cfg.decay_per_step * self.t)

    def step(self, action: A.Action) -> StepResult:
        cfg = self.cfg
        self.t += 1
        done = self.t >= cfg.max_steps
        if rules.is_terminal(action):
            final = self.baseline_s / self.prev_s
            r = 0.25 * max(0.0, final - 1.0)
            return StepResult(self.state, r, True,
                              {"status": "stop", "speedup": final})
        res = self._apply(action)
        if res.status == "compile_error":
            return StepResult(self.state, cfg.penalty_compile, done,
                              {"status": res.status, "detail": res.detail})
        if res.status == "wrong_result":
            return StepResult(self.state, cfg.penalty_wrong, done,
                              {"status": res.status})
        new_s = self._cost(res.program)
        delta = self.prev_s / new_s - 1.0          # speedup vs prev step
        r = cfg.reward_valid + cfg.reward_speed_scale * max(
            min(delta, 3.0), -0.5)
        r *= self._decay()
        self.state = res.program
        self.prev_s = new_s
        return StepResult(self.state, r, done,
                          {"status": "ok",
                           "speedup": self.baseline_s / new_s})


# ---------------------------------------------------------------------------
# offline tree
# ---------------------------------------------------------------------------

def action_key(a: A.Action) -> str:
    return f"{a.kind}|{a.region}|{a.param!r}"


@dataclasses.dataclass
class TreeNode:
    program: KernelProgram
    cost_s: float
    children: dict = dataclasses.field(default_factory=dict)
    # action_key -> (child_fp | None, status)


class OfflineTree:
    """Materialized transition cache for offline policy training.

    When given a ``store`` (``core.engine.TranspositionStore``), the tree
    interns and expands against that shared backing store, so live envs,
    pipelines and other trees reuse its transitions (and vice versa).
    """

    def __init__(self, task: KernelProgram, store=None, target=None):
        self.task = task
        self.store = store
        self.target = hardware.resolve(target)
        self.nodes: dict[str, TreeNode] = {}
        self.root = self._intern(task)

    def _intern(self, prog: KernelProgram) -> str:
        if self.store is not None:
            fp = self.store.intern(prog, self.target)
            if fp not in self.nodes:
                self.nodes[fp] = TreeNode(prog,
                                          self.store.cost(prog,
                                                          self.target))
            return fp
        fp = prog.fingerprint()
        if fp not in self.nodes:
            self.nodes[fp] = TreeNode(
                prog, cost_model.program_cost(prog, self.target).total_s)
        return fp

    def expand(self, fp: str, action: A.Action,
               coder: MicroCoder) -> tuple[str | None, str]:
        node = self.nodes[fp]
        k = action_key(action)
        if k in node.children:
            return node.children[k]
        if self.store is not None:
            res = self.store.apply(coder, node.program, action)
        else:
            res = coder.apply(node.program, action)
        child = self._intern(res.program) if res.status == "ok" and \
            not rules.is_terminal(action) else None
        node.children[k] = (child, res.status)
        return node.children[k]

    def materialized_actions(self, fp: str) -> list[tuple[A.Action, str]]:
        node = self.nodes[fp]
        out = []
        import ast
        for k, (child, status) in node.children.items():
            kind, region, param = k.split("|", 2)
            out.append((A.Action(kind, region,
                                 ast.literal_eval(param)), status))
        return out

    @property
    def size(self) -> int:
        return len(self.nodes)


class OfflineEnv:
    """Replays an OfflineTree with the same reward shaping as KernelEnv.

    The candidate set at each state is the tree's materialized actions
    (plus stop) — the policy learns from offline data exactly as in the
    paper's environment design.
    """

    def __init__(self, tree: OfflineTree, cfg: EnvConfig | None = None):
        self.tree = tree
        self.cfg = cfg if cfg is not None else EnvConfig()
        self.baseline_s = tree.nodes[tree.root].cost_s

    def reset(self) -> str:
        self.fp = self.tree.root
        self.t = 0
        self.prev_s = self.baseline_s
        return self.fp

    def program(self, fp: str | None = None) -> KernelProgram:
        return self.tree.nodes[fp or self.fp].program

    def candidates(self) -> list[A.Action]:
        acts = [a for a, _ in
                self.tree.materialized_actions(self.fp)]
        if not any(rules.is_terminal(a) for a in acts):
            acts.append(A.STOP)
        return acts

    def step(self, action: A.Action) -> StepResult:
        cfg = self.cfg
        self.t += 1
        done = self.t >= cfg.max_steps
        decay = max(cfg.decay_floor, 1.0 - cfg.decay_per_step * self.t)
        if rules.is_terminal(action):
            final = self.baseline_s / self.prev_s
            r = 0.25 * max(0.0, final - 1.0)
            return StepResult(self.program(), r, True,
                              {"status": "stop", "speedup": final})
        child, status = self.tree.nodes[self.fp].children.get(
            action_key(action), (None, "compile_error"))
        if status == "compile_error":
            return StepResult(self.program(), cfg.penalty_compile, done,
                              {"status": status})
        if status == "wrong_result":
            return StepResult(self.program(), cfg.penalty_wrong, done,
                              {"status": status})
        new_s = self.tree.nodes[child].cost_s
        delta = self.prev_s / new_s - 1.0
        r = (cfg.reward_valid + cfg.reward_speed_scale *
             max(min(delta, 3.0), -0.5)) * decay
        self.fp = child
        self.prev_s = new_s
        return StepResult(self.program(), r, done,
                          {"status": "ok",
                           "speedup": self.baseline_s / new_s})
