"""End-to-end behaviour tests for the MTMC system + training substrate."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RunConfig, ShapeConfig
from repro.configs.registry import get_config, reduced
from repro.core import (Action, MTMCPipeline, StructuredMicroCoder,
                        candidate_actions, program_cost)
from repro.core import tasks as T
from repro.core.kernel_ir import evaluate, make_inputs
from repro.data.pipeline import host_batch
from repro.models import api
from repro.serve.engine import Engine, make_serve_step, \
    prefill_transformer
from repro.train.trainer import Trainer


# ---------------------------------------------------------------------------
# MTMC core behaviour
# ---------------------------------------------------------------------------

def test_flash_fusion_discovery():
    """The canonical MTMC result: the attention triple fuses into one
    flash kernel, correct and faster."""
    task = T._attn_program("attn", 2, 512, 4, 64)
    pipe = MTMCPipeline(mode="greedy_cost", max_steps=8)
    res = pipe.optimize(task)
    assert res.correct
    assert res.speedup > 2.0
    assert [n.op for n in res.program.nodes] == ["attention"]


def test_fusion_rewrite_preserves_semantics():
    task = T._attn_program("attn", 1, 256, 2, 32)
    mc = StructuredMicroCoder()
    r1 = mc.apply(task, Action("fusion", "scores", ("probs",)))
    r2 = mc.apply(r1.program, Action("fusion", "scores", ("out",)))
    inputs = make_inputs(task, jax.random.PRNGKey(0))
    a = evaluate(task, inputs)[0]
    b = evaluate(r2.program, inputs)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_illegal_actions_are_compile_errors():
    task = T.kb_level2()[0]            # gemm_bias_relu
    mc = StructuredMicroCoder()
    # tile not dividing
    r = mc.apply(task, Action("tiling", "y0", (("bm", 100),)))
    assert r.status == "compile_error"
    # bogus region
    r = mc.apply(task, Action("tiling", "nope", (("bm", 128),)))
    assert r.status == "compile_error"
    # non-adjacent fusion
    r = mc.apply(task, Action("fusion", "y0", ("y",)))
    assert r.status == "compile_error"
    # VMEM overflow
    r = mc.apply(task, Action("tiling", "y0",
                              (("bm", 8192), ("bn", 8192),
                               ("bk", 1024))))
    assert r.status == "compile_error"


def test_every_benchmark_task_evaluates():
    for suite in (T.kb_level1(), T.kb_level2(), T.kb_level3(), T.tb_t(),
                  T.tb_g()):
        for task in suite:
            outs = evaluate(task, make_inputs(task,
                                              jax.random.PRNGKey(1)))
            assert all(bool(jnp.all(jnp.isfinite(o))) for o in outs), \
                task.name
            c = program_cost(task)
            assert c.total_s > 0


def test_candidate_actions_valid():
    task = T.kb_level2()[2]
    mc = StructuredMicroCoder()
    cands = candidate_actions(task)
    assert any(a.kind == "fusion" for a in cands)
    assert any(a.kind == "tiling" for a in cands)
    ok = sum(mc.apply(task, a).status == "ok" for a in cands)
    assert ok >= len(cands) // 2   # curated space is mostly-valid


def test_greedy_cost_monotone():
    """greedy_cost never returns a slower program than the baseline."""
    for task in T.kb_level2():
        res = MTMCPipeline(mode="greedy_cost", max_steps=6,
                           validate=False).optimize(task)
        assert res.speedup >= 0.999, (task.name, res.speedup)


# ---------------------------------------------------------------------------
# training loop behaviour
# ---------------------------------------------------------------------------

def _tiny_cfg():
    cfg = reduced(get_config("qwen2_5_3b"))
    return dataclasses.replace(cfg, n_layers=2, d_model=64,
                               vocab_size=128, true_vocab_size=128)


def test_loss_decreases():
    cfg = _tiny_cfg()
    shape = ShapeConfig("s", 64, 4, "train")
    tr = Trainer(cfg, shape, RunConfig(accum_steps=1))
    st = tr.init_state()
    st = tr.run_steps(st, 20)
    losses = [m["loss"] for m in tr.metrics_log]
    # robust to step-to-step noise: late average < early average
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses


def test_checkpoint_resume_exact():
    """Stop/restart mid-run == uninterrupted run (bitwise params)."""
    cfg = _tiny_cfg()
    shape = ShapeConfig("s", 32, 4, "train")
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(cfg, shape, RunConfig(accum_steps=1), ckpt_dir=d,
                     ckpt_every=3)
        st = tr.init_state()
        st = tr.run_steps(st, 3)          # ckpt written at step 3
        st = tr.run_steps(st, 2)          # continue to 5
        direct = st.params
        # "crash" and restore from step 3, replay to 5
        tr2 = Trainer(cfg, shape, RunConfig(accum_steps=1), ckpt_dir=d)
        st2 = tr2.restore_or_init()
        assert st2.step == 3
        st2 = tr2.run_steps(st2, 2)
        diff = jax.tree.reduce(max, jax.tree.map(
            lambda a, b: float(jnp.abs(jnp.asarray(a, jnp.float32)
                                       - jnp.asarray(b, jnp.float32)
                                       ).max()), direct, st2.params))
        assert diff < 1e-6, diff


def test_data_determinism_across_topologies():
    """Global batch at step k is identical no matter how many hosts."""
    cfg = _tiny_cfg()
    shape = ShapeConfig("s", 32, 8, "train")
    whole = host_batch(cfg, shape, 5, process_index=0, process_count=1)
    parts = [host_batch(cfg, shape, 5, process_index=i, process_count=4)
             for i in range(4)]
    merged = np.concatenate([p["tokens"] for p in parts])
    np.testing.assert_array_equal(whole["tokens"], merged)


# ---------------------------------------------------------------------------
# serving behaviour
# ---------------------------------------------------------------------------

def test_decode_matches_teacher_forcing():
    cfg = _tiny_cfg()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    from repro.models import transformer
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 1, 100)
    logits_full, _ = transformer.forward(cfg, params, {"tokens": toks},
                                         remat=False)
    lg, cache = prefill_transformer(cfg, params, toks[:, :7], 12)
    step = make_serve_step(cfg)
    lg2, _ = step(params, cache, toks[:, 7:8], jnp.int32(7))
    np.testing.assert_allclose(np.asarray(lg2[:, 0]),
                               np.asarray(logits_full[:, 7]),
                               atol=2e-3, rtol=2e-3)


def test_engine_batched_generation():
    cfg = _tiny_cfg()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=32, batch_slots=2)
    prompts = [jnp.array([1, 2, 3], jnp.int32),
               jnp.array([4, 5], jnp.int32),
               jnp.array([6], jnp.int32)]
    outs = eng.generate(prompts, max_new_tokens=4)
    assert len(outs) == 3
    assert all(len(o) == 4 for o in outs)
    # batched == solo generation for the same prompt
    solo = eng.generate([prompts[0]], max_new_tokens=4)
    assert outs[0] == solo[0]
