"""Semantic optimization action space (Macro Thinking's vocabulary).

An action = (optimization type, code region, parameter) — exactly the
paper's "(Optimization Type, Code Region)" with the concrete knob value.
``candidate_actions`` performs the dataflow analysis that determines
syntactically/semantically valid regions: fusion candidates are adjacent
producer/consumer group pairs; tiling/pipeline/reorder target existing
fused kernels.

The curated space ("w/ AS" in Table 7) only proposes hardware-meaningful
values (MXU-aligned tiles, realistic pipeline depths, accumulator-legal
loop orders first).  ``unrestricted_actions`` is the "w/o AS" ablation: it
also proposes misaligned tiles, bogus regions and illegal fusions — the
way an unconstrained LLM does.
"""
from __future__ import annotations

import dataclasses
import itertools

from repro.core.kernel_ir import KernelProgram

TILE_PRESETS = {
    "matmul": [{"bm": m, "bn": n, "bk": k}
               for m, n, k in [(128, 128, 128), (256, 128, 128),
                               (128, 256, 128), (256, 256, 128),
                               (512, 128, 128), (128, 128, 256),
                               (512, 256, 128), (256, 256, 256),
                               (64, 64, 64)]],
    "flash_attention": [{"bq": q, "bk": k}
                        for q, k in [(128, 128), (256, 128), (128, 256),
                                     (256, 256), (512, 128), (64, 64),
                                     (512, 256), (1024, 128)]],
    "rmsnorm": [{"rows": r} for r in (128, 256, 512, 1024)],
    "rwkv6_scan": [{"chunk": c} for c in (16, 32, 64, 128)],
    "ssm_scan": [{"chunk": c} for c in (16, 32, 64, 128)],
    "grouped_matmul": [{"bc": c, "bf": f, "bd": d}
                       for c, f, d in [(128, 128, 128), (256, 128, 128),
                                       (128, 256, 128), (256, 256, 128),
                                       (512, 128, 128)]],
}

BAD_TILES = [{"bm": 96, "bn": 80, "bk": 56}, {"bm": 8192, "bn": 8192,
             "bk": 8192}, {"bq": 100, "bk": 60}, {"chunk": 7},
             {"bm": 33, "bn": 100, "bk": 17}]

LOOP_ORDERS = [("m", "n", "k"), ("n", "m", "k"),
               ("m", "k", "n"), ("k", "m", "n")]
PIPELINE_DEPTHS = (1, 2, 3, 4)


@dataclasses.dataclass(frozen=True)
class Action:
    kind: str          # tiling | fusion | pipeline | reorder | stop
    region: str        # group root node name ("" for stop)
    param: tuple = ()  # knob payload, hashable

    def describe(self) -> str:
        if self.kind == "stop":
            return "stop optimization"
        p = dict(self.param) if self.param and isinstance(
            self.param[0], tuple) else self.param
        return f"{self.kind} @ {self.region} -> {p}"


STOP = Action("stop", "")


def _sched_kind_of_group(prog: KernelProgram,
                         group: tuple[str, ...]) -> str:
    from repro.core.kernel_ir import _sched_kind
    nm = prog.node_map
    for name in group:
        k = _sched_kind(nm[name].op)
        if k != "elementwise":
            return k
    return "elementwise"


def fusion_candidates(prog: KernelProgram) -> list[tuple[str, str]]:
    """Adjacent (producer_root, consumer_root) group pairs (dataflow)."""
    roots = {}
    for g in prog.fusion_groups:
        for n in g:
            roots[n] = prog.group_root(g)
    pairs = []
    nm = prog.node_map
    for n in prog.nodes:
        for inp in n.inputs:
            if inp in nm and roots[inp] != roots[n.name]:
                pairs.append((roots[inp], roots[n.name]))
    return sorted(set(pairs))


def candidate_actions(prog: KernelProgram) -> list[Action]:
    acts: list[Action] = []
    for g in prog.fusion_groups:
        root = prog.group_root(g)
        kind = _sched_kind_of_group(prog, g)
        for preset in TILE_PRESETS.get(kind, []):
            acts.append(Action("tiling", root,
                               tuple(sorted(preset.items()))))
        if kind in ("matmul", "grouped_matmul"):
            for order in LOOP_ORDERS:
                acts.append(Action("reorder", root, order))
        if kind != "elementwise":
            for d in PIPELINE_DEPTHS:
                acts.append(Action("pipeline", root, (d,)))
    for a, b in fusion_candidates(prog):
        acts.append(Action("fusion", a, (b,)))
    acts.append(STOP)
    return acts


def unrestricted_actions(prog: KernelProgram) -> list[Action]:
    """'w/o AS' ablation: adds invalid-prone proposals."""
    acts = candidate_actions(prog)
    names = [n.name for n in prog.nodes]
    for g in prog.fusion_groups:
        root = prog.group_root(g)
        for bad in BAD_TILES:
            acts.append(Action("tiling", root,
                               tuple(sorted(bad.items()))))
    # bogus fusions between arbitrary non-adjacent nodes
    for a, b in itertools.islice(itertools.combinations(names, 2), 12):
        acts.append(Action("fusion", a, (b,)))
    return acts
