"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run
JSON results.

  python -m repro.roofline.report results/dryrun_single_pod.json ...
"""
from __future__ import annotations

import json
import sys


def load(paths: list[str]) -> list[dict]:
    rows = []
    for p in paths:
        with open(p) as f:
            rows.extend(json.load(f))
    return rows


def dryrun_table(rows: list[dict]) -> str:
    out = ["| arch | shape | mesh | status | compile_s | args GB/dev | "
           "temp GB/dev | accum |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "SKIP":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"SKIP ({r.get('skipped', '')[:46]}...) "
                       f"| - | - | - | - |")
            continue
        if r["status"] == "FAIL":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"FAIL {r.get('error', '')[:40]} | - | - | - | - |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | "
            f"{r.get('compile_s', '-')} | {r.get('arg_gb', '-')} | "
            f"{r.get('temp_gb', '-')} | {r.get('accum', '-')} |")
    return "\n".join(out)


def _one_liner(rl: dict) -> str:
    """What would move the dominant term down."""
    d = rl["dominant"]
    if d == "memory":
        if rl.get("memory_s_kernelized", 1e9) < 0.7 * rl["memory_s"]:
            return ("attention-score HBM traffic dominates -> Pallas "
                    "flash kernel keeps S^2 tiles in VMEM")
        return ("activation traffic dominates -> larger microbatch/"
                "fused elementwise chains, bf16 residuals")
    if d == "collective":
        return ("grad/param all-reduce bound -> overlap with backward, "
                "reduce-scatter + FSDP resharding")
    return "MXU-bound -> tile alignment / fewer remat recomputes"


def roofline_table(rows: list[dict]) -> str:
    out = ["| arch | shape | mesh | compute_s | memory_s | mem_s(kern) |"
           " coll_s | dominant | useful | roofline_frac | fix |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "OK" or "roofline" not in r:
            continue
        rl = r["roofline"]
        out.append(
            f"| {rl['arch']} | {rl['shape']} | {rl['mesh']} | "
            f"{rl['compute_s']:.3f} | {rl['memory_s']:.3f} | "
            f"{rl.get('memory_s_kernelized', 0):.3f} | "
            f"{rl['collective_s']:.3f} | {rl['dominant']} | "
            f"{rl['useful_ratio']:.2f} | {rl['roofline_fraction']:.4f} | "
            f"{_one_liner(rl)} |")
    return "\n".join(out)


def collective_summary(rows: list[dict]) -> str:
    out = ["| arch | shape | mesh | all-reduce | all-gather | "
           "reduce-scatter | all-to-all | permute |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "OK" or "roofline" not in r:
            continue
        rl = r["roofline"]
        c = rl["collectives"]
        gb = lambda k: f"{c.get(k, 0) / 1e9:.2f}"
        out.append(f"| {rl['arch']} | {rl['shape']} | {rl['mesh']} | "
                   f"{gb('all-reduce')} | {gb('all-gather')} | "
                   f"{gb('reduce-scatter')} | {gb('all-to-all')} | "
                   f"{gb('collective-permute')} |")
    return "\n".join(out)


def main():
    rows = load(sys.argv[1:])
    print("## Dry-run\n")
    print(dryrun_table(rows))
    print("\n## Roofline\n")
    print(roofline_table(rows))
    print("\n### Collective bytes per device (GB)\n")
    print(collective_summary(rows))


if __name__ == "__main__":
    main()
