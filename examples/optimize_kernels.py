"""MTMC as the framework autotuner: tune a model's hot kernels and
install the schedules into the kernel registry.

    PYTHONPATH=src python examples/optimize_kernels.py [--arch qwen2_5_3b]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs.base import SHAPES  # noqa: E402
from repro.configs.registry import get_config  # noqa: E402
from repro.core.autotune import tune_model_kernels  # noqa: E402
from repro.kernels import ops  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_5_3b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    print(f"tuning hot kernels for {cfg.name} @ {shape.name} ...")
    report = tune_model_kernels(cfg, shape)
    for kname, r in report.items():
        print(f"\n[{kname}] modeled speedup {r['speedup']:.2f}x "
              f"correct={r['correct']}")
        for step in r["trace"]:
            print(f"    - {step}")
        print(f"    installed schedule: {r['schedule']}")
    print(f"\nregistry now holds {len(ops._SCHEDULES)} tuned schedules; "
          "model forwards pick them up on TPU backends via kernels.ops.")


if __name__ == "__main__":
    main()
