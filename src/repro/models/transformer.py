"""Dense decoder-only transformer (qwen2.5 / qwen3 / yi / gemma / dbrx /
phi-moe families — MoE swaps the MLP via models.moe).

Interface (uniform across model families, see models/api.py):
    param_tree(cfg, make)                         -> params declaration
    forward(cfg, params, batch, rules, remat)     -> (logits, aux)
    cache_tree(cfg, make, batch, max_len)         -> decode cache decl
    decode_step(cfg, params, cache, tokens, pos)  -> (logits, new_cache)

Layer parameters are stacked on a leading ``layers`` axis and consumed via
``jax.lax.scan`` (compact HLO => fast 512-device compiles); remat wraps the
scanned block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import layers
from repro.models.layers import (
    apply_rope, linear, normal_init, ones_init, rms_norm, swiglu, zeros_init,
)

# ---------------------------------------------------------------------------
# parameter tree
# ---------------------------------------------------------------------------

def block_tree(cfg: ModelConfig, make, prefix: str = "",
               n_layers: int | None = None, cross: bool = False):
    """Stacked per-layer params for the standard attention+MLP block."""
    L = n_layers if n_layers is not None else cfg.n_layers
    D, H, KV, hd, FF = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                        cfg.head_dim, cfg.d_ff)
    w = normal_init(0.02)
    wo_init = normal_init(layers.depth_scale(0.02, L))
    p = prefix
    t = {
        "attn_norm": make(p + "attn_norm", (L, D), ("layers", "embed"),
                          ones_init()),
        "wq": make(p + "wq", (L, D, H * hd), ("layers", "embed", "heads"), w),
        "wk": make(p + "wk", (L, D, KV * hd),
                   ("layers", "embed", "kv_heads"), w),
        "wv": make(p + "wv", (L, D, KV * hd),
                   ("layers", "embed", "kv_heads"), w),
        "wo": make(p + "wo", (L, H * hd, D), ("layers", "heads", "embed"),
                   wo_init),
        "mlp_norm": make(p + "mlp_norm", (L, D), ("layers", "embed"),
                         ones_init()),
    }
    if cfg.family == "moe":
        from repro.models import moe
        t.update(moe.moe_mlp_tree(cfg, make, L, p))
    else:
        t.update({
            "w_gate": make(p + "w_gate", (L, D, FF),
                           ("layers", "embed", "mlp"), w),
            "w_up": make(p + "w_up", (L, D, FF),
                         ("layers", "embed", "mlp"), w),
            "w_down": make(p + "w_down", (L, FF, D),
                           ("layers", "mlp", "embed"), wo_init),
        })
    if cfg.qkv_bias:
        t["bq"] = make(p + "bq", (L, H * hd), ("layers", "heads"),
                       zeros_init())
        t["bk"] = make(p + "bk", (L, KV * hd), ("layers", "kv_heads"),
                       zeros_init())
        t["bv"] = make(p + "bv", (L, KV * hd), ("layers", "kv_heads"),
                       zeros_init())
    if cfg.qk_norm:
        t["q_norm"] = make(p + "q_norm", (L, hd), ("layers", None),
                           ones_init())
        t["k_norm"] = make(p + "k_norm", (L, hd), ("layers", None),
                           ones_init())
    if cross:
        t["cross_norm"] = make(p + "cross_norm", (L, D),
                               ("layers", "embed"), ones_init())
        t["c_wq"] = make(p + "c_wq", (L, D, H * hd),
                         ("layers", "embed", "heads"), w)
        t["c_wk"] = make(p + "c_wk", (L, D, KV * hd),
                         ("layers", "embed", "kv_heads"), w)
        t["c_wv"] = make(p + "c_wv", (L, D, KV * hd),
                         ("layers", "embed", "kv_heads"), w)
        t["c_wo"] = make(p + "c_wo", (L, H * hd, D),
                         ("layers", "heads", "embed"), wo_init)
    return t


def param_tree(cfg: ModelConfig, make):
    V, D = cfg.vocab_size, cfg.d_model
    t = {
        "embed": make("embed", (V, D), ("vocab", "embed"),
                      normal_init(0.02)),
        "blocks": block_tree(cfg, make),
        "final_norm": make("final_norm", (D,), ("embed",), ones_init()),
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = make("lm_head", (D, V), ("embed", "vocab"),
                            normal_init(0.02))
    return t


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _window_for_layer(cfg: ModelConfig, idx):
    """Per-layer sliding window: 0 (global) on cfg.global_layers."""
    if not cfg.swa_window:
        return 0
    if isinstance(idx, int):
        return 0 if idx in cfg.global_layers else cfg.swa_window
    is_global = jnp.zeros((), bool)
    for g in cfg.global_layers:
        is_global |= idx == g
    return jnp.where(is_global, 0, cfg.swa_window)


def _q_axes(cfg: ModelConfig, rules):
    """Shard q over heads when divisible; otherwise over the query
    sequence axis ("seq" logical rule — §Perf H1: hymba's 25 heads can't
    split 16 ways, so the S x S score tensors shard over seq instead of
    replicating)."""
    if rules is not None and cfg.n_heads % max(rules.tp, 1) != 0:
        return ("batch", "seq", "heads", None)
    return ("batch", None, "heads", None)


def attn_block(cfg: ModelConfig, p: dict, x: jax.Array, *, positions,
               window=0, bidir_prefix=0, rules=None):
    """Pre-norm attention sub-block -> residual delta."""
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = ops.rmsnorm(x, p["attn_norm"], eps=cfg.norm_eps)
    q = linear(h, p["wq"], p.get("bq")).reshape(B, S, H, hd)
    k = linear(h, p["wk"], p.get("bk")).reshape(B, S, KV, hd)
    v = linear(h, p["wv"], p.get("bv")).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if rules is not None:
        q = rules.constrain(q, _q_axes(cfg, rules))
        k = rules.constrain(k, ("batch", None, "kv_heads", None))
        v = rules.constrain(v, ("batch", None, "kv_heads", None))
    o = ops.attention(q, k, v, causal=True, window=window,
                      bidir_prefix=bidir_prefix)
    return linear(o.reshape(B, S, H * hd), p["wo"])


def mlp_block(cfg: ModelConfig, p: dict, x: jax.Array, rules=None):
    """Pre-norm MLP sub-block -> (residual delta, aux_loss)."""
    h = ops.rmsnorm(x, p["mlp_norm"], eps=cfg.norm_eps)
    if cfg.family == "moe":
        from repro.models import moe
        return moe.moe_mlp(cfg, p, h, rules)
    if cfg.family == "vlm":        # gemma GeGLU
        g = jnp.einsum("...d,df->...f", h, p["w_gate"].astype(h.dtype))
        u = jnp.einsum("...d,df->...f", h, p["w_up"].astype(h.dtype))
        out = jnp.einsum("...f,fd->...d", jax.nn.gelu(g) * u,
                         p["w_down"].astype(h.dtype))
        return out, jnp.float32(0)
    return swiglu(h, p["w_gate"], p["w_up"], p["w_down"]), jnp.float32(0)


def _pin_bf16(delta: jax.Array, rules) -> jax.Array:
    """§Perf H2: keep the TP partial-sum all-reduce in bf16.

    XLA hoists the next rms-norm's f32 upcast ABOVE the contraction
    all-reduce (numerically nicer, 2x the ICI bytes).  An optimization
    barrier on the bf16 residual delta pins the convert below the
    all-reduce.  Enabled via ShardingRules flag "bf16_reduce"."""
    if rules is not None and "bf16_reduce" in rules.flags:
        return jax.lax.optimization_barrier(delta)
    return delta


def make_block_fn(cfg: ModelConfig, *, rules=None, bidir_prefix=0,
                  remat=True, collect_cache=False, pad_mask=None):

    def block(x, scanned):
        p, idx = scanned
        S = x.shape[1]
        positions = jnp.arange(S)
        window = _window_for_layer(cfg, idx)
        h = ops.rmsnorm(x, p["attn_norm"], eps=cfg.norm_eps)
        B = x.shape[0]
        H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        q = linear(h, p["wq"], p.get("bq")).reshape(B, S, H, hd)
        k = linear(h, p["wk"], p.get("bk")).reshape(B, S, KV, hd)
        v = linear(h, p["wv"], p.get("bv")).reshape(B, S, KV, hd)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        if rules is not None:
            q = rules.constrain(q, _q_axes(cfg, rules))
            k = rules.constrain(k, ("batch", None, "kv_heads", None))
            v = rules.constrain(v, ("batch", None, "kv_heads", None))
        o = ops.attention(q, k, v, causal=True, window=window,
                          bidir_prefix=bidir_prefix, kv_mask=pad_mask)
        x = x + _pin_bf16(linear(o.reshape(B, S, H * hd), p["wo"]),
                          rules)
        delta, aux = mlp_block(cfg, p, x, rules)
        x = x + _pin_bf16(delta, rules)
        if rules is not None:
            x = rules.constrain(x, ("batch", None, None))
        ys = ((k, v), aux) if collect_cache else aux
        return x, ys

    if remat:
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable)
    return block


def forward(cfg: ModelConfig, params: dict, batch: dict, *, rules=None,
            remat: bool = True, collect_cache: bool = False,
            pad_mask=None):
    """batch: {'tokens': (B,S)[, 'prefix_embeds': (B,P,D)]}.

    pad_mask (B,S) bool marks real (non-pad) tokens; pad key/value
    positions are masked out of every attention so mixed-length
    left-padded rows match their solo forward."""
    tokens = batch["tokens"]
    prefix_embeds = batch.get("prefix_embeds")
    cdt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"].astype(cdt)[tokens]
    if cfg.family == "vlm":
        x = x * jnp.asarray(cfg.d_model ** 0.5, cdt)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cdt), x], axis=1)
    bidir = cfg.prefix_len if prefix_embeds is not None else 0
    if rules is not None:
        x = rules.constrain(x, ("batch", None, None))
    block = make_block_fn(cfg, rules=rules, bidir_prefix=bidir,
                          remat=remat, collect_cache=collect_cache,
                          pad_mask=pad_mask)
    idxs = jnp.arange(cfg.n_layers)
    x, ys = jax.lax.scan(block, x, (params["blocks"], idxs))
    x = ops.rmsnorm(x, params["final_norm"], eps=cfg.norm_eps)
    logits = unembed(cfg, params, x, rules)
    if collect_cache:
        (kvs, aux) = ys
        return logits, jnp.mean(aux), kvs
    return logits, jnp.mean(ys)


def unembed(cfg: ModelConfig, params: dict, x: jax.Array, rules=None):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("...d,dv->...v", x, w.astype(x.dtype))
    if rules is not None:
        logits = rules.constrain(logits, ("batch", None, "vocab"))
    return logits


# ---------------------------------------------------------------------------
# serving: KV cache + decode step
# ---------------------------------------------------------------------------

def cache_tree(cfg: ModelConfig, make, batch: int, max_len: int):
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    shape = (L, batch, max_len, KV, hd)
    axes = ("layers", "batch", "kv_seq", "kv_heads", None)
    return {"k": make("cache_k", shape, axes, zeros_init()),
            "v": make("cache_v", shape, axes, zeros_init())}


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array, pos: jax.Array, *, rules=None,
                start: jax.Array | None = None):
    """One-token decode: tokens (B,1) -> (logits, new_cache).

    pos is the write/attend position of the new token — a scalar shared
    by the whole batch (lockstep decode), or a (B,) vector of per-slot
    positions (continuous batching: each slot is at its own depth).
    start (scalar or (B,)) fences off cache positions below it, for
    caches prefilled with a left-pad offset."""
    cdt = jnp.dtype(cfg.compute_dtype)
    B = tokens.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    x = params["embed"].astype(cdt)[tokens]
    if cfg.family == "vlm":
        x = x * jnp.asarray(cfg.d_model ** 0.5, cdt)
    if rules is not None:
        x = rules.constrain(x, ("batch", None, None))
    pos = jnp.asarray(pos)
    vector_pos = pos.ndim == 1
    positions = pos[:, None] if vector_pos else jnp.full((1,), pos)

    def block(x, scanned):
        p, idx, ck, cv = scanned
        window = _window_for_layer(cfg, idx)
        h = ops.rmsnorm(x, p["attn_norm"], eps=cfg.norm_eps)
        q = linear(h, p["wq"], p.get("bq")).reshape(B, 1, H, hd)
        k = linear(h, p["wk"], p.get("bk")).reshape(B, 1, KV, hd)
        v = linear(h, p["wv"], p.get("bv")).reshape(B, 1, KV, hd)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"], cfg.norm_eps)
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        if vector_pos:
            ck = ck.at[jnp.arange(B), pos].set(k[:, 0].astype(ck.dtype))
            cv = cv.at[jnp.arange(B), pos].set(v[:, 0].astype(cv.dtype))
        else:
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, pos, 0, 0))
        if rules is not None:
            ck = rules.constrain(ck, ("batch", "kv_seq", "kv_heads", None))
            cv = rules.constrain(cv, ("batch", "kv_seq", "kv_heads", None))
        o = ops.decode_attention(q, ck, cv, pos, window=window,
                                 start=start)
        x = x + linear(o.reshape(B, 1, H * hd), p["wo"])
        delta, _ = mlp_block(cfg, p, x, rules)
        x = x + delta
        return x, (ck, cv)

    idxs = jnp.arange(cfg.n_layers)
    x, (new_k, new_v) = jax.lax.scan(
        block, x, (params["blocks"], idxs, cache["k"], cache["v"]))
    x = ops.rmsnorm(x, params["final_norm"], eps=cfg.norm_eps)
    logits = unembed(cfg, params, x, rules)
    return logits, {"k": new_k, "v": new_v}
