"""Table 8 — hardware-target × search-strategy grid (ours).

The paper's portability pitch ("poor portability" of expert kernels)
plus its exploration claim, measured together: every KernelBench-level
task is optimized by each registered ``SearchStrategy`` against each
registered ``HardwareTarget``, all sharing one transposition store
(transitions and oracle checks are target-independent; only cost memos
fork per target).  Emitted per (target, strategy): mean modeled time,
execute accuracy, mean speedup — plus a beam-vs-greedy row with the
fraction of tasks where beam strictly improves modeled cost over the
greedy baseline at equal oracle accuracy.
"""
from __future__ import annotations

from .common import STORE, WORKERS, fmt_row
from repro.core import EvalEngine, OptimizeConfig, program_cost
from repro.core import tasks as T

TARGETS = ("tpu_v5e", "tpu_v4", "gpu_a100")
STRATEGIES = ("greedy", "beam", "anneal")


def run(policy=None) -> list[str]:
    suite = T.kb_level1() + T.kb_level2() + T.kb_level3()
    rows = []
    for tname in TARGETS:
        per_strategy = {}
        for sname in STRATEGIES:
            eng = EvalEngine(None, store=STORE, workers=WORKERS,
                             config=OptimizeConfig(mode="greedy_cost",
                                                   strategy=sname,
                                                   target=tname,
                                                   max_steps=8))
            m = eng.evaluate_suite(suite)
            per_strategy[sname] = m["results"]
            rows.append(fmt_row("table8", f"{tname}/{sname}", m,
                                target=tname))
        rows.append(_beam_vs_greedy_row(tname, suite, per_strategy))
    return rows


def _beam_vs_greedy_row(tname: str, suite, per_strategy) -> str:
    """Fraction of tasks where beam strictly beats greedy's modeled
    cost (overall and on the fused-subgraph levels L2+L3, where fusion
    ordering makes exploration matter), at equal oracle accuracy."""
    wins = wins_l23 = n_l23 = 0
    acc_equal = True
    for task, g, b in zip(suite, per_strategy["greedy"],
                          per_strategy["beam"]):
        cg = program_cost(g.program, tname).total_s
        cb = program_cost(b.program, tname).total_s
        fused_level = task.name.startswith(("L2", "L3"))
        n_l23 += fused_level
        if cb < cg and b.correct:
            wins += 1
            wins_l23 += fused_level
        if g.correct != b.correct:
            acc_equal = False
    n = len(suite)
    return (f"table8/{tname}/beam_vs_greedy,0.0,"
            f"improved={wins}/{n};improved_frac={wins / n:.3f};"
            f"improved_frac_l23={wins_l23 / max(n_l23, 1):.3f};"
            f"acc_equal={int(acc_equal)}")
