"""Persistent, content-addressed measurement database.

Every measured sample is keyed by the full provenance of the number:

    (task_fp, program_fp, target, env_fp)

``task_fp``/``program_fp`` are the kernel-IR fingerprints (what was
measured), ``target`` is the hardware target the *analytic* side was
priced against (which search produced the candidate and which
calibration bucket the sample feeds), and ``env_fp`` fingerprints the
execution environment the wall-clock number came from: jax backend +
version, measurement mode (compiled vs pallas-interpret), and the
target's frozen constants.  A sample is a pure function of its key —
the DB never invalidates entries; a changed environment simply hashes
to a different ``env_fp`` and misses (the same rule the
``TranspositionStore`` uses for cost-model changes, DESIGN.md §8/§11).

Layout on disk (JSON, one file per entry, atomic writes)::

    <root>/samples/<sha16>.json   — MeasureSample
    <root>/winners/<sha16>.json   — winning program per (task, target,
                                    env): the KernelService warm-start
                                    record (DESIGN.md §11)

The DB survives process restarts: a restarted ``KernelService`` pointed
at the same directory answers repeat requests from ``winners/`` without
re-running the search, and ``calibrate.fit_calibration`` fits correction
factors from ``samples/`` accumulated across sessions.

One directory may be shared by MANY live writers — replicas of a
serving fleet, background measurement workers, restarted services
(DESIGN.md §13).  The cross-process contract:

* **Samples** are content-addressed and immutable: concurrent writers
  of the same key write identical payloads, each ``os.replace`` is
  atomic, so last-write-wins is trivially convergent and readers never
  see a torn file.
* **Winner records** are mutable (a background worker upgrades an
  analytic pick to a measured one), so each carries a monotonically
  increasing ``generation``.  ``update_winner`` performs the
  read-modify-write under a per-key lock file (``O_CREAT|O_EXCL``,
  broken when stale), so generations count writes exactly; if the lock
  cannot be acquired before ``lock_timeout_s`` the write degrades to
  plain last-write-wins (``stats["lock_timeouts"]``) — availability
  over strict ordering, still torn-free thanks to the atomic replace.
* **Reads poll the disk**: ``get_winner`` revalidates its in-memory
  cache against the file's ``(mtime_ns, size)`` stamp on every call,
  so a replica observes a peer's newly landed winner on its next
  request without any broadcast channel (``refresh()`` force-drops the
  caches for callers that want an explicit barrier).
* **Crashes leave no landmines**: a writer dying between ``open(tmp)``
  and ``os.replace`` orphans only a ``*.tmp`` file, which
  ``__init__``/``reap_stale_tmp`` deletes once its writer pid is dead
  (or the file is older than ``tmp_ttl_s``); unreadable/corrupt
  records read as misses and are counted in
  ``stats["corrupt_records"]`` instead of being silently swallowed.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import os
import threading
import time
from collections.abc import Callable, Iterator


@dataclasses.dataclass(frozen=True)
class MeasureSample:
    """One measured program: robust wall time + analytic context."""

    task_fp: str
    prog_fp: str
    target: str               # hardware-target name the search priced on
    env_fp: str               # environment fingerprint (see env_fingerprint)
    time_s: float             # trimmed-median measured seconds
    samples: tuple[float, ...]   # raw repeat times (post-warmup)
    n_rejected: int           # MAD-outlier rejections
    mode: str                 # "xla" | "pallas" | "pallas_interpret"
    analytic_s: float         # cost_model.program_cost(...).total_s
    bottleneck: str           # dominant group bottleneck: compute|memory
    env: tuple[tuple[str, str], ...] = ()   # the fingerprinted env, readable
    # the measured program itself (kernel_ir.program_to_json), embedded
    # so a sample is self-contained training data for the learned cost
    # model (DESIGN.md §17).  Optional: pre-§17 records lack it and
    # read back as None; to_json omits it when None so old fixture
    # files stay byte-stable.  Not part of the content address —
    # prog_fp already pins the program identity.
    program: dict | None = None

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["samples"] = list(self.samples)
        d["env"] = [list(kv) for kv in self.env]
        if self.program is None:
            del d["program"]
        return d

    @classmethod
    def from_json(cls, d: dict) -> MeasureSample:
        return cls(task_fp=d["task_fp"], prog_fp=d["prog_fp"],
                   target=d["target"], env_fp=d["env_fp"],
                   time_s=float(d["time_s"]),
                   samples=tuple(float(x) for x in d["samples"]),
                   n_rejected=int(d["n_rejected"]), mode=d["mode"],
                   analytic_s=float(d["analytic_s"]),
                   bottleneck=d["bottleneck"],
                   env=tuple((k, v) for k, v in d.get("env", [])),
                   program=d.get("program"))


# bump whenever kernel or lowering semantics change in a way that moves
# wall times without touching jax/backend/target (e.g. a rewritten
# Pallas kernel, a new group-lowering rule): old samples then miss
# instead of silently ranking today's programs by yesterday's timings
MEASURE_SCHEMA = 1


def env_fingerprint(target=None, mode: str = "auto",
                    rigor: tuple = ()
                    ) -> tuple[str, tuple[tuple[str, str], ...]]:
    """(12-hex fingerprint, readable env) of the measurement environment.

    Covers what changes what a wall-clock sample *means*: the jax
    backend actually executing (cpu/tpu/gpu), the jax version (compiler
    changes move timings), the measurement mode, the measurement-schema
    version (``MEASURE_SCHEMA`` — bumped on kernel/lowering semantic
    changes), the timing ``rigor`` (warmup/repeats/trim settings: a
    2-repeat spot sample must not masquerade as a 10-repeat one), and
    the target name AND a hash of its frozen constants (editing a
    registered target's numbers re-keys its samples instead of silently
    mixing them — same rule as the cost-memo invalidation, DESIGN.md
    §9).
    """
    import jax

    from repro.core import hardware
    tgt = hardware.resolve(target)
    env = (
        ("backend", str(jax.default_backend())),
        ("jax", str(jax.__version__)),
        ("mode", mode),
        ("rigor", repr(tuple(rigor))),
        ("schema", str(MEASURE_SCHEMA)),
        ("target", tgt.name),
        ("target_sha", hashlib.sha1(
            repr(tgt).encode()).hexdigest()[:8]),
    )
    fp = hashlib.sha1(repr(env).encode()).hexdigest()[:12]
    return fp, env


def _key16(*parts: str) -> str:
    return hashlib.sha1("|".join(parts).encode()).hexdigest()[:16]


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except OSError:
        return True       # exists but not ours (EPERM etc.)
    return True


def _tmp_pid(fn: str) -> int | None:
    """Writer pid from a ``<key>.json.<pid>.<tid>.tmp`` name."""
    parts = fn.split(".")
    try:
        return int(parts[-3])
    except (IndexError, ValueError):
        return None


class MeasureDB:
    """On-disk sample + winner store with an in-memory read cache.

    Thread-safe within a process; safe to share across processes (see
    the module docstring's cross-process contract).  Writes are atomic
    (tmp file + ``os.replace``) so a crashed process never leaves a
    truncated JSON entry behind, and stale tmps of dead writers are
    reaped on construction.
    """

    def __init__(self, path: str, *, tmp_ttl_s: float = 3600.0,
                 lock_timeout_s: float = 5.0,
                 lock_stale_s: float = 30.0):
        self.path = str(path)
        self._samples_dir = os.path.join(self.path, "samples")
        self._winners_dir = os.path.join(self.path, "winners")
        os.makedirs(self._samples_dir, exist_ok=True)
        os.makedirs(self._winners_dir, exist_ok=True)
        self._tmp_ttl_s = float(tmp_ttl_s)
        self._lock_timeout_s = float(lock_timeout_s)
        self._lock_stale_s = float(lock_stale_s)
        self._lock = threading.RLock()
        # serializes same-process winner read-modify-writes so threads
        # of one process never spin on each other's lock FILE (the file
        # is for OTHER processes)
        self._winner_write_lock = threading.Lock()
        self.stats = {"corrupt_records": 0, "tmp_reaped": 0,
                      "lock_timeouts": 0, "winner_refreshes": 0}
        # bounded read caches: entries always live on disk, so clearing
        # on overflow only costs a re-read — a long-lived service under
        # distinct-kernel traffic must not grow memory without bound.
        # Winner entries carry the file's (mtime_ns, size) stamp and
        # are revalidated against it on every read (peer pickup).
        self._cache_cap = 4096
        self._cache: dict[str, MeasureSample] = {}
        self._winner_cache: dict[str, tuple[tuple[int, int], dict]] = {}
        self.reap_stale_tmp()

    # -- samples -------------------------------------------------------------
    def sample_key(self, task_fp: str, prog_fp: str, target: str,
                   env_fp: str) -> str:
        return _key16(task_fp, prog_fp, target, env_fp)

    def get(self, task_fp: str, prog_fp: str, target: str,
            env_fp: str) -> MeasureSample | None:
        key = self.sample_key(task_fp, prog_fp, target, env_fp)
        with self._lock:
            hit = self._cache.get(key)
            if hit is not None:
                return hit
        d = self._read(os.path.join(self._samples_dir, key + ".json"))
        if d is None:
            return None
        s = MeasureSample.from_json(d)
        with self._lock:
            self._cache_insert(self._cache, key, s)
        return s

    def put(self, sample: MeasureSample) -> None:
        key = self.sample_key(sample.task_fp, sample.prog_fp,
                              sample.target, sample.env_fp)
        self._write(os.path.join(self._samples_dir, key + ".json"),
                    sample.to_json())
        with self._lock:
            self._cache_insert(self._cache, key, sample)

    def iter_samples(self, *, target: str | None = None,
                     env_fp: str | None = None) -> Iterator[MeasureSample]:
        """Every stored sample, optionally filtered, in deterministic
        (sorted-key) order — the canonical training-data export for
        calibration and the learned cost model.  Corrupt records —
        torn/non-JSON files AND structurally valid JSON missing sample
        fields — are skipped and counted in ``stats["corrupt_records"]``
        rather than aborting the sweep."""
        for fn in sorted(os.listdir(self._samples_dir)):
            if not fn.endswith(".json"):
                continue
            d = self._read(os.path.join(self._samples_dir, fn))
            if d is None:
                continue
            try:
                s = MeasureSample.from_json(d)
            except (KeyError, TypeError, ValueError):
                with self._lock:
                    self.stats["corrupt_records"] += 1
                continue
            if target is not None and s.target != target:
                continue
            if env_fp is not None and s.env_fp != env_fp:
                continue
            yield s

    def env_fps(self, *, target: str | None = None) -> list[str]:
        """Distinct sample env fingerprints (sorted) — what a trainer
        enumerates before filtering ``iter_samples(env_fp=...)``."""
        return sorted({s.env_fp
                       for s in self.iter_samples(target=target)})

    # -- winners (KernelService warm-start records) --------------------------
    def winner_key(self, task_fp: str, target: str, env_fp: str) -> str:
        return _key16("winner", task_fp, target, env_fp)

    def put_winner(self, task_fp: str, target: str, env_fp: str,
                   record: dict) -> dict:
        """``record`` must be JSON-safe and carry a ``program`` entry
        (``kernel_ir.program_to_json``) — enough to answer a repeat
        request in a fresh process without re-searching.  The stored
        record gains a ``generation`` one past the current on-disk one
        (last-write-wins across replicas); the stamped record is
        returned."""
        return self.update_winner(task_fp, target, env_fp,
                                  lambda old: record)

    def update_winner(self, task_fp: str, target: str, env_fp: str,
                      fn: Callable[[dict | None], dict | None]
                      ) -> dict | None:
        """Read-modify-write one winner record under the per-key lock.

        ``fn(current_record_or_None)`` returns the new record, or
        ``None`` to keep the current one (e.g. a replica's analytic
        pick refusing to clobber a background worker's measured winner
        — the KernelService merge policy, DESIGN.md §13).  The write
        gets ``generation = current + 1``; with the file lock held the
        increment is exact, on lock timeout it degrades to plain
        last-write-wins.  Returns whatever record is now current."""
        key = self.winner_key(task_fp, target, env_fp)
        path = os.path.join(self._winners_dir, key + ".json")
        with self._winner_lock(key):
            old = self._read(path)
            new = fn(old)
            if new is None:
                return old
            gen = int(old.get("generation", 0)) + 1 if old else 1
            new = dict(new, generation=gen)
            self._write(path, new)
            stamp = self._stamp(path)
        with self._lock:
            if stamp is not None:
                self._cache_insert(self._winner_cache, key,
                                   (stamp, new))
        return new

    def get_winner(self, task_fp: str, target: str,
                   env_fp: str) -> dict | None:
        """Current winner record, revalidated against the file stamp —
        a record a PEER replica landed since the last read is picked up
        here, not served stale from the cache."""
        key = self.winner_key(task_fp, target, env_fp)
        path = os.path.join(self._winners_dir, key + ".json")
        stamp = self._stamp(path)
        with self._lock:
            hit = self._winner_cache.get(key)
        if stamp is None:
            # gone from disk (clear() / external delete): a cached copy
            # would resurrect it forever
            with self._lock:
                self._winner_cache.pop(key, None)
            return None
        if hit is not None and hit[0] == stamp:
            return hit[1]
        d = self._read(path)
        if d is not None:
            with self._lock:
                if hit is not None:
                    self.stats["winner_refreshes"] += 1
                self._cache_insert(self._winner_cache, key, (stamp, d))
        return d

    def refresh(self) -> None:
        """Drop the in-memory read caches: the next read of every key
        goes to disk.  ``get_winner`` already revalidates per key by
        file stamp; this is the explicit whole-DB barrier."""
        with self._lock:
            self._cache.clear()
            self._winner_cache.clear()

    @contextlib.contextmanager
    def _winner_lock(self, key: str):
        """Cross-process per-key lock: ``O_CREAT|O_EXCL`` lock file,
        stale-broken after ``lock_stale_s`` (a holder that died cannot
        release), degrading to lockless last-write-wins after
        ``lock_timeout_s``.  Same-process threads serialize on
        ``_winner_write_lock`` first so they contend on a mutex, not
        the filesystem."""
        lock_path = os.path.join(self._winners_dir, key + ".lock")
        with self._winner_write_lock:
            fd = None
            deadline = time.monotonic() + self._lock_timeout_s
            while fd is None:
                try:
                    fd = os.open(lock_path,
                                 os.O_CREAT | os.O_EXCL | os.O_WRONLY)
                    os.write(fd, str(os.getpid()).encode())
                except FileExistsError:
                    try:
                        st = os.stat(lock_path)
                    except OSError:
                        continue          # released between open and stat
                    if time.time() - st.st_mtime > self._lock_stale_s:
                        # the holder is presumed dead; breaking the lock
                        # can race another breaker, which merely
                        # degrades this write to last-write-wins
                        try:
                            os.remove(lock_path)
                        except OSError:
                            pass
                        continue
                    if time.monotonic() > deadline:
                        with self._lock:
                            self.stats["lock_timeouts"] += 1
                        break
                    time.sleep(0.005)
            try:
                yield
            finally:
                if fd is not None:
                    os.close(fd)
                    try:
                        os.remove(lock_path)
                    except OSError:
                        pass

    # -- bookkeeping ---------------------------------------------------------
    def _cache_insert(self, cache: dict, key: str, value) -> None:
        """Caller holds the lock.  Overflow clears: disk is canonical."""
        if len(cache) >= self._cache_cap:
            cache.clear()
        cache[key] = value

    @property
    def n_samples(self) -> int:
        return sum(fn.endswith(".json")
                   for fn in os.listdir(self._samples_dir))

    @property
    def n_winners(self) -> int:
        return sum(fn.endswith(".json")
                   for fn in os.listdir(self._winners_dir))

    def clear(self) -> None:
        with self._lock:
            self._cache.clear()
            self._winner_cache.clear()
            for d in (self._samples_dir, self._winners_dir):
                for fn in os.listdir(d):
                    # tmp/lock litter goes too — clear() means "empty
                    # directory", not "empty except crash debris"
                    if fn.endswith((".json", ".tmp", ".lock")):
                        try:
                            os.remove(os.path.join(d, fn))
                        except OSError:
                            pass

    def reap_stale_tmp(self, ttl_s: float | None = None) -> int:
        """Delete orphaned ``*.tmp`` files: a writer that died between
        ``open(tmp)`` and ``os.replace`` leaves one behind forever (the
        directory scans only ever consider ``.json``).  A tmp is stale
        when its writer pid (encoded in the name) is dead, or — pid
        unparsable / recycled — when it is older than ``ttl_s``.  Runs
        on ``__init__``; returns the number reaped."""
        ttl = self._tmp_ttl_s if ttl_s is None else float(ttl_s)
        now = time.time()
        n = 0
        for d in (self._samples_dir, self._winners_dir):
            for fn in os.listdir(d):
                if not fn.endswith(".tmp"):
                    continue
                p = os.path.join(d, fn)
                try:
                    age = now - os.stat(p).st_mtime
                except OSError:
                    continue              # completed or reaped by a peer
                pid = _tmp_pid(fn)
                if (pid is not None and not _pid_alive(pid)) \
                        or age > ttl:
                    try:
                        os.remove(p)
                        n += 1
                    except OSError:
                        pass
        with self._lock:
            self.stats["tmp_reaped"] += n
        return n

    def stats_dict(self) -> dict:
        with self._lock:
            return dict(self.stats)

    @staticmethod
    def _stamp(path: str) -> tuple[int, int] | None:
        try:
            st = os.stat(path)
        except OSError:
            return None
        return (st.st_mtime_ns, st.st_size)

    # -- file IO -------------------------------------------------------------
    def _read(self, path: str) -> dict | None:
        try:
            with open(path) as f:
                return json.load(f)
        except FileNotFoundError:
            return None
        except (OSError, json.JSONDecodeError, UnicodeDecodeError):
            # unreadable or torn-looking record: a miss, but a COUNTED
            # miss — silent swallowing hid real corruption
            with self._lock:
                self.stats["corrupt_records"] += 1
            return None

    def _write(self, path: str, payload: dict) -> None:
        # unique tmp per writer: concurrent writers of the same key each
        # replace atomically (identical payloads — keys are content
        # addresses), never tripping over a shared tmp file
        tmp = f"{path}.{os.getpid()}.{threading.get_ident()}.tmp"
        try:
            with open(tmp, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        finally:
            # a failed dump/replace (full disk, unserializable payload)
            # must not orphan the tmp for the reaper to find later
            if os.path.exists(tmp):
                try:
                    os.remove(tmp)
                except OSError:
                    pass
