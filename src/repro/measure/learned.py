"""Learned cost model over the measurement DB (DESIGN.md §17).

The analytic roofline ranks *tasks* well but ranks *candidates* badly
(Spearman ~0.18 at candidate level, ~0.32 after per-bottleneck
calibration — ``results/measure_bench.txt``): candidates sit on
analytic-cost plateaus that real execution splits.  Scalar calibration
cannot separate a plateau; a model with access to the *schedule* can.

This module closes that gap with the standard autotuner recipe:

* ``featurize(prog, target)`` — a deterministic feature vector from the
  ``KernelProgram`` + its schedules + the ``HardwareTarget`` constants:
  op mix, fused-group shapes, **effective** tiles after the lowerer's
  ``min(tile, dim)`` clamp (grid cells — the term interpret-mode
  execution actually pays), VMEM tile footprint, arithmetic intensity,
  pipeline/loop-order/split-k/dtype markers, and the target's
  bandwidth/FLOP/geometry constants (so one model can transfer across
  targets).  Pure function of ``(program, target)``; never raises on
  any well-formed program (defensive per-group fallbacks are
  property-tested).
* ``fit_learned_model(samples)`` — ridge regression on ``log(time_s)``
  over MeasureDB samples that embed their program
  (``MeasureSample.program``), **group-normalized per
  (task, target, env)**: features and targets are centered within each
  candidate group before the fit, so the model learns candidate
  *ranking*, not task identity or environment scale.
* ``LearnedCostModel`` — a drop-in for ``CalibratedCostModel`` behind
  the existing pricing seams (``TranspositionStore(cost_model=...)``,
  ``OptimizeConfig.cost_model``, ``get_reward_source``).  With no
  artifact it is **bit-identical to the analytic model** (the absent /
  missing-file case), and any prediction failure — featurization
  error, feature-schema drift, out-of-training-distribution features —
  falls back to the analytic price and is counted in ``stats``.

Artifacts are pickled dicts carrying provenance ``meta`` (sample/group
counts, targets, env fingerprints, fit quality) exactly like
``results/macro_policy.pkl``; ``python -m repro.measure.train_cost_model``
fits one from any MeasureDB directory, and ``repro.analysis.lint
--artifact`` sweeps the meta in CI.
"""
from __future__ import annotations

import dataclasses
import math
import os
import pickle
from collections.abc import Iterable

import numpy as np

from repro.core import cost_model, hardware
from repro.core.cost_model import ProgramCost
from repro.core.kernel_ir import (KernelProgram, program_from_json,
                                  sched_kind, sched_kind_of_group)

# bump when the feature vector changes shape or meaning: an artifact
# fit under another version must fall back to analytic pricing instead
# of silently dotting mismatched coordinates
FEATURE_VERSION = 1

# the op vocabulary contributing op-mix counts (kernel_ir's op set)
_OPS = ("matmul", "grouped_matmul", "attention", "qk_scores", "av",
        "softmax", "rmsnorm", "row_max", "row_sum", "rwkv_chunk",
        "ssm_chunk", "bias", "add", "mul", "relu", "gelu", "silu",
        "square")

# |predicted - analytic| log-time is clamped to this many nats: even an
# in-distribution prediction must not move a price by more than ~e^12
# (~1.6e5x) from the roofline — interpret-mode vs analytic gaps are
# ~1e3x, so this never binds on sane predictions but caps the damage of
# a pathological extrapolation
LOG_ANCHOR_CLIP = 12.0


def _log1p(v: float) -> float:
    return math.log1p(max(0.0, float(v)))


def feature_names() -> tuple[str, ...]:
    names = ["n_nodes", "n_groups", "n_inputs", "n_outputs"]
    names += [f"op_{op}" for op in _OPS]
    names += [
        "log_analytic_s", "log_mxu_flops", "log_vpu_flops",
        "log_hbm_bytes", "arith_intensity", "log_compute_s",
        "log_memory_s", "compute_memory_ratio", "frac_compute_bound",
        "log_grid_cells", "log_max_grid_cells", "log_vmem_bytes",
        "log_max_vmem_bytes", "mean_mxu_efficiency",
        "mean_pipeline_depth", "frac_pipelined", "frac_reordered",
        "n_epilogues", "split_k_total", "n_dtype_marked",
        "min_eff_tile", "mean_log_eff_tile",
        "frac_divisible", "log_kernel_grid_cells", "frac_lowerable",
    ]
    names += [
        "tgt_log_matmul_flops", "tgt_log_vector_flops", "tgt_log_hbm_bw",
        "tgt_log_vmem_bw", "tgt_log_vmem_bytes", "tgt_lane",
        "tgt_sublane", "tgt_log_launch_s", "tgt_is_gpu",
    ]
    return tuple(names)


FEATURE_NAMES = feature_names()


# kernel-library schedule kinds with a real Pallas lowering
# (harness._GROUP_LOWERERS) — groups of these kinds pay grid-shaped
# execution cost; everything else runs through the eager reference path
_KERNEL_KINDS = ("matmul", "flash_attention", "rmsnorm",
                 "grouped_matmul")


def _group_features(prog: KernelProgram, group, shapes, tgt):
    """(grid_cells, vmem_bytes, mxu_eff, depth, pipelined, reordered,
    epilogue, split_k, eff_tiles, kernel_kind, divisible) for one
    fusion group — every value defensively defaulted so an exotic
    group cannot raise."""
    from repro.core import rules
    sched = prog.schedule_for(group)
    tiles = sched.blocks_dict
    kind = sched_kind_of_group(prog, group)
    nm = prog.node_map
    main = next((nm[n] for n in group if sched_kind(nm[n].op) == kind),
                nm[group[0]])
    try:
        dims = rules.tileable_dims(main, shapes, prog.inputs)
    except Exception:
        dims = {}
    grid = 1.0
    eff_tiles = []
    divisible = True
    for tname in sorted(dims):
        dim = max(1, int(dims[tname]))
        eff = min(max(1, int(tiles.get(tname, 128))), dim)
        eff_tiles.append(float(eff))
        grid *= max(1.0, dim // eff)
        divisible = divisible and dim % eff == 0
    try:
        vmem = float(rules.vmem_tile_bytes(kind, tiles, dims))
    except Exception:
        vmem = 0.0
    try:
        eff = float(tgt.mxu_efficiency(tiles)) if tiles else 0.45
    except Exception:
        eff = 0.45
    depth = max(1, int(sched.pipeline_depth))
    order = sched.loop_order
    reordered = bool(order) and order[-1] != "k" and "k" in order
    epilogue = sched.epilogue not in (None, "", "none")
    split_k = 0
    for f in sched.flags:
        if isinstance(f, str) and f.startswith("split_k="):
            try:
                split_k += int(f.split("=", 1)[1])
            except ValueError:
                pass
    return (grid, vmem, eff, depth, depth >= 2, reordered, epilogue,
            split_k, eff_tiles, kind in _KERNEL_KINDS, divisible)


def featurize(prog: KernelProgram, target=None) -> np.ndarray:
    """Deterministic feature vector for ``(program, target)``.

    Aggregations are order-invariant (sums / means / maxes over nodes
    and groups), so permuting the ``fusion_groups`` tuple — a
    fingerprint change the IR treats as the same partition — leaves the
    vector bit-identical.  Never raises on a well-formed program: any
    per-group extraction failure contributes neutral values instead.
    """
    tgt = hardware.resolve(target)
    feats: list[float] = [
        _log1p(len(prog.nodes)), _log1p(len(prog.fusion_groups)),
        _log1p(len(prog.inputs)), _log1p(len(prog.outputs)),
    ]
    counts = {op: 0 for op in _OPS}
    n_marked = 0
    for n in prog.nodes:
        if n.op in counts:
            counts[n.op] += 1
        if n.attr("compute_dtype") or n.attr("out_dtype"):
            n_marked += 1
    feats += [_log1p(counts[op]) for op in _OPS]

    pc = cost_model.program_cost(prog, tgt)
    mxu = sum(g.mxu_flops for g in pc.groups)
    vpu = sum(g.vpu_flops for g in pc.groups)
    hbm = sum(g.hbm_bytes for g in pc.groups)
    comp = sum(g.compute_s for g in pc.groups)
    mem = sum(g.memory_s for g in pc.groups)
    n_compute = sum(g.bottleneck == "compute" for g in pc.groups)
    feats += [
        math.log(max(pc.total_s, 1e-12)), _log1p(mxu), _log1p(vpu),
        _log1p(hbm), _log1p((mxu + vpu) / max(hbm, 1.0)),
        math.log(max(comp, 1e-12)), math.log(max(mem, 1e-12)),
        math.log(max(comp, 1e-12) / max(mem, 1e-12)),
        n_compute / max(1, len(pc.groups)),
    ]

    shapes = prog.shapes()
    grid_total = 0.0
    grid_max = 0.0
    vmem_total = 0.0
    vmem_max = 0.0
    effs: list[float] = []
    depths: list[float] = []
    n_pipe = n_reord = n_epi = 0
    n_div = n_lowerable = 0
    kernel_grid = 0.0
    split_total = 0
    eff_tiles_all: list[float] = []
    for g in prog.fusion_groups:
        try:
            (grid, vmem, eff, depth, pipelined, reordered, epilogue,
             split_k, eff_tiles, is_kernel, divisible) = \
                _group_features(prog, g, shapes, tgt)
        except Exception:
            grid, vmem, eff, depth = 1.0, 0.0, 0.45, 1
            pipelined = reordered = epilogue = False
            split_k, eff_tiles = 0, []
            is_kernel, divisible = False, True
        grid_total += grid
        grid_max = max(grid_max, grid)
        vmem_total += vmem
        vmem_max = max(vmem_max, vmem)
        effs.append(eff)
        depths.append(float(depth))
        n_pipe += pipelined
        n_reord += reordered
        n_epi += epilogue
        n_div += divisible
        if is_kernel and divisible:
            # what a kernel-library lowering would actually execute:
            # the grid-shaped cost regime (an indivisible tiling falls
            # back to the eager reference path instead)
            kernel_grid += grid
            n_lowerable += 1
        split_total += split_k
        eff_tiles_all.extend(eff_tiles)
    ng = max(1, len(prog.fusion_groups))
    feats += [
        _log1p(grid_total), _log1p(grid_max), _log1p(vmem_total),
        _log1p(vmem_max),
        (sum(effs) / len(effs)) if effs else 0.45,
        (sum(depths) / len(depths)) if depths else 1.0,
        n_pipe / ng, n_reord / ng, float(n_epi), float(split_total),
        float(n_marked),
        min(eff_tiles_all) if eff_tiles_all else 0.0,
        (sum(math.log(t) for t in eff_tiles_all)
         / len(eff_tiles_all)) if eff_tiles_all else 0.0,
        n_div / ng, _log1p(kernel_grid), n_lowerable / ng,
    ]

    feats += [
        math.log(tgt.matmul_flops("bf16")), math.log(tgt.vector_flops),
        math.log(tgt.hbm_bw), math.log(tgt.vmem_bw),
        math.log(max(tgt.vmem_bytes, 1.0)), tgt.lane / 128.0,
        tgt.sublane / 8.0, math.log(max(tgt.launch_s, 1e-12)),
        1.0 if tgt.kind == "gpu" else 0.0,
    ]
    vec = np.asarray(feats, dtype=np.float64)
    assert vec.shape == (len(FEATURE_NAMES),)
    return vec


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LearnedModel:
    """Fitted ridge model + the normalization/provenance it needs."""

    weights: np.ndarray          # (d,) on standardized features
    intercept: float             # anchors absolute log-seconds
    mean: np.ndarray             # per-feature standardization
    std: np.ndarray
    lo: np.ndarray               # training bounds (standardized space)
    hi: np.ndarray
    feature_names: tuple[str, ...]
    ridge_lambda: float
    meta: dict
    # mean(log measured - log analytic) over training: puts analytic
    # fallbacks on the measured-seconds scale so an OOD candidate stays
    # comparable with its predicted siblings instead of looking ~e^8
    # cheaper and hijacking every rerank it appears in
    fallback_log_scale: float = 0.0

    def predict_log_s(self, x: np.ndarray) -> float | None:
        """Predicted ``log(time_s)`` — or ``None`` when the feature
        vector falls outside the training distribution or the feature
        schema drifted; callers fall back to analytic.

        Out-of-distribution is judged on the vector, not any single
        coordinate: a handful of features beyond the per-feature
        training range (plus margin) is ordinary extrapolation — an
        unseen op regime under leave-one-task-out, a sibling chip's
        constants under cross-target transfer — and the ridge weights
        are small enough to survive it.  Only when many coordinates
        leave the training envelope at once (a genuinely alien
        program) does prediction decline."""
        if tuple(self.feature_names) != FEATURE_NAMES:
            return None
        xs = (np.asarray(x, dtype=np.float64) - self.mean) / self.std
        margin = 2.0 * (self.hi - self.lo) + 2.5
        out = (xs < self.lo - margin) | (xs > self.hi + margin)
        if int(out.sum()) > max(2, len(xs) // 8):
            return None
        v = float(xs @ self.weights + self.intercept)
        return v if math.isfinite(v) else None

    # -- persistence (plain-dict pickle, macro_policy.pkl idiom) ------------
    def to_blob(self) -> dict:
        return {
            "kind": "learned_cost_model",
            "weights": np.asarray(self.weights, dtype=np.float64),
            "intercept": float(self.intercept),
            "mean": np.asarray(self.mean, dtype=np.float64),
            "std": np.asarray(self.std, dtype=np.float64),
            "lo": np.asarray(self.lo, dtype=np.float64),
            "hi": np.asarray(self.hi, dtype=np.float64),
            "feature_names": list(self.feature_names),
            "ridge_lambda": float(self.ridge_lambda),
            "meta": dict(self.meta),
            "fallback_log_scale": float(self.fallback_log_scale),
        }

    @classmethod
    def from_blob(cls, blob: dict) -> LearnedModel:
        return cls(
            weights=np.asarray(blob["weights"], dtype=np.float64),
            intercept=float(blob["intercept"]),
            mean=np.asarray(blob["mean"], dtype=np.float64),
            std=np.asarray(blob["std"], dtype=np.float64),
            lo=np.asarray(blob["lo"], dtype=np.float64),
            hi=np.asarray(blob["hi"], dtype=np.float64),
            feature_names=tuple(blob["feature_names"]),
            ridge_lambda=float(blob["ridge_lambda"]),
            meta=dict(blob.get("meta", {})),
            fallback_log_scale=float(blob.get("fallback_log_scale",
                                              0.0)))

    def save(self, path: str) -> None:
        with open(path, "wb") as f:
            pickle.dump(self.to_blob(), f)

    @classmethod
    def load(cls, path: str) -> LearnedModel:
        with open(path, "rb") as f:
            return cls.from_blob(pickle.load(f))


def fit_learned_model(samples: Iterable, *,
                      ridge_lambda: float = 1.0,
                      min_group: int = 2,
                      env_fp: str | None = None,
                      target: str | None = None,
                      allow_mixed_envs: bool = False,
                      extra_meta: dict | None = None
                      ) -> LearnedModel | None:
    """Group-normalized ridge fit on ``log(time_s)``.

    Usable samples must embed their program (``MeasureSample.program``
    — written by every post-§17 harness) and carry a positive measured
    time; others are skipped and counted in ``meta``.  Samples are
    grouped by ``(task_fp, target, env_fp)`` and each group's features
    and log-times are centered before the least-squares solve, so the
    fit explains only *within-candidate-set* time differences — the
    ranking signal — never task scale or environment regime.  Groups
    smaller than ``min_group`` carry no ranking signal and are dropped.

    Environment discipline matches ``fit_calibration``: samples
    spanning several env fingerprints are refused unless filtered
    (``env_fp=``) or explicitly allowed — group centering makes mixed
    envs *rankable*, but the intercept (absolute scale) would still
    average incomparable regimes, so the caller must opt in.

    Returns ``None`` when no trainable group survives (the caller
    keeps analytic pricing).
    """
    rows: list[np.ndarray] = []
    ys: list[float] = []
    gids: list[tuple[str, str, str]] = []
    envs: set[str] = set()
    n_no_prog = n_bad = 0
    modes: set[str] = set()
    for s in samples:
        if target is not None and s.target != target:
            continue
        if env_fp is not None and s.env_fp != env_fp:
            continue
        if s.program is None:
            n_no_prog += 1
            continue
        if s.time_s <= 0.0:
            n_bad += 1
            continue
        envs.add(s.env_fp)
        if len(envs) > 1 and not allow_mixed_envs:
            raise ValueError(
                f"samples span {len(envs)} environment fingerprints "
                f"({sorted(envs)}); filter with env_fp= or pass "
                f"allow_mixed_envs=True")
        try:
            prog = program_from_json(s.program)
            x = featurize(prog, s.target)
        except Exception:
            n_bad += 1
            continue
        rows.append(x)
        ys.append(math.log(s.time_s))
        gids.append((s.task_fp, s.target, s.env_fp))
        modes.add(s.mode)

    # drop groups without ranking signal (fewer than min_group rows)
    by_gid: dict[tuple, list[int]] = {}
    for i, gid in enumerate(gids):
        by_gid.setdefault(gid, []).append(i)
    keep = sorted(i for idxs in by_gid.values()
                  if len(idxs) >= max(2, min_group) for i in idxs)
    if not keep:
        return None
    X = np.stack([rows[i] for i in keep])
    y = np.asarray([ys[i] for i in keep])
    groups = [gids[i] for i in keep]

    mean = X.mean(axis=0)
    std = X.std(axis=0)
    std[std < 1e-12] = 1.0
    Xs = (X - mean) / std

    # group centering: subtract each candidate set's own mean so the
    # solve sees only within-set contrasts
    Xc = Xs.copy()
    yc = y.copy()
    for gid in sorted(set(groups)):
        idx = [i for i, g in enumerate(groups) if g == gid]
        Xc[idx] -= Xs[idx].mean(axis=0)
        yc[idx] -= y[idx].mean()
    d = Xc.shape[1]
    w = np.linalg.solve(Xc.T @ Xc + ridge_lambda * np.eye(d),
                        Xc.T @ yc)
    intercept = float((y - Xs @ w).mean())
    ia = FEATURE_NAMES.index("log_analytic_s")
    fallback_log_scale = float((y - X[:, ia]).mean())

    preds = Xs @ w
    fit_rho = grouped_spearman(preds.tolist(), y.tolist(), groups)
    meta = {
        "kind": "learned_cost_model",
        "feature_version": FEATURE_VERSION,
        "n_features": d,
        "n_samples": int(len(keep)),
        "n_groups": len(set(groups)),
        "n_skipped_no_program": n_no_prog,
        "n_skipped_bad": n_bad,
        "targets": sorted({g[1] for g in groups}),
        "env_fps": sorted(envs),
        "modes": sorted(modes),
        "ridge_lambda": float(ridge_lambda),
        "min_group": int(min_group),
        "spearman_fit": float(fit_rho),
    }
    if extra_meta:
        meta.update(extra_meta)
    return LearnedModel(
        weights=w, intercept=intercept, mean=mean, std=std,
        lo=Xs.min(axis=0), hi=Xs.max(axis=0),
        feature_names=FEATURE_NAMES, ridge_lambda=float(ridge_lambda),
        meta=meta, fallback_log_scale=fallback_log_scale)


def grouped_spearman(preds: list[float], ys: list[float],
                     groups: list) -> float:
    """Mean per-group Spearman over groups with >= 2 rows (0.0 when no
    group qualifies) — the fit-quality number the artifact meta and the
    trainer CLI report."""
    from repro.measure.calibrate import spearman
    by: dict = {}
    for p, t, g in zip(preds, ys, groups):
        by.setdefault(g, []).append((p, t))
    rhos = [spearman([p for p, _ in rows], [t for _, t in rows])
            for rows in by.values() if len(rows) >= 2]
    return float(sum(rhos) / len(rhos)) if rhos else 0.0


# ---------------------------------------------------------------------------
# the drop-in cost model
# ---------------------------------------------------------------------------

class LearnedCostModel:
    """Drop-in pricing model backed by a fitted ``LearnedModel``.

    Same duck type as ``CalibratedCostModel`` (``program_cost`` /
    ``total_s``), so it slots behind ``TranspositionStore(cost_model=)``
    and ``OptimizeConfig.cost_model`` unchanged.  Pricing:

    * **no model attached** (``LearnedCostModel()``, or ``load`` on a
      missing artifact) — bit-identical to the analytic roofline;
    * **prediction declined** (featurization error, feature-schema
      drift, out-of-distribution vector) — analytic scaled by the
      model's ``fallback_log_scale`` (the training-set mean measured/
      analytic offset) so the program stays on the measured-seconds
      scale and rankable against its predicted siblings; counted in
      ``stats["fallbacks"]``;
    * otherwise the program's groups are scaled uniformly so the total
      equals ``exp(predicted log-time)``, clamped to within
      ``LOG_ANCHOR_CLIP`` nats of the analytic total.
    """

    def __init__(self, model: LearnedModel | None = None):
        self.model = model
        self.stats = {"predicted": 0, "fallbacks": 0}

    @property
    def meta(self) -> dict:
        return dict(self.model.meta) if self.model is not None else {}

    def program_cost(self, prog: KernelProgram, target=None
                     ) -> ProgramCost:
        tgt = hardware.resolve(target)
        base = cost_model.program_cost(prog, tgt)
        if self.model is None:
            return base
        try:
            pred = self.model.predict_log_s(featurize(prog, tgt))
        except Exception:
            pred = None
        anchor = math.log(max(base.total_s, 1e-12))
        if pred is None:
            self.stats["fallbacks"] += 1
            pred = anchor + self.model.fallback_log_scale
        else:
            self.stats["predicted"] += 1
        pred = min(max(pred, anchor - LOG_ANCHOR_CLIP),
                   anchor + LOG_ANCHOR_CLIP)
        scale = math.exp(pred) / max(base.total_s, 1e-12)
        groups = tuple(
            dataclasses.replace(g, time_s=g.time_s * scale,
                                compute_s=g.compute_s * scale,
                                memory_s=g.memory_s * scale)
            for g in base.groups)
        return ProgramCost(sum(g.time_s for g in groups), groups,
                           tgt.name)

    def total_s(self, prog: KernelProgram, target=None) -> float:
        return self.program_cost(prog, target).total_s

    # -- persistence ---------------------------------------------------------
    def save(self, path: str) -> None:
        if self.model is None:
            raise ValueError("no fitted model to save")
        self.model.save(path)

    @classmethod
    def load(cls, path: str, *, missing_ok: bool = True
             ) -> LearnedCostModel:
        """Load an artifact; a missing file yields the identity
        (analytic) model when ``missing_ok`` — the contract that lets
        every entry point name an artifact path unconditionally."""
        if not os.path.exists(path):
            if missing_ok:
                return cls(None)
            raise FileNotFoundError(path)
        return cls(LearnedModel.load(path))


def resolve_cost_model(spec):
    """``OptimizeConfig.cost_model`` resolution: instances (anything
    with ``program_cost``) and ``None`` pass through; spec strings make
    the model addressable from configs that cross pickle/process
    boundaries (service + fleet):

      ``"analytic"``           -> None (the default pricing)
      ``"learned:PATH"``       -> ``LearnedCostModel.load(PATH)``
                                  (missing artifact = analytic identity)
      ``"calibrated:PATH"``    -> ``CalibratedCostModel`` over the
                                  ``Calibration`` JSON at PATH
    """
    if spec is None or not isinstance(spec, str):
        return spec
    if spec == "analytic":
        return None
    if spec.startswith("learned:"):
        return LearnedCostModel.load(spec.split(":", 1)[1])
    if spec.startswith("calibrated:"):
        from repro.measure.calibrate import (CalibratedCostModel,
                                             Calibration)
        return CalibratedCostModel(
            Calibration.load(spec.split(":", 1)[1]))
    raise ValueError(
        f"unknown cost_model spec {spec!r}; expected 'analytic', "
        f"'learned:PATH', 'calibrated:PATH', or a model instance")
