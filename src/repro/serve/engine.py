"""Serving engine: prefill + decode with slot-based continuous batching.

``serve_step`` (one token for the whole batch against a KV cache) is the
function the decode_* / long_* dry-run cells lower.  The Engine below runs
real generation for the examples/tests (transformer families; rwkv/hymba
decode through their own cache trees).
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import api


def make_serve_step(cfg: ModelConfig, *, rules=None):
    model = api.get_model(cfg)

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(cfg, params, cache, tokens, pos,
                                 rules=rules)
    return serve_step


def prefill_transformer(cfg: ModelConfig, params, tokens, max_len: int):
    """Run the prompt through forward(collect_cache) and build a cache."""
    from repro.models import transformer
    logits, aux, (ks, vs) = transformer.forward(
        cfg, params, {"tokens": tokens}, remat=False, collect_cache=True)
    B, S = tokens.shape
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    cache = api.init_cache(cfg, B, max_len)
    k = jax.lax.dynamic_update_slice(
        cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    v = jax.lax.dynamic_update_slice(
        cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    return logits, {"k": k, "v": v}


@dataclasses.dataclass
class Request:
    prompt: jnp.ndarray           # (S,) int32
    max_new_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """Slot-based batched generation for dense transformer families."""

    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 128,
                 batch_slots: int = 4, greedy: bool = True):
        assert cfg.family in ("dense", "moe", "vlm")
        self.cfg, self.params = cfg, params
        self.max_len, self.slots = max_len, batch_slots
        self.greedy = greedy
        self.serve_step = jax.jit(make_serve_step(cfg))

    def generate(self, prompts: list[jnp.ndarray],
                 max_new_tokens: int = 16) -> list[list[int]]:
        """Static batching within slot groups (continuous batching lite:
        new prompts join as finished ones free their slot group)."""
        results: list[list[int]] = []
        queue = list(prompts)
        while queue:
            group = queue[:self.slots]
            queue = queue[self.slots:]
            results.extend(self._generate_group(group, max_new_tokens))
        return results

    def _generate_group(self, prompts, max_new):
        B = len(prompts)
        S = max(len(p) for p in prompts)
        toks = jnp.stack([jnp.pad(p, (S - len(p), 0)) for p in prompts])
        logits, cache = prefill_transformer(self.cfg, self.params, toks,
                                            self.max_len)
        last = logits[:, -1]
        outs = [[] for _ in range(B)]
        pos = S
        for _ in range(max_new):
            nxt = jnp.argmax(last, -1).astype(jnp.int32) if self.greedy \
                else None
            for b in range(B):
                outs[b].append(int(nxt[b]))
            logits, cache = self.serve_step(
                self.params, cache, nxt[:, None], jnp.int32(pos))
            last = logits[:, -1]
            pos += 1
            if pos >= self.max_len:
                break
        return outs


class KernelService:
    """Kernel-optimization-as-a-service on top of ``core.engine``.

    A long-lived server process keeps ONE transposition store: repeated
    or similar optimization requests (the common case in production —
    many users submitting the same hot kernels) hit cached rewrites,
    cost pricing and oracle checks instead of redoing the search
    substrate.  Same cache the batched benchmark evaluator uses.
    """

    def __init__(self, policy=None, *, mode: str = "greedy_cost",
                 max_steps: int = 8, workers: int = 0, store=None,
                 max_programs: int = 200_000, target=None,
                 strategy: str | None = None):
        from repro.core import hardware
        from repro.core.engine import EvalEngine, TranspositionStore
        self.store = store if store is not None else TranspositionStore()
        # default hardware target requests are priced against; a single
        # service instance serves mixed-target traffic (per-request
        # override) because the store keys costs by (program, target)
        # and shares rewrites/oracle checks across targets
        self.target = hardware.resolve(target)
        self._engine = EvalEngine(policy, store=self.store, mode=mode,
                                  max_steps=max_steps, workers=workers,
                                  target=self.target.name,
                                  strategy=strategy)
        # capacity bound: the store never invalidates for correctness
        # (all entries are pure functions of their keys) but a server
        # fed a stream of DISTINCT kernels grows without bound — drop
        # and recreate wholesale past the cap
        self.max_programs = max_programs
        self.n_requests = 0
        self.n_store_resets = 0

    def _maybe_evict(self) -> None:
        if len(self.store.programs) > self.max_programs:
            from repro.core.engine import TranspositionStore
            self.store = TranspositionStore()
            self._engine.store = self.store
            self.n_store_resets += 1

    def optimize(self, task, seed: int | None = None, target=None):
        """One request -> OptimizationResult (cached substrate).

        ``target`` prices this request against a different registered
        chip; transitions/oracle entries are shared with every other
        target's requests (only cost memos are per-target)."""
        self.n_requests += 1
        self._maybe_evict()
        return self._engine.optimize(task, seed, target=target)

    def optimize_install(self, task, kernel: str, key: str, *,
                         seed: int | None = None, target=None):
        """Optimize and install the winning schedule into the kernel
        registry under the request's target
        (``ops.set_schedule(kernel, key, sched, target)``) — the serving
        path picks it up when that target is active."""
        from repro.core import hardware
        from repro.core.autotune import _extract_schedule
        from repro.kernels import ops
        res = self.optimize(task, seed, target=target)
        sched = _extract_schedule(res.program, kernel)
        if sched is not None and res.correct:
            tgt = self.target if target is None else \
                hardware.resolve(target)
            ops.set_schedule(kernel, key, sched, target=tgt)
        return res, sched

    def optimize_batch(self, tasks) -> dict:
        self.n_requests += len(tasks)
        self._maybe_evict()
        return self._engine.evaluate_suite(tasks)

    def stats(self) -> dict:
        return dict(self.store.stats_dict(), requests=self.n_requests,
                    store_resets=self.n_store_resets,
                    target=self.target.name)
