"""repro.analysis — static verification of programs and schedules.

Three diagnostic-producing passes (DESIGN.md §15):

  verifier   — well-formedness of the ``KernelProgram`` graph
               (MT001-MT015)
  legality   — schedule legality against a ``HardwareTarget`` or the
               portability envelope (MT020-MT028)
  soundness  — differential harness proving every rule's enumerated
               candidates rewrite into analyzable programs
               (MT030-MT031)

plus the ``python -m repro.analysis.lint`` CLI.

Only ``diagnostics`` is imported eagerly: ``core/rules.py`` attaches
``Diagnostic``s to its ``CompileError``s, and importing this package's
analysis passes from there would re-enter ``repro.core`` mid-import.
The pass entry points resolve lazily (PEP 562).
"""
from __future__ import annotations

from repro.analysis.diagnostics import (AnalysisError, CODES, Diagnostic,
                                        error, warning)

__all__ = [
    "AnalysisError", "CODES", "Diagnostic", "error", "warning",
    "verify_program", "analyze_legality", "analyze_program",
    "check_program", "check_rule_soundness", "soundness_report",
]

_LAZY = {
    "verify_program": "repro.analysis.verifier",
    "analyze_legality": "repro.analysis.legality",
    "analyze_program": "repro.analysis.legality",
    "check_program": "repro.analysis.legality",
    "check_rule_soundness": "repro.analysis.soundness",
    "soundness_report": "repro.analysis.soundness",
}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)
