"""Table 9 — rewrite-rule registry ablation: classic vs extended space.

The registry refactor's payoff claim, measured: the same greedy search
over the same tasks, once with the classic four rules and once with the
extended registry (``dtype`` bf16-compute and ``split_k`` skinny-M
rules registered through ``core/rules.py`` alone).  Emitted per task:
modeled time under each space and whether the extended space strictly
improved; the summary row's ``rules_improved_frac`` is gated by
``check_regression.py`` (a rules/cost-model change that stops the
extended space from winning fails CI), as is every row's execute
accuracy — the extra rules must never cost correctness.
"""
from __future__ import annotations

from .common import STORE, WORKERS
from repro.core import EvalEngine, OptimizeConfig, program_cost
from repro.core import tasks as T

# strict-improvement margin, matching the searches' GREEDY_REL_TOL
_REL_TOL = 0.999


def run(policy=None) -> list[str]:
    suite = T.ext_tasks() + T.kb_level2() + T.tb_t()
    results = {}
    for name, ext in (("classic", False), ("extended", True)):
        eng = EvalEngine(None, store=STORE, workers=WORKERS,
                         config=OptimizeConfig(mode="greedy_cost",
                                               strategy="greedy",
                                               extended_rules=ext,
                                               max_steps=8))
        results[name] = eng.evaluate_suite(suite)["results"]
    rows, wins, n_acc = [], 0, 0
    for task, rc, rx in zip(suite, results["classic"],
                            results["extended"]):
        cc = program_cost(rc.program).total_s * 1e6
        cx = program_cost(rx.program).total_s * 1e6
        win = int(cx < cc * _REL_TOL)
        wins += win
        ok = rc.correct and rx.correct
        n_acc += ok
        rows.append(f"table9/rules/{task.name},{cx:.1f},"
                    f"acc={1.0 if ok else 0.0:.2f};"
                    f"classic_us={cc:.1f};extended_us={cx:.1f};"
                    f"improved={win}")
    n = len(suite)
    rows.append(f"table9/rules/summary,0.0,"
                f"acc={n_acc / n:.2f};"
                f"rules_improved_frac={wins / n:.3f};"
                f"improved={wins}/{n}")
    return rows
