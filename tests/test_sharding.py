"""ShardingRules unit + property tests (divisibility, padding, specs)."""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs.base import normalize_for_mesh
from repro.configs.registry import ARCH_IDS, get_config
from repro.dist.sharding import ShardingRules
from repro.launch.mesh import make_local_mesh


@pytest.fixture(scope="module")
def rules():
    return ShardingRules(make_local_mesh())


def test_divisibility_replicates(rules):
    # 'model' axis has size 1 locally; use a fake 4-wide mesh via rule math
    spec = rules.spec((6, 8), ("heads", "embed"))
    assert isinstance(spec, P)


def test_padding_policy_all_archs():
    tp = 16
    for arch in ARCH_IDS:
        cfg = normalize_for_mesh(get_config(arch), tp)
        assert cfg.vocab_size % tp == 0, arch
        if cfg.n_kv_heads and cfg.n_kv_heads < cfg.n_heads:
            # GQA grouping must stay exact
            assert cfg.n_heads % cfg.n_kv_heads == 0, arch
        assert cfg.n_heads >= cfg.true_n_heads, arch
        assert cfg.vocab_size >= cfg.true_vocab_size, arch


def test_padding_specific_cases():
    tp = 16
    yi = normalize_for_mesh(get_config("yi_34b"), tp)
    assert yi.n_heads == 64 and yi.n_kv_heads == 8       # 56 -> 64
    hymba = normalize_for_mesh(get_config("hymba_1_5b"), tp)
    assert hymba.n_heads == 25                            # unpaddable GQA
    rwkv = normalize_for_mesh(get_config("rwkv6_3b"), tp)
    assert rwkv.n_heads == 48 and rwkv.n_kv_heads == 48   # MHA-style pad
    seam = normalize_for_mesh(get_config("seamless_m4t_medium"), tp)
    assert seam.n_heads == 16


@settings(max_examples=30, deadline=None)
@given(dim=st.integers(1, 4096))
def test_spec_divisibility_property(dim):
    """A sharded dim always divides the mesh axis product; otherwise the
    spec must replicate that dim."""
    mesh = make_local_mesh()
    rules = ShardingRules(mesh)
    spec = rules.spec((dim,), ("vocab",))
    axes = spec[0] if len(spec) > 0 else None
    if axes is not None:
        names = (axes,) if isinstance(axes, str) else tuple(axes)
        total = int(np.prod([mesh.shape[a] for a in names]))
        assert dim % total == 0


def test_no_duplicate_mesh_axes():
    mesh = make_local_mesh()
    rules = ShardingRules(mesh).with_fsdp()
    # expert and mlp both map to model: first-come-wins, no duplicates
    spec = rules.spec((4, 64, 128), ("expert", "embed", "mlp"))
    used = []
    for entry in spec:
        if entry is None:
            continue
        used += [entry] if isinstance(entry, str) else list(entry)
    assert len(used) == len(set(used))


def test_fsdp_rules_shard_embed():
    mesh = make_local_mesh()
    r0 = ShardingRules(mesh)
    r1 = r0.with_fsdp()
    assert r0.rules["embed"] == ()
    assert r1.rules["embed"] == ("data",)


# ---------------------------------------------------------------------------
# divisibility-or-replicate on a WIDE (faked) mesh — the local box has a
# single device, so spec() policy is exercised against a stub mesh that
# only exposes what ShardingRules reads: .shape and .axis_names
# ---------------------------------------------------------------------------

class _FakeMesh:
    def __init__(self, shape: dict):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def test_nondivisible_dim_replicates_wide_mesh():
    rules = ShardingRules(_FakeMesh({"data": 2, "model": 4}))
    # 6 heads cannot split 4 ways -> the dim must fully replicate
    spec = rules.spec((6, 8), ("heads", "embed"))
    assert spec[0] is None
    # 8 heads can -> sharded over model
    spec = rules.spec((8, 8), ("heads", "embed"))
    assert spec[0] == "model"


def test_divisible_prefix_only_wide_mesh():
    # kv_seq maps to (data, model): 4 divides data(2) but not 2*4 -> the
    # longest dividing PREFIX shards, the rest replicates
    rules = ShardingRules(_FakeMesh({"data": 2, "model": 4})).replace(
        kv_seq=("data", "model"))
    spec = rules.spec((4, 16), ("kv_seq", None))
    assert spec[0] == "data"
    spec = rules.spec((16, 16), ("kv_seq", None))
    assert spec[0] == ("data", "model")


def test_batch_rule_spans_pod_and_data():
    rules = ShardingRules(_FakeMesh({"pod": 2, "data": 16, "model": 16}))
    assert rules.dp == 32 and rules.tp == 16
    spec = rules.spec((256, 4096), ("batch", None))
    assert spec[0] == ("pod", "data")


def test_gqa_grouping_exact_after_normalize():
    """Padded q-heads stay an exact multiple of kv-heads (grouping
    correctness), and the padded heads shard where the true ones would
    replicate."""
    for tp in (4, 8, 16):
        rules = ShardingRules(_FakeMesh({"data": 2, "model": tp}))
        for arch in ARCH_IDS:
            cfg = normalize_for_mesh(get_config(arch), rules.tp)
            if cfg.n_kv_heads and cfg.n_kv_heads < cfg.n_heads:
                assert cfg.n_heads % cfg.n_kv_heads == 0, (arch, tp)
            if cfg.n_heads % tp == 0:
                spec = rules.spec((cfg.n_heads, cfg.head_dim),
                                  ("heads", None))
                assert spec[0] == "model", (arch, tp)


def test_dryrun_smoke_on_forced_8device_mesh():
    """dryrun's rules_for + param/batch shardings materialize on a real
    8-virtual-device host mesh (subprocess: device count must be forced
    before jax backend init)."""
    import json
    import os
    import subprocess
    import sys
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
jax.devices()          # lock the 8-device backend BEFORE importing dryrun
from repro.configs.base import ShapeConfig, normalize_for_mesh
from repro.configs.registry import get_config, reduced
from repro.launch.dryrun import rules_for
from repro.models import api
mesh = jax.make_mesh((2, 4), ("data", "model"))
out = {}
for kind in ("train", "decode"):
    rules = rules_for(mesh, kind)
    cfg = normalize_for_mesh(reduced(get_config("qwen2_5_3b")), rules.tp)
    sh = jax.tree.leaves(api.param_shardings(cfg, rules))
    out[kind] = {"n": len(sh), "tp": rules.tp, "dp": rules.dp,
                 "named": all(type(s).__name__ == "NamedSharding"
                              for s in sh)}
cache_sh = jax.tree.leaves(api.cache_pspecs(cfg, 8, 64,
                           rules_for(mesh, "decode")))
out["cache_specs"] = len(cache_sh)
out["n_devices"] = len(jax.devices())
print("RESULT:" + json.dumps(out))
"""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = next(ln for ln in proc.stdout.splitlines()
                if ln.startswith("RESULT:"))
    out = json.loads(line[len("RESULT:"):])
    assert out["n_devices"] == 8
    for kind in ("train", "decode"):
        assert out[kind]["tp"] == 4 and out[kind]["dp"] == 2
        assert out[kind]["n"] > 0 and out[kind]["named"]
    assert out["cache_specs"] > 0
