from repro.dist.sharding import ShardingRules  # noqa: F401
