"""Multi-device integration: REAL sharded execution on 8 virtual CPU
devices (subprocess — device count must be set before jax init, and the
main test process stays single-device per the dry-run spec).

Covers: pjit train step under TP+FSDP rules, decode under kv-seq
sharding, checkpoint saved on one mesh and restored on a DIFFERENT mesh
(elastic rescale) with identical loss.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json, tempfile
import jax, jax.numpy as jnp
from repro.configs.base import RunConfig, ShapeConfig, normalize_for_mesh
from repro.configs.registry import get_config, reduced
from repro.dist.sharding import ShardingRules
from repro.models import api
from repro.optim import adamw
from repro.train.trainer import make_train_step
from repro.data.pipeline import host_batch
from repro import ckpt

out = {}
cfg0 = reduced(get_config("qwen2_5_3b"))
shape = ShapeConfig("s", 32, 8, "train")

def run_on_mesh(data, model, params_np=None, opt_np=None):
    mesh = jax.make_mesh((data, model), ("data", "model"))
    rules = ShardingRules(mesh).with_fsdp()
    cfg = normalize_for_mesh(cfg0, rules.tp)
    params = params_np if params_np is not None else api.init_params(
        cfg, jax.random.PRNGKey(0))
    p_sh = api.param_shardings(cfg, rules)
    params = jax.tree.map(jax.device_put, params, p_sh)
    opt = opt_np if opt_np is not None else adamw.init(params)
    step = make_train_step(cfg, shape, RunConfig(accum_steps=2),
                           rules=rules)
    batch = host_batch(cfg, shape, 0, process_index=0, process_count=1)
    new_p, new_o, metrics = jax.jit(step)(params, opt, batch)
    return cfg, new_p, new_o, float(metrics["loss"])

# mesh A: 4x2
cfg, pA, oA, lossA = run_on_mesh(4, 2)
out["lossA"] = lossA
d = tempfile.mkdtemp()
ckpt.save(d, 1, pA, oA)

# elastic rescale: restore the same checkpoint on mesh B: 2x4
params_np, opt_np, _ = ckpt.restore(d, 1)
opt_np["step"] = jnp.asarray(opt_np["step"])
cfgB, pB, oB, lossB = run_on_mesh(2, 4, params_np, opt_np)
out["lossB"] = lossB

# decode under kv-seq sharding
meshB = jax.make_mesh((2, 4), ("data", "model"))
rulesB = ShardingRules(meshB).replace(kv_seq=("data", "model"))
cfgD = normalize_for_mesh(cfg0, rulesB.tp)
paramsD = api.init_params(cfgD, jax.random.PRNGKey(0))
from repro.serve.engine import make_serve_step
cache = api.init_cache(cfgD, 8, 64)
c_sh = api.cache_pspecs(cfgD, 8, 64, rulesB)
cache = jax.tree.map(lambda a, s: jax.device_put(
    a, jax.sharding.NamedSharding(meshB, s)), cache, c_sh)
logits, _ = jax.jit(make_serve_step(cfgD, rules=rulesB))(
    paramsD, cache, jnp.ones((8, 1), jnp.int32), jnp.int32(3))
out["decode_finite"] = bool(jnp.all(jnp.isfinite(logits)))
out["n_devices"] = len(jax.devices())
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.slow
def test_multidevice_train_and_elastic_rescale():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = next(ln for ln in proc.stdout.splitlines()
                if ln.startswith("RESULT:"))
    out = json.loads(line[len("RESULT:"):])
    assert out["n_devices"] == 8
    assert out["decode_finite"]
    # elastic rescale: same data, same state => same loss on both meshes
    assert abs(out["lossA"] - out["lossB"]) < 5e-3, out
