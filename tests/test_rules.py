"""Rewrite-rule registry: migration fidelity, extensibility, hooks.

Covers the acceptance contract of the registry refactor:
  * classic (default-rule) candidate enumeration is BYTE-identical to
    the pre-registry frozen action space — same Actions, same order,
    same describe() strings — on every suite task and on rewritten
    descendants (fingerprints / action_key caches / measurement DB
    keys stay valid);
  * curated presets are target-aware (lane/sublane-derived), legality
    is not;
  * property: for every task in every suite and every registered rule,
    each enumerated candidate applies ``ok`` or fails with
    ``compile_error`` — never raises — and no ok-rewrite silently
    miscompiles (oracle-checked through a shared store);
  * registry↔vocab consistency: every registered rule serializes every
    enumerated action to in-vocabulary tokens (CI gate against silent
    OOV scoring);
  * the extended rules (dtype, split_k) strictly improve best-found
    cost through the generic search path, at unchanged oracle accuracy;
  * no dispatch on action-kind string literals outside core/rules.py.
"""
import dataclasses
import itertools
import os
import sys

import pytest

from repro.core import actions as A
from repro.core import cost_model, rules as R
from repro.core import tasks as T
from repro.core.engine import TranspositionStore
from repro.core.env import EnvConfig, KernelEnv
from repro.core.kernel_ir import sched_kind_of_group
from repro.core.micro_coding import StructuredMicroCoder
from repro.core.pipeline import MTMCPipeline
from repro.core.policy import VOCAB, action_words, region_slots, \
    state_words
from repro.core.search import GreedySearch

ALL_SUITES = {name: fn() for name, fn in T.SUITES.items()}
CODER = StructuredMicroCoder()
STORE = TranspositionStore()


# ---------------------------------------------------------------------------
# frozen pre-registry action space (verbatim copy of the retired
# actions.py enumeration — the migration contract this refactor must
# honor byte-for-byte)
# ---------------------------------------------------------------------------

_LEGACY_TILE_PRESETS = {
    "matmul": [{"bm": m, "bn": n, "bk": k}
               for m, n, k in [(128, 128, 128), (256, 128, 128),
                               (128, 256, 128), (256, 256, 128),
                               (512, 128, 128), (128, 128, 256),
                               (512, 256, 128), (256, 256, 256),
                               (64, 64, 64)]],
    "flash_attention": [{"bq": q, "bk": k}
                        for q, k in [(128, 128), (256, 128), (128, 256),
                                     (256, 256), (512, 128), (64, 64),
                                     (512, 256), (1024, 128)]],
    "rmsnorm": [{"rows": r} for r in (128, 256, 512, 1024)],
    "rwkv6_scan": [{"chunk": c} for c in (16, 32, 64, 128)],
    "ssm_scan": [{"chunk": c} for c in (16, 32, 64, 128)],
    "grouped_matmul": [{"bc": c, "bf": f, "bd": d}
                       for c, f, d in [(128, 128, 128), (256, 128, 128),
                                       (128, 256, 128), (256, 256, 128),
                                       (512, 128, 128)]],
}

_LEGACY_BAD_TILES = [{"bm": 96, "bn": 80, "bk": 56},
                     {"bm": 8192, "bn": 8192, "bk": 8192},
                     {"bq": 100, "bk": 60}, {"chunk": 7},
                     {"bm": 33, "bn": 100, "bk": 17}]
_LEGACY_LOOP_ORDERS = [("m", "n", "k"), ("n", "m", "k"),
                       ("m", "k", "n"), ("k", "m", "n")]
_LEGACY_PIPELINE_DEPTHS = (1, 2, 3, 4)


def _legacy_candidate_actions(prog):
    acts = []
    for g in prog.fusion_groups:
        root = prog.group_root(g)
        kind = sched_kind_of_group(prog, g)
        for preset in _LEGACY_TILE_PRESETS.get(kind, []):
            acts.append(A.Action("tiling", root,
                                 tuple(sorted(preset.items()))))
        if kind in ("matmul", "grouped_matmul"):
            for order in _LEGACY_LOOP_ORDERS:
                acts.append(A.Action("reorder", root, order))
        if kind != "elementwise":
            for d in _LEGACY_PIPELINE_DEPTHS:
                acts.append(A.Action("pipeline", root, (d,)))
    for a, b in A.fusion_candidates(prog):
        acts.append(A.Action("fusion", a, (b,)))
    acts.append(A.STOP)
    return acts


def _legacy_unrestricted_actions(prog):
    acts = _legacy_candidate_actions(prog)
    names = [n.name for n in prog.nodes]
    for g in prog.fusion_groups:
        root = prog.group_root(g)
        for bad in _LEGACY_BAD_TILES:
            acts.append(A.Action("tiling", root,
                                 tuple(sorted(bad.items()))))
    for a, b in itertools.islice(itertools.combinations(names, 2), 12):
        acts.append(A.Action("fusion", a, (b,)))
    return acts


def _classic_and_descendants():
    """Every suite task plus a few greedy-rewritten descendants (the
    states a real search actually enumerates from)."""
    progs = []
    for suite in ALL_SUITES.values():
        for task in suite:
            progs.append(task)
            out = GreedySearch().search(task, coder=CODER, store=STORE,
                                        max_steps=3)
            progs.append(out.program)
    return progs


def test_classic_candidates_byte_identical_to_pre_registry():
    for prog in _classic_and_descendants():
        legacy = _legacy_candidate_actions(prog)
        now = A.candidate_actions(prog)
        assert legacy == now, prog.name
        assert [a.describe() for a in legacy] == \
            [a.describe() for a in now]
        assert _legacy_unrestricted_actions(prog) == \
            A.unrestricted_actions(prog), prog.name


def test_classic_programs_priced_identically_across_hooks():
    """Registry pricing hooks must be neutral on pre-registry programs
    (committed measurement DBs / benchmark rows rely on it): every
    hook-visible quantity reduces to the pre-hook formula when no rule
    marker is present."""
    import numpy as np
    from repro.core import hardware
    targets = [hardware.get_target(t) for t in ("tpu_v5e", "gpu_a100")]
    for prog in _classic_and_descendants()[:20]:
        shapes = prog.shapes()
        for g in prog.fusion_groups:
            sched = prog.schedule_for(g)
            assert R.SplitKRule.splits_of(sched) == 1
            # the matmul pricing hook (incl. split_k's occupancy term)
            # must be EXACTLY neutral on every classic matmul node on
            # every registered target — this is the invariant that
            # keeps committed benchmark rows and the measurement DB
            # valid (DESIGN.md §12)
            tiles = sched.blocks_dict
            for name in g:
                n = prog.node_map[name]
                if n.op != "matmul":
                    continue
                a = shapes.get(n.inputs[0],
                               prog.input_specs.get(n.inputs[0]))
                b = shapes.get(n.inputs[1],
                               prog.input_specs.get(n.inputs[1]))
                M = int(np.prod(a.shape[:-1]))
                K, N = a.shape[-1], b.shape[-1]
                for tgt in targets:
                    adj = R.matmul_price(n, sched, shapes[name],
                                         M, N, K, tiles, tgt)
                    assert (adj.hbm_scale, adj.hbm_extra,
                            adj.vpu_extra) == (1.0, 0.0, 0.0), \
                        (prog.name, name, tgt.name)
        for n in prog.nodes:
            assert R.compute_dtype_of(n) is None
        rtol, atol, norm = R.check_tolerance(prog, 2e-3, 2e-3)
        assert (rtol, atol, norm) == (2e-3, 2e-3, False)


# ---------------------------------------------------------------------------
# target-aware presets, target-independent legality
# ---------------------------------------------------------------------------

def test_presets_derive_from_target_geometry():
    v5e = R.tile_presets("matmul", "tpu_v5e")
    assert v5e == _LEGACY_TILE_PRESETS["matmul"]
    # same lane/sublane geometry -> same ladder
    assert R.tile_presets("matmul", "tpu_v4") == v5e
    a100 = R.tile_presets("matmul", "gpu_a100")
    assert a100 != v5e
    assert all(v % 32 == 0 for p in a100 for v in p.values())
    assert {"bm": 64, "bn": 64, "bk": 64} in a100
    # scans scale with sublane granularity (gpu_a100 sublane=16)
    assert R.tile_presets("ssm_scan", "gpu_a100") == \
        [{"chunk": c} for c in (32, 64, 128, 256)]


def test_enumeration_target_aware_legality_not():
    task = T.kb_level1()[0]
    default = A.candidate_actions(task)
    v4 = A.candidate_actions(task, target="tpu_v4")
    a100 = A.candidate_actions(task, target="gpu_a100")
    assert default == v4
    assert default != a100
    # legality is the portability envelope: a candidate legal for one
    # target must grade identically when applied (no target enters
    # rewrite/legality), so the shared transition memo stays sound
    for act in a100:
        r1 = CODER.apply(task, act)
        r2 = CODER.apply(task, act)
        assert r1.status == r2.status


# ---------------------------------------------------------------------------
# property: never raises, never silently miscompiles (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("suite", sorted(ALL_SUITES))
def test_every_rule_candidate_applies_or_fails_cleanly(suite):
    store = STORE
    coder = StructuredMicroCoder()          # validate=False sweep
    for task in ALL_SUITES[suite]:
        cands = A.unrestricted_actions(task, extended=True)
        assert any(a.kind == "dtype" or a.kind == "split_k"
                   for a in cands) or suite != "EXT"
        for act in cands:
            res = coder.apply(task, act)    # must never raise
            assert res.status in ("ok", "compile_error"), (task.name,
                                                           act)
            if res.status == "ok" and not R.is_terminal(act):
                # tier-2: the rewrite must pass the oracle (relaxed
                # per the rule's hook) — no silent miscompilation
                assert store.check(task, res.program), (task.name, act)


def test_validating_coder_grades_extended_rules():
    """With validate=True the coder executes every graph-changing
    rewrite against the original — extended rules must come back
    ``ok`` (not wrong_result) under their declared tolerance."""
    mc = StructuredMicroCoder(validate=True)
    for task in (T.kb_level2()[0], T.ext_tasks()[0], T.ext_tasks()[3]):
        for act in A.candidate_actions(task, extended=True):
            res = mc.apply(task, act)
            assert res.status in ("ok", "compile_error"), (task.name,
                                                           act)


# ---------------------------------------------------------------------------
# registry <-> vocab consistency (CI satellite)
# ---------------------------------------------------------------------------

def test_every_registered_rule_serializes_in_vocab():
    probes = [ALL_SUITES["KB-L1"][0], ALL_SUITES["KB-L2"][3],
              ALL_SUITES["KB-L3"][0], ALL_SUITES["TB-G"][0],
              ALL_SUITES["EXT"][0], ALL_SUITES["EXT"][3]]
    seen_kinds = set()
    for task in probes:
        slots = region_slots(task)
        assert all(w in VOCAB for w in state_words(task))
        for act in A.unrestricted_actions(task, extended=True):
            seen_kinds.add(act.kind)
            ws = action_words(act, slots)
            assert ws and all(w in VOCAB for w in ws), (act, ws)
    # the probe set must actually exercise every registered rule —
    # otherwise a new rule could ship with an OOV serialization and
    # this gate would stay green
    registered = {r.kind for r in R.registered_rules(extended=True)}
    assert registered <= seen_kinds


# ---------------------------------------------------------------------------
# extensibility proof: dtype + split_k through the generic paths
# ---------------------------------------------------------------------------

def _best_cost(task, extended):
    pipe = MTMCPipeline(mode="greedy_cost", strategy="greedy",
                        store=STORE, extended_rules=extended,
                        max_steps=8)
    res = pipe.optimize(task)
    return cost_model.program_cost(res.program).total_s, res


def test_extended_space_strictly_improves_new_and_old_tasks():
    improved = []
    for task in T.ext_tasks() + [T.kb_level2()[4]]:   # + L2_mlp
        c_classic, r_classic = _best_cost(task, extended=False)
        c_ext, r_ext = _best_cost(task, extended=True)
        assert r_classic.correct and r_ext.correct, task.name
        assert c_ext <= c_classic * (1 + 1e-12), task.name
        if c_ext < c_classic * 0.999:
            improved.append(task.name)
    # at least three tasks strictly improve, including a skinny-M
    # (split_k) and a bf16 chain (dtype)
    assert len(improved) >= 3, improved
    assert any("decode" in n for n in improved), improved
    assert any("bf16" in n for n in improved), improved


def test_split_k_occupancy_pricing_has_an_interior_optimum():
    task = T.ext_tasks()[0]           # EXT_decode_head, M=4
    base = cost_model.program_cost(task).total_s
    costs = {}
    for s in (2, 4, 8):
        res = CODER.apply(task, A.Action("split_k", "y", (s,)))
        assert res.status == "ok"
        costs[s] = cost_model.program_cost(res.program).total_s
    assert all(c < base for c in costs.values())
    # partial-sum traffic makes oversplitting pay: S=8 is not free
    assert costs[8] > costs[4]


def test_split_k_illegal_on_wide_matmuls():
    res = CODER.apply(T.kb_level1()[0],
                      A.Action("split_k", "y", (4,)))
    assert res.status == "compile_error"
    assert "skinny" in res.detail


def test_dtype_rule_relaxes_oracle_and_halves_output_bytes():
    task = T.ext_tasks()[4]           # EXT_proj_bf16
    res = CODER.apply(task, A.Action("dtype", "h", ("bfloat16",)))
    assert res.status == "ok"
    new = res.program
    shapes_old, shapes_new = task.shapes(), new.shapes()
    assert shapes_new["h"].bytes * 2 == shapes_old["h"].bytes
    rtol, atol, norm = R.check_tolerance(new, 2e-3, 2e-3)
    assert rtol > 2e-3 and norm
    assert STORE.check(task, new)
    # double-cast is a compile error, not a silent no-op
    again = CODER.apply(new, A.Action("dtype", "h", ("bfloat16",)))
    assert again.status == "compile_error"


def test_dtype_rule_prices_through_per_dtype_flops_table():
    """The compute-dtype bucket must hit the target's real table entry
    (IR name "bfloat16" normalized to the datasheet key "bf16"), not
    silently fall back to the native rate: on a target whose bf16 peak
    is 2x the native rate, the dtype rewrite halves compute_s."""
    from repro.core import hardware
    tgt = hardware.HardwareTarget(
        name="_t9_tf32_chip", kind="gpu",
        matmul_flops_by_dtype=(("tf32", 100e12), ("bf16", 200e12)),
        vector_flops=1e13, hbm_bw=1e12, hbm_bytes=16 * hardware.GIB,
        vmem_bw=1e13, vmem_bytes=16 * hardware.MIB)
    assert tgt.matmul_flops("bfloat16") == tgt.matmul_flops("bf16") \
        == 200e12
    assert tgt.matmul_flops("float32") == 100e12     # native fallback
    task = T.kb_level1()[0]
    res = CODER.apply(task, A.Action("dtype", "y", ("bfloat16",)))
    assert res.status == "ok"
    g_f32 = cost_model.program_cost(task, tgt).groups[0]
    g_bf16 = cost_model.program_cost(res.program, tgt).groups[0]
    assert g_bf16.compute_s == pytest.approx(g_f32.compute_s / 2,
                                             rel=1e-6)


def test_tolerance_relaxation_scoped_to_dependent_outputs():
    """A rule's relaxed oracle tolerance applies only to outputs that
    depend on its marked nodes — an unrelated output of the same
    program keeps the strict default, so the relaxation cannot mask a
    miscompile elsewhere."""
    from repro.core.kernel_ir import chain_program
    prog = chain_program("t_two_heads",
                         {"a": (256, 256), "b": (256, 256),
                          "c": (256, 256)},
                         [("m1", "matmul", ("a", "b")),
                          ("m2", "matmul", ("a", "c"))],
                         outputs=("m1", "m2"))
    res = CODER.apply(prog, A.Action("dtype", "m1", ("bfloat16",)))
    assert res.status == "ok"
    per = R.output_tolerances(res.program, 2e-3, 2e-3)
    assert per[0][0] > 2e-3 and per[0][2]          # m1: relaxed
    assert per[1] == (2e-3, 2e-3, False)           # m2: strict
    # mismatched output counts never silently pass
    import numpy as np
    x = [np.zeros((2, 2)), np.zeros((2, 2))]
    assert not R.outputs_match(x, x[:1], 1e-3, 1e-3)


def test_harness_verifies_bf16_lowering_at_rule_tolerance():
    """Measured reranking must not silently drop dtype-rule candidates:
    the harness's lowering verification consults the same
    rules.check_tolerance hook as the oracle checks, so a faithful
    bf16 kernel (output cast via rules.lower_cast) measures in Pallas
    mode instead of falling back to xla."""
    from repro.core.kernel_ir import chain_program
    from repro.measure.harness import ExecutionHarness, MeasureConfig
    task = chain_program("t_bf16_lower", {"x": (128, 256),
                                          "w": (256, 128)},
                         [("h", "matmul", ("x", "w")),
                          ("y", "gelu", ("h",))])
    res = CODER.apply(task, A.Action("dtype", "h", ("bfloat16",)))
    assert res.status == "ok"
    h = ExecutionHarness(cfg=MeasureConfig(warmup=0, repeats=1,
                                           mode="pallas"))
    sample = h.measure(task, res.program)
    assert h.stats["verify_fallbacks"] == 0
    assert sample.mode in ("pallas", "pallas_interpret")


def test_preset_cache_keys_on_geometry_not_name():
    import dataclasses as dc
    from repro.core import hardware
    base = hardware.get_target("tpu_v5e")
    assert R.tile_presets("matmul", base) == \
        R.tile_presets("matmul", dc.replace(base, name="elsewhere"))
    narrow = dc.replace(base, name="tpu_v5e", lane=64)
    assert R.tile_presets("matmul", narrow) != \
        R.tile_presets("matmul", base)


def test_dtype_rule_serializes_and_searches_through_offline_tree():
    """action_key round-trip for extension-rule actions (offline tree,
    measurement-DB winner records depend on it)."""
    import ast
    from repro.core.env import action_key
    for act in (A.Action("dtype", "y", ("bfloat16",)),
                A.Action("split_k", "y", (4,))):
        kind, region, param = action_key(act).split("|", 2)
        assert A.Action(kind, region, ast.literal_eval(param)) == act


# ---------------------------------------------------------------------------
# config hygiene + layering (satellites)
# ---------------------------------------------------------------------------

def test_env_config_default_is_not_shared():
    e1 = KernelEnv(T.kb_level1()[0])
    e2 = KernelEnv(T.kb_level1()[1])
    e1.cfg.max_steps = 99
    assert e2.cfg.max_steps == EnvConfig().max_steps
    # and no mutable dataclass instance hides in the signature default
    import inspect
    sig = inspect.signature(KernelEnv.__init__)
    assert sig.parameters["cfg"].default is None
    for f in dataclasses.fields(EnvConfig):
        assert not dataclasses.is_dataclass(f.default)


def test_no_action_kind_literal_dispatch_outside_rules():
    """Acceptance guard: no layer outside core/rules.py compares
    ``.kind`` against string literals (registered-rule dispatch must go
    through the registry).  The gate itself lives in tools/repolint.py
    so CI can run it without pytest; this test pins it into tier 1."""
    repo = os.path.join(os.path.dirname(__file__), "..")
    sys.path.insert(0, os.path.join(repo, "tools"))
    try:
        import repolint
    finally:
        sys.path.pop(0)
    offenders = repolint.lint_kind_literals(repo)
    assert not offenders, "\n".join(offenders)
