"""AdamW + schedules + global-norm clipping (pure JAX, optax-free).

Optimizer state is a pytree mirroring params (mu/nu) so the same
PartitionSpecs shard it (ZeRO-style when FSDP rules are active).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"       # cosine | linear | constant


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def init(params: Any) -> dict:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def opt_pspecs(param_pspecs: Any) -> dict:
    return {"mu": param_pspecs, "nu": param_pspecs, "step": P()}


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads: Any, state: dict, params: Any
           ) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mhat = mu / bc1
        nhat = nu / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:     # no decay on norms/bias
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p - lr * delta.astype(p.dtype)).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, mu, nu) for p, g, mu, nu
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step + 1}, metrics
