"""Completion backends for the LLM micro-coder.

``CoderBackend`` is the full seam a real LLM integration implements:
one ``complete(request) -> text`` call.  Everything else — prompt
construction, parsing, the analyzer/oracle gates, repair feedback,
retries — lives in the loop (``loop.py``), so a backend stays a thin
transport.  Two deterministic backends keep tier-1 CI hermetic with
zero network:

``TemplateBackend``
    A stand-in "LLM" that perturbs registry rewrites.  In strict mode
    (default) it emits exactly what ``rules.apply_rule`` produces and
    refuses exactly when the registry refuses — fingerprint-identical
    to ``StructuredMicroCoder`` on the closed rule space, which is what
    the protocol-conformance suite and the store-cache parity gate
    exercise.  In ``adapt`` mode it reproduces the failure-then-repair
    shape of a real model on *tiling* requests the registry rejects:
    attempt 0 eagerly stamps the requested tiles verbatim (the
    analyzer rejects them with MT02x), and attempt >= 1 — after the
    loop has fed those diagnostics back — legalizes the tiles against
    the group's actual dimensions (nearest divisor, lane-aligned).
    That legalized schedule is how the coder lands programs the closed
    preset enumeration cannot reach (the open-space tasks).

``ReplayBackend``
    Serves recorded transcripts (``transcript.TranscriptStore``) keyed
    by ``(task_fp, prog_fp, action_key, attempt)``.  Falls back to an
    any-task record for the same (parent, action, attempt) edge —
    sound because the coder contract requires task-independent answers
    — and raises a non-transient ``BackendError`` on a true miss.
    Recorded backend failures replay as failures.

``RecordingBackend``
    Wraps any backend and appends every exchange (refusals included)
    to a ``TranscriptStore`` — how the committed fixtures under
    ``tests/fixtures/llm_transcripts/`` are produced.

Only ``repro.llmcoder`` may import these classes directly; every other
module selects a coder by spec string through ``OptimizeConfig.coder``
(``tools/repolint.py`` gates the seam).
"""
from __future__ import annotations

import dataclasses
import json
import math

from repro.core import rules as R
from repro.core.kernel_ir import (program_from_json, program_to_json,
                                  sched_kind, sched_kind_of_group)
from repro.llmcoder.transcript import TranscriptStore, make_record


class BackendError(Exception):
    """A completion failure.  ``transient=True`` marks retryable
    faults (timeouts, rate limits, connection resets) the loop wraps
    in exponential backoff; non-transient errors mean the backend
    cannot answer this request at all (no recorded transcript, a
    refusal) and map straight to a ``compile_error``."""

    def __init__(self, message: str, *, transient: bool = False):
        super().__init__(message)
        self.transient = transient


@dataclasses.dataclass(frozen=True)
class CoderRequest:
    """One completion request: the rendered prompt plus the structured
    fields deterministic backends and the transcript store key on."""
    task_fp: str
    prog_fp: str
    action_key: str
    attempt: int
    prompt: str
    program: dict          # program_to_json of the parent
    action: object         # the Macro Action being implemented
    feedback: tuple = ()   # rendered diagnostics from prior attempts


class CoderBackend:
    """Abstract completion interface."""

    name = "backend"
    #: deterministic/local backends set True: the loop then skips the
    #: per-attempt timeout thread (there is nothing to time out)
    instant = False

    def complete(self, req: CoderRequest) -> str:
        raise NotImplementedError


# ---------------------------------------------------------------------------

def _tile_request(prog, act):
    """(group, kind, dims, requested_tiles) when ``act`` is a tiling-
    shaped request against a real group, else None.  Detected
    structurally (param = pairs naming the group's tileable dims), not
    by kind literal — the registry owns kind dispatch."""
    if not (act.param and isinstance(act.param[0], tuple)
            and len(act.param[0]) == 2
            and isinstance(act.param[0][0], str)):
        return None
    try:
        g = R.group_for_root(prog, act.region)
    except R.CompileError:
        return None
    kind = sched_kind_of_group(prog, g)
    nm = prog.node_map
    main = next((nm[n] for n in g if sched_kind(nm[n].op) == kind),
                nm[g[0]])
    dims = R.tileable_dims(main, prog.shapes(), prog.input_specs)
    try:
        tiles = dict(act.param)
    except (TypeError, ValueError):
        return None
    if not tiles or not dims or not all(k in dims for k in tiles):
        return None
    return g, kind, dims, tiles


def _legalize_tiles(kind: str, dims: dict, tiles: dict) -> dict:
    """Snap each requested tile to the legal value nearest in log
    space: a divisor of its dimension, aligned to the kind's lane
    requirement.  Tiles with no legal value are dropped."""
    align = 8 if kind in ("matmul", "grouped_matmul",
                          "flash_attention") else 1
    out = {}
    for name, req in tiles.items():
        dim = int(dims[name])
        legal = [d for d in range(align, dim + 1, align)
                 if dim % d == 0]
        if not legal:
            continue
        out[name] = min(
            legal, key=lambda d: (abs(math.log(d / max(req, 1))), d))
    return out


class TemplateBackend(CoderBackend):
    """Deterministic registry-perturbing stand-in LLM (see module
    docstring).  Pure function of the request — the transposition
    store's coder contract."""

    instant = True

    def __init__(self, adapt: bool = False):
        self.adapt = adapt
        self.name = "template-adapt" if adapt else "template"

    def complete(self, req: CoderRequest) -> str:
        prog = program_from_json(req.program)
        act = req.action
        try:
            child = R.apply_rule(prog, act)
            return json.dumps(program_to_json(child), sort_keys=True)
        except R.CompileError as e:
            if not self.adapt:
                raise BackendError(
                    f"cannot implement {R.describe(act)}: {e}") from e
            reject = e
        tr = _tile_request(prog, act)
        if tr is None:
            raise BackendError(
                f"cannot implement {R.describe(act)}: "
                f"{reject}") from reject
        g, kind, dims, tiles = tr
        if req.attempt == 0:
            # eager first draft: take the planner's numbers at face
            # value — the loop's analyzer rejects this with the MT02x
            # diagnostics the repair attempt then consumes
            blocks = tiles
        else:
            blocks = _legalize_tiles(kind, dims, tiles)
            if not blocks:
                raise BackendError(
                    f"no legal tiling for {R.describe(act)}: "
                    f"{reject}") from reject
        sched = prog.schedule_for(g).replace(blocks=blocks)
        child = prog.with_schedule(act.region, sched)
        if req.attempt > 0:
            try:
                R.check_tiles(child, g, blocks)
            except R.CompileError as e2:
                raise BackendError(
                    f"legalized tiling still illegal: {e2}") from e2
        return json.dumps(program_to_json(child), sort_keys=True)


class ReplayBackend(CoderBackend):
    """Serves recorded transcripts; the hermetic CI backend."""

    name = "replay"
    instant = True

    def __init__(self, transcripts: TranscriptStore | str):
        if isinstance(transcripts, str):
            transcripts = TranscriptStore(transcripts)
        self.transcripts = transcripts
        self.stats = {"replays": 0, "fallbacks": 0, "misses": 0}

    def complete(self, req: CoderRequest) -> str:
        rec = self.transcripts.lookup(req.task_fp, req.prog_fp,
                                      req.action_key, req.attempt)
        if rec is None:
            rec = self.transcripts.lookup_any(req.prog_fp,
                                              req.action_key,
                                              req.attempt)
            if rec is not None:
                self.stats["fallbacks"] += 1
        if rec is None:
            self.stats["misses"] += 1
            raise BackendError(
                f"no recorded transcript for action "
                f"{req.action_key!r} at attempt {req.attempt} "
                f"(prog {req.prog_fp[:12]}...)")
        self.stats["replays"] += 1
        if rec.get("error"):
            raise BackendError(rec["error"])
        return rec["response"]


class RecordingBackend(CoderBackend):
    """Records every exchange of an inner backend to a store."""

    def __init__(self, inner: CoderBackend,
                 transcripts: TranscriptStore | str):
        if isinstance(transcripts, str):
            transcripts = TranscriptStore(transcripts)
        self.inner = inner
        self.transcripts = transcripts
        self.name = f"recording-{inner.name}"

    @property
    def instant(self) -> bool:
        return self.inner.instant

    def complete(self, req: CoderRequest) -> str:
        try:
            resp = self.inner.complete(req)
        except BackendError as e:
            if not e.transient:
                # refusals are part of the behavior replay must
                # reproduce; transient faults are not (a retry answers)
                self.transcripts.put(make_record(
                    req.task_fp, req.prog_fp, req.action_key,
                    req.attempt, prompt=req.prompt, error=str(e)))
            raise
        self.transcripts.put(make_record(
            req.task_fp, req.prog_fp, req.action_key, req.attempt,
            prompt=req.prompt, response=resp))
        return resp
