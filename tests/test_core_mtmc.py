"""Unit + property tests for the MTMC core (env, rewards, policy, cost)."""
import jax
import numpy as np
from _hyp import given, settings, strategies as st

from repro.core import (Action, EnvConfig, KernelEnv, MacroPolicy,
                        OfflineEnv, PolicyConfig,
                        StructuredMicroCoder, candidate_actions,
                        program_cost)
from repro.core import tasks as T
from repro.core.actions import unrestricted_actions
from repro.core.env import action_key
from repro.core.policy import (action_words, region_slots,
                               state_words, VOCAB)
from repro.core.trajectories import CollectConfig, collect, tree_stats


# ---------------------------------------------------------------------------
# reward shaping (paper's three tiers + step decay)
# ---------------------------------------------------------------------------

def test_reward_tiers():
    task = T._attn_program("attn", 1, 256, 4, 64)
    env = KernelEnv(task)
    env.reset()
    # tier 1: compile error penalised
    res = env.step(Action("tiling", "out", (("bq", 999),)))
    assert res.reward < 0 and res.info["status"] == "compile_error"
    # tier 2+3: a beneficial fusion earns positive reward
    env.reset()
    res = env.step(Action("fusion", "scores", ("probs",)))
    assert res.info["status"] == "ok"
    assert res.reward > 0


def test_step_decay_suppresses_loops():
    """Same no-op-ish action later in the episode earns less."""
    task = T.kb_level2()[0]
    cfg = EnvConfig(decay_per_step=0.2, decay_floor=0.2)
    env = KernelEnv(task, cfg=cfg)
    env.reset()
    a = Action("pipeline", "y0", (3,))
    r1 = env.step(a).reward
    env.reset()
    env.t = 4   # pretend we're late in the episode
    r2 = env.step(a).reward
    if r1 > 0:
        assert r2 < r1


def test_stop_reward_reflects_achieved_speedup():
    task = T._attn_program("attn", 1, 256, 4, 64)
    env = KernelEnv(task)
    env.reset()
    r_stop_early = env.step(Action("stop", "")).reward
    env.reset()
    env.step(Action("fusion", "scores", ("probs",)))
    env.step(Action("fusion", "scores", ("out",)))
    r_stop_after = env.step(Action("stop", "")).reward
    assert r_stop_after > r_stop_early


# ---------------------------------------------------------------------------
# offline tree env == live env semantics
# ---------------------------------------------------------------------------

def test_offline_tree_replay_matches_live():
    task = T.kb_level2()[1]  # gemm_max
    tree = collect(task, CollectConfig(episodes_random=4,
                                       episodes_greedy=2))
    stats = tree_stats(tree)
    assert stats["nodes"] > 1 and stats["ok_edges"] > 0
    env = OfflineEnv(tree)
    env.reset()
    acts = env.candidates()
    assert acts
    # replaying a materialized ok-action gives the same cost/reward sign
    ok_act = next((a for a, s in tree.materialized_actions(tree.root)
                   if s == "ok"), None)
    if ok_act is not None:
        live = KernelEnv(task)
        live.reset()
        r_live = live.step(ok_act)
        env.reset()
        r_off = env.step(ok_act)
        assert r_off.info["status"] == r_live.info["status"]
        np.testing.assert_allclose(r_off.reward, r_live.reward,
                                   rtol=1e-6)


def test_action_key_roundtrip():
    task = T.kb_level2()[0]
    for a in candidate_actions(task)[:20]:
        k = action_key(a)
        kind, region, param = k.split("|", 2)
        import ast
        a2 = Action(kind, region, ast.literal_eval(param))
        assert a2 == a


# ---------------------------------------------------------------------------
# policy serialization / scoring
# ---------------------------------------------------------------------------

def test_state_and_action_words_in_vocab():
    for task in (T.kb_level1()[0], T.kb_level3()[0],
                 T._attn_program("a", 1, 256, 4, 64)):
        words = state_words(task)
        assert words and all(w in VOCAB for w in words)
        slots = region_slots(task)
        for a in candidate_actions(task)[:25]:
            aw = action_words(a, slots)
            assert all(w in VOCAB for w in aw), (a, aw)


def test_policy_distribution_sums_to_one():
    task = T.kb_level2()[0]
    pol = MacroPolicy(PolicyConfig(), jax.random.PRNGKey(0))
    cands = candidate_actions(task)
    logp, v = pol.action_dist(task, cands)
    assert len(logp) == len(cands)
    np.testing.assert_allclose(np.exp(logp).sum(), 1.0, rtol=1e-4)
    assert np.isfinite(v)


def test_policy_distinguishes_actions():
    """Different candidate sets give different distributions (the LM is
    actually reading the action tokens)."""
    task = T.kb_level2()[0]
    pol = MacroPolicy(PolicyConfig(), jax.random.PRNGKey(1))
    cands = candidate_actions(task)
    lp1, _ = pol.action_dist(task, cands[:6])
    lp2, _ = pol.action_dist(task, cands[6:12])
    assert not np.allclose(lp1, lp2)


# ---------------------------------------------------------------------------
# cost model properties
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(bq=st.sampled_from([64, 128, 256, 512]))
def test_flash_tiling_monotone_kv_traffic(bq):
    """Bigger q-blocks strictly reduce modeled KV re-read traffic."""
    task = T._attn_program("attn", 1, 1024, 4, 64)
    mc = StructuredMicroCoder()
    r1 = mc.apply(task, Action("fusion", "scores", ("probs",)))
    r2 = mc.apply(r1.program, Action("fusion", "scores", ("out",)))
    base = program_cost(r2.program).total_s
    r3 = mc.apply(r2.program, Action("tiling", "out",
                                     (("bk", 128), ("bq", bq))))
    assert r3.status == "ok"
    t = program_cost(r3.program).total_s
    if bq > 128:
        assert t <= base * 1.001


def test_fusion_strictly_reduces_cost():
    task = T.kb_level2()[0]  # gemm + bias + relu chain
    mc = StructuredMicroCoder()
    c0 = program_cost(task).total_s
    r = mc.apply(task, Action("fusion", "y0", ("y1",)))
    c1 = program_cost(r.program).total_s
    r = mc.apply(r.program, Action("fusion", "y0", ("y",)))
    c2 = program_cost(r.program).total_s
    assert c2 < c1 < c0


def test_pipeline_depth1_slower():
    task = T.kb_level1()[0]
    mc = StructuredMicroCoder()
    c0 = program_cost(task).total_s
    r = mc.apply(task, Action("pipeline", "y", (1,)))
    assert program_cost(r.program).total_s >= c0


def test_unrestricted_space_has_more_failures():
    task = T.kb_level2()[0]
    mc = StructuredMicroCoder()
    cur = [mc.apply(task, a).status for a in candidate_actions(task)]
    unr = [mc.apply(task, a).status for a in unrestricted_actions(task)]
    fail = lambda xs: sum(s != "ok" for s in xs) / len(xs)
    assert fail(unr) > fail(cur)
