"""Synthetic sharded data pipeline.

Deterministic per-(step, host) token generation — each host materialises
only its shard of the global batch (how a 1000-node fleet would feed the
model without a central dispenser), with background prefetch.  Determinism
by construction makes restart/elastic-rescale exactly reproducible: the
stream is a pure function of (seed, step), so a resumed or re-sharded job
sees the same tokens (see ft/ and tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class DataConfig:
    seed: int = 1234
    zipf_a: float = 1.3          # skewed token distribution (realistic-ish)
    prefetch: int = 2


def _tokens_for(cfg: ModelConfig, shape, rows: np.ndarray, seed: int,
                step: int, length: int) -> np.ndarray:
    """Deterministic (step, row)-addressed token block."""
    out = np.empty((len(rows), length), np.int32)
    for i, r in enumerate(rows):
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, step, int(r)]))
        v = cfg.true_vocab_size
        toks = rng.zipf(1.3, size=length).astype(np.int64)
        out[i] = np.clip(toks, 1, v - 1).astype(np.int32)
    return out


def host_batch(cfg: ModelConfig, shape: ShapeConfig, step: int, *,
               dcfg: DataConfig | None = None,
               process_index: int | None = None,
               process_count: int | None = None) -> dict:
    """The host-local shard of the global batch at ``step``."""
    dcfg = dcfg if dcfg is not None else DataConfig()
    pi = jax.process_index() if process_index is None else process_index
    pc = jax.process_count() if process_count is None else process_count
    B = shape.global_batch
    rows = np.arange(pi * B // pc, (pi + 1) * B // pc)
    S = shape.seq_len
    if cfg.family == "vlm":
        text = _tokens_for(cfg, shape, rows, dcfg.seed, step,
                           S - cfg.prefix_len + 1)
        rng = np.random.default_rng(
            np.random.SeedSequence([dcfg.seed, step, 7]))
        pre = rng.standard_normal(
            (len(rows), cfg.prefix_len, cfg.d_model)).astype(np.float32)
        tgt = np.concatenate(
            [np.zeros((len(rows), cfg.prefix_len - 1), np.int32),
             text], axis=1)[:, :S]
        return {"tokens": text[:, :-1], "prefix_embeds": pre,
                "targets": tgt}
    toks = _tokens_for(cfg, shape, rows, dcfg.seed, step, S + 1)
    batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
    if cfg.family == "encdec":
        rng = np.random.default_rng(
            np.random.SeedSequence([dcfg.seed, step, 11]))
        batch["enc_embeds"] = rng.standard_normal(
            (len(rows), cfg.enc_len, cfg.d_model)).astype(np.float32)
    return batch


class Prefetcher:
    """Background-thread prefetch of host batches."""

    def __init__(self, cfg, shape, start_step: int = 0,
                 dcfg: DataConfig | None = None):
        dcfg = dcfg if dcfg is not None else DataConfig()
        self.cfg, self.shape, self.dcfg = cfg, shape, dcfg
        self._q: queue.Queue = queue.Queue(maxsize=dcfg.prefetch)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            b = host_batch(self.cfg, self.shape, step, dcfg=self.dcfg)
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, dict]:
        return self._q.get()

    def stop(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
