"""Table 11 — micro-coder ablation: structured vs replay-LLM coder.

Two claims, both self-gating and both regression-gated by
``check_regression.py`` against the committed ``results/coder_bench.csv``:

* **Closed-space parity** — on registry-coverable tasks the LLM coder
  (replaying committed transcripts, fully offline) must land the SAME
  winners as ``StructuredMicroCoder``: identical winner fingerprints
  (hence identical modeled cost), identical accuracy.  Emitted as
  ``coder_parity=`` (fraction of tasks with byte-equal winner
  fingerprints; gated at zero slack).

* **Open-space gain** — on the ragged-dimension OPEN suite, where no
  closed tile preset divides any dimension and the structured coder can
  only fuse, the LLM coder's verify-and-repair loop must land verified
  custom tilings no registered rule can produce.  Emitted as
  ``open_gain=`` (geomean of per-task LLM/structured speedup ratios;
  gated at zero slack), with the repair telemetry the run is also
  asserted on: at least one first-attempt analyzer reject recovered by
  a repair round (``coder_analysis_rejects`` / ``coder_repaired_ok``),
  and a winner tile outside the closed preset ladder.

Modes:

  PYTHONPATH=src python -m benchmarks.table11_coder            # replay
  PYTHONPATH=src python -m benchmarks.table11_coder --record   # re-record
  PYTHONPATH=src python -m benchmarks.table11_coder --fast     # CI smoke

``--record`` drives the deterministic template backends (strict on the
closed suite, adapt on the open suite) through a ``RecordingBackend``
and regenerates the fixtures under ``tests/fixtures/llm_transcripts/``;
the default mode replays exactly those fixtures and asserts zero
transcript misses.  ``--fast`` trims the closed suite (row subset —
the regression gate compares shared rows only); gated summary values
are computed identically in both modes.
"""
from __future__ import annotations

import argparse
import math
import os

from .common import RESULTS, WORKERS
from repro.core import EvalEngine, OptimizeConfig, program_cost
from repro.core import tasks as T

TRANSCRIPTS = os.path.join("tests", "fixtures", "llm_transcripts")
MAX_STEPS = 6
# the closed preset ladder (rules.tile_presets values on the default
# target) — an open-space winner must use a block size outside it
_PRESET_VALUES = {64, 128, 256, 512}


def _closed_suite() -> list:
    by_name = {t.name: t for t in
               T.kb_level1() + T.kb_level2() + T.tb_t()}
    return [by_name[n] for n in ("L1_matmul_0", "L1_softmax",
                                 "L2_gemm_bias_relu", "T_gelu_gemm")]


def _engine(coder, *, serial: bool = False) -> EvalEngine:
    # private store per (coder, run): a transposition store must never
    # be shared across coders, and parity must come from cold caches.
    # Recording runs serially so re-recorded fixture shards keep a
    # stable record order (byte-stable committed files)
    return EvalEngine(None, workers=1 if serial else WORKERS,
                      config=OptimizeConfig(mode="greedy_cost",
                                            max_steps=MAX_STEPS,
                                            coder=coder))


def _llm_coder(mode: str, spec: str, record_dir: str):
    """Coder argument for the LLM side: a replay spec string in replay
    mode, a recording template coder in --record mode."""
    if mode == "replay":
        return f"llm-replay:{record_dir}"
    from repro.llmcoder import make_coder
    return make_coder(spec, record=record_dir)


def _geomean(xs: list[float]) -> float:
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def run(policy=None, *, mode: str = "replay",
        transcripts: str = TRANSCRIPTS,
        fast: bool = False) -> list[str]:
    del policy  # greedy_cost ablation: the coder is the variable
    rows: list[str] = []

    # -- closed space: parity ------------------------------------------------
    closed = _closed_suite()[:2] if fast else _closed_suite()
    eng_s = _engine("structured")
    res_s = eng_s.evaluate_suite(closed)["results"]
    eng_l = _engine(_llm_coder(mode, "llm-template", transcripts),
                serial=(mode == "record"))
    res_l = eng_l.evaluate_suite(closed)["results"]
    n_parity = n_acc = 0
    for task, rs, rl in zip(closed, res_s, res_l):
        us_s = program_cost(rs.program).total_s * 1e6
        us_l = program_cost(rl.program).total_s * 1e6
        parity = int(rs.program.fingerprint() == rl.program.fingerprint())
        n_parity += parity
        ok = rs.correct and rl.correct
        n_acc += ok
        rows.append(f"table11/coder/closed/{task.name},{us_l:.1f},"
                    f"acc={1.0 if ok else 0.0:.2f};"
                    f"structured_us={us_s:.1f};llm_us={us_l:.1f};"
                    f"parity={parity}")
    stats_l = eng_l.stats()
    depth = stats_l.get("coder_repair_depth", {})
    depth_s = "|".join(f"{k}:{v}" for k, v in sorted(depth.items()))
    rows.append(f"table11/coder/closed/summary,0.0,"
                f"acc={n_acc / len(closed):.2f};"
                f"coder_parity={n_parity / len(closed):.3f};"
                f"repair_depth={depth_s or '0:0'}")
    assert n_parity == len(closed), (
        f"closed-space parity broken: {n_parity}/{len(closed)} winner "
        f"fingerprints match the structured coder's")
    assert n_acc == len(closed), "closed-space accuracy below 1.0"
    if mode == "replay":
        assert stats_l.get("coder_backend_misses", 0) == 0, (
            "replay served a transcript miss — fixtures are stale; "
            "re-record with --record")

    # -- open space: verified programs the rule space cannot produce ---------
    open_suite = T.open_tasks()
    eng_os = _engine("structured")
    res_os = eng_os.evaluate_suite(open_suite)["results"]
    eng_ol = _engine(_llm_coder(mode, "llm-adapt", transcripts),
                 serial=(mode == "record"))
    res_ol = eng_ol.evaluate_suite(open_suite)["results"]
    gains, n_open_acc, novel = [], 0, 0
    for task, rs, rl in zip(open_suite, res_os, res_ol):
        us_l = program_cost(rl.program).total_s * 1e6
        ok = rs.correct and rl.correct
        n_open_acc += ok
        gains.append(rl.speedup / rs.speedup)
        blocks = {v for _, s in rl.program.schedules
                  for _, v in s.blocks}
        novel += int(bool(blocks - _PRESET_VALUES))
        rows.append(f"table11/coder/open/{task.name},{us_l:.1f},"
                    f"acc={1.0 if ok else 0.0:.2f};"
                    f"structured_x={rs.speedup:.3f};"
                    f"llm_x={rl.speedup:.3f};"
                    f"novel_tiles={int(bool(blocks - _PRESET_VALUES))}")
    stats_ol = eng_ol.stats()
    open_gain = _geomean(gains)
    rows.append(f"table11/coder/open/summary,0.0,"
                f"acc={n_open_acc / len(open_suite):.2f};"
                f"open_gain={open_gain:.3f};"
                f"coder_analysis_rejects="
                f"{stats_ol.get('coder_analysis_rejects', 0)};"
                f"coder_repaired_ok="
                f"{stats_ol.get('coder_repaired_ok', 0)};"
                f"coder_gave_up={stats_ol.get('coder_gave_up', 0)}")
    assert n_open_acc == len(open_suite), "open-space accuracy below 1.0"
    assert open_gain > 1.0, (
        f"open_gain={open_gain:.3f}: the LLM coder landed nothing the "
        f"closed rule space could not")
    assert novel >= 1, ("no open-space winner uses a block size outside "
                        "the closed preset ladder")
    assert stats_ol.get("coder_analysis_rejects", 0) >= 1, (
        "expected at least one first-attempt analyzer reject")
    assert stats_ol.get("coder_repaired_ok", 0) >= 1, (
        "expected at least one repair round to recover a reject")
    if mode == "replay":
        assert stats_ol.get("coder_backend_misses", 0) == 0, (
            "replay served a transcript miss on the open suite; "
            "re-record with --record")
    return rows


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--record", action="store_true",
                    help="re-record fixtures via the template backends "
                         "instead of replaying them")
    ap.add_argument("--transcripts", default=TRANSCRIPTS)
    ap.add_argument("--fast", action="store_true",
                    help="trim the closed suite (CI smoke)")
    args = ap.parse_args()
    mode = "record" if args.record else "replay"
    rows = run(mode=mode, transcripts=args.transcripts, fast=args.fast)
    print("name,us_per_call,derived")
    for r in rows:
        print(r, flush=True)
    os.makedirs(RESULTS, exist_ok=True)
    out = os.path.join(RESULTS, "coder_bench.csv")
    with open(out, "w") as f:
        f.write("name,us_per_call,derived\n" + "\n".join(rows) + "\n")
    print(f"# wrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
