"""Macro Thinking policy — a lightweight LM over the schedule-state DSL.

A small decoder-only transformer (same family shape as the paper's
DeepSeek-Coder-1.3B backbone, scaled to CPU budget; Table 7 shows policy
quality is robust to backbone size) reads the serialized kernel state and
scores each candidate semantic action TWOSOME-style: an action's logit is
the length-normalized sum of its tokens' log-probs under the LM, and the
sampling distribution is the softmax over candidate logits (paper Eq. 2).

A value head (mean-pooled state encoding) serves PPO.
"""
from __future__ import annotations

import dataclasses
import re
from collections.abc import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import actions as A
from repro.core import rules as R
from repro.core.kernel_ir import KernelProgram
from repro.core.rules import NUM_BUCKETS as _NUM_BUCKETS, bucket as _bucket

# ---------------------------------------------------------------------------
# DSL tokenizer (word-level, closed vocabulary)
# ---------------------------------------------------------------------------

_WORDS = (
    ["<pad>", "<s>", "</s>", "[G]", "[H]", "[A]", "->", "@"]
    + ["matmul", "grouped_matmul", "attention", "qk_scores", "av",
       "softmax", "rmsnorm", "row_max", "row_sum", "bias", "add", "mul",
       "relu", "gelu", "silu", "square", "rwkv_chunk", "ssm_chunk"]
    + ["tiling", "fusion", "pipeline", "reorder", "stop"]
    + ["bm", "bn", "bk", "bq", "bc", "bf", "bd", "chunk", "rows",
       "depth", "order", "m", "n", "k", "mem", "flop"]
    + [f"n{v}" for v in _NUM_BUCKETS]
    + [f"r{i}" for i in range(24)]          # region slots
    + ["compute", "memory", "bound", "fused", "epi"]
    # registry-extension words are APPENDED so every pre-existing token
    # id (and with it any pickled policy's embedding rows) stays stable
    + ["dtype", "bf16", "split_k", "sk"]
)
VOCAB = {w: i for i, w in enumerate(_WORDS)}
VOCAB_SIZE = len(_WORDS)
PAD, BOS, EOS = 0, 1, 2


def encode(words: Sequence[str]) -> list[int]:
    return [VOCAB[w] for w in words if w in VOCAB]


# ---------------------------------------------------------------------------
# serialization: program state / actions -> DSL words
# ---------------------------------------------------------------------------

def region_slots(prog: KernelProgram) -> dict[str, str]:
    return {prog.group_root(g): f"r{i % 24}"
            for i, g in enumerate(prog.fusion_groups)}


def state_words(prog: KernelProgram, max_groups: int = 10) -> list[str]:
    shapes = prog.shapes()
    nm = prog.node_map
    slots = region_slots(prog)
    words = ["<s>"]
    from repro.core import cost_model
    pc = cost_model.program_cost(prog)
    by_root = {g.root: g for g in pc.groups}
    for g in prog.fusion_groups[:max_groups]:
        root = prog.group_root(g)
        words.append("[G]")
        words.append(slots[root])
        for nname in g[:4]:
            words.append(nm[nname].op)
        out = shapes[g[-1]]
        for d in out.shape[-2:]:
            words.append(_bucket(d))
        sched = prog.schedule_for(g)
        for bn, bv in sched.blocks[:3]:
            words += [bn, _bucket(bv)]
        words += ["depth", _bucket(sched.pipeline_depth)]
        gc = by_root.get(root)
        if gc is not None:
            words += [gc.bottleneck, "bound"]
    words.append("[H]")
    for h in prog.history[-2:]:
        words += [w for w in re.split(r"[^\w]+", h) if w in VOCAB][:6]
    return words


def action_words(act: A.Action, slots: dict[str, str]) -> list[str]:
    """Serialize an action to DSL words — delegated to its rewrite
    rule's ``words`` hook, so a newly registered rule scores through
    the Macro LM with zero edits here (the registry↔vocab consistency
    test pins that every registered rule emits in-vocabulary words)."""
    return R.action_words(act, slots)


# ---------------------------------------------------------------------------
# the LM
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 384
    max_len: int = 192
    vocab: int = VOCAB_SIZE


def init_policy(cfg: PolicyConfig, key: jax.Array) -> dict:
    k = jax.random.split(key, 16)
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab
    L = cfg.n_layers
    s = 0.02

    def nrm(ki, shape):
        return s * jax.random.normal(ki, shape, jnp.float32)

    return {
        "embed": nrm(k[0], (V, D)),
        "pos": nrm(k[1], (cfg.max_len, D)),
        "blocks": {
            "wq": nrm(k[2], (L, D, D)), "wk": nrm(k[3], (L, D, D)),
            "wv": nrm(k[4], (L, D, D)), "wo": nrm(k[5], (L, D, D)),
            "n1": jnp.ones((L, D)), "n2": jnp.ones((L, D)),
            "w1": nrm(k[6], (L, D, F)), "w2": nrm(k[7], (L, F, D)),
        },
        "final_norm": jnp.ones((D,)),
        "lm_head": nrm(k[8], (D, V)),
        "value_head": nrm(k[9], (D, 1)),
    }


def _rms(x, sc):
    v = jnp.mean(jnp.square(x), -1, keepdims=True)
    return x * jax.lax.rsqrt(v + 1e-6) * sc


def policy_forward(cfg: PolicyConfig, params: dict, tokens: jax.Array):
    """tokens: (B, T) -> (token_logits (B,T,V), value (B,))."""
    B, T = tokens.shape
    H = cfg.n_heads
    hd = cfg.d_model // H
    x = params["embed"][tokens] + params["pos"][:T]
    mask = jnp.tril(jnp.ones((T, T), bool))
    pad_mask = tokens != PAD

    def block(x, p):
        h = _rms(x, p["n1"])
        q = (h @ p["wq"]).reshape(B, T, H, hd)
        k = (h @ p["wk"]).reshape(B, T, H, hd)
        v = (h @ p["wv"]).reshape(B, T, H, hd)
        sc = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
        sc = jnp.where(mask[None, None], sc, -1e30)
        o = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(sc, -1), v)
        x = x + o.reshape(B, T, -1) @ p["wo"]
        h = _rms(x, p["n2"])
        x = x + jax.nn.gelu(h @ p["w1"]) @ p["w2"]
        return x, None

    x, _ = jax.lax.scan(block, x, params["blocks"])
    x = _rms(x, params["final_norm"])
    logits = x @ params["lm_head"]
    pooled = jnp.sum(x * pad_mask[..., None], 1) / \
        jnp.maximum(jnp.sum(pad_mask, 1, keepdims=True), 1)
    value = (pooled @ params["value_head"])[:, 0]
    return logits, value


# ---------------------------------------------------------------------------
# TWOSOME-style action scoring
# ---------------------------------------------------------------------------

def build_candidate_batch(cfg: PolicyConfig, prog: KernelProgram,
                          cands: list[A.Action]
                          ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (tokens (N,T), act_mask (N,T), state_len) padded arrays:
    tokens = state || action; act_mask marks action-token positions."""
    slots = region_slots(prog)
    state = encode(state_words(prog))[: cfg.max_len - 24]
    rows, masks = [], []
    for a in cands:
        aw = encode(action_words(a, slots))
        seq = state + aw
        m = [0] * len(state) + [1] * len(aw)
        seq, m = seq[:cfg.max_len], m[:cfg.max_len]
        pad = cfg.max_len - len(seq)
        rows.append(seq + [PAD] * pad)
        masks.append(m + [0] * pad)
    return (np.array(rows, np.int32), np.array(masks, np.float32),
            np.int32(len(state)))


def make_scorer(cfg: PolicyConfig):
    @jax.jit
    def scores(params, tokens, act_mask):
        logits, value = policy_forward(cfg, params, tokens)
        logp = jax.nn.log_softmax(logits, -1)
        # token t predicted by position t-1
        tgt = tokens[:, 1:]
        lp = jnp.take_along_axis(logp[:, :-1], tgt[..., None],
                                 -1)[..., 0]
        m = act_mask[:, 1:]
        tok_sum = jnp.sum(lp * m, -1)
        n_tok = jnp.maximum(jnp.sum(m, -1), 1.0)
        norm = tok_sum / n_tok                  # TWOSOME normalization
        return norm, value[0]
    return scores


class MacroPolicy:
    """Bundles params + scoring; used by PPO and the inference pipeline."""

    def __init__(self, cfg: PolicyConfig | None = None, key=None,
                 params: dict | None = None):
        # None -> fresh config (no config construction at import time)
        self.cfg = cfg = cfg if cfg is not None else PolicyConfig()
        self.params = params if params is not None else init_policy(
            cfg, key if key is not None else jax.random.PRNGKey(0))
        self._scorer = make_scorer(cfg)

    def action_dist(self, prog: KernelProgram, cands: list[A.Action],
                    params=None):
        """Score the WHOLE candidate set in one batched forward (the
        candidate axis is the batch axis of ``policy_forward``) — no
        per-action calls.  The axis is padded to the next power of two
        so the jit sees O(log n) shapes instead of O(n/8): under the
        engine's worker pool this caps recompilations across tasks with
        wildly varying candidate counts."""
        tokens, mask, _ = build_candidate_batch(self.cfg, prog, cands)
        n = len(cands)
        n_pad = max(8, 1 << (n - 1).bit_length())
        if n_pad > n:
            tokens = np.concatenate(
                [tokens, np.zeros((n_pad - n, tokens.shape[1]),
                                  tokens.dtype)])
            mask = np.concatenate(
                [mask, np.zeros((n_pad - n, mask.shape[1]), mask.dtype)])
        norm, value = self._scorer(
            self.params if params is None else params, tokens, mask)
        norm = np.asarray(norm)[:n]
        logp = jax.nn.log_softmax(jnp.asarray(norm))
        return np.asarray(logp), float(value)

    def act(self, prog, cands, key, greedy=False):
        logp, value = self.action_dist(prog, cands)
        if greedy:
            idx = int(np.argmax(logp))
        else:
            idx = int(jax.random.categorical(key, jnp.asarray(logp)))
        return idx, float(logp[idx]), value
