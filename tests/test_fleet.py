"""Fleet-scale serving (serve/fleet.py + the measure/db.py
cross-process protocol, DESIGN.md §13).

Covers: winner-record generations (monotonic, exact under threaded and
multi-process update races), torn-read freedom of concurrent
put_winner/get_winner/iter_samples, the update_winner merge hook
(keep-current), peer-write pickup through the stamp-revalidated
get_winner, stale-tmp reaping + crash-safe _write + corrupt-record
counting, cross-replica KernelService warm starts (including the
stale-oracle force-overwrite and the analytic-never-downgrades-measured
merge policy), the refiner hot-swap chain, and the Fleet layer itself
(admission control, per-tenant round-robin fairness, deterministic
close).

The multiprocessing children import only ``repro.measure.db`` (no jax
at module scope), so spawned workers stay cheap.
"""
import json
import multiprocessing as mp
import os
import threading

import pytest

from repro.measure.db import MeasureDB

KEY = ("taskfp0000000000", "cpu_generic", "envfp0")


def _tiny(name="tiny_mm", n=256):
    from repro.core.kernel_ir import chain_program
    return chain_program(name, {"a": (n, n), "b": (n, n)},
                         [("y", "matmul", ("a", "b"))])


def _measure_cfg():
    from repro.measure.harness import MeasureConfig
    return MeasureConfig(repeats=1, warmup=0)


# ---------------------------------------------------------------------------
# winner generations: monotonic, exact under racing writers
# ---------------------------------------------------------------------------

def test_winner_generation_monotonic(tmp_path):
    db = MeasureDB(str(tmp_path))
    r1 = db.put_winner(*KEY, {"speedup": 1.0})
    r2 = db.put_winner(*KEY, {"speedup": 2.0})
    r3 = db.update_winner(*KEY, lambda old: dict(old, speedup=3.0))
    assert (r1["generation"], r2["generation"], r3["generation"]) \
        == (1, 2, 3)
    assert db.get_winner(*KEY)["speedup"] == 3.0


def test_update_winner_none_keeps_current(tmp_path):
    """fn returning None keeps the record: no write, no generation
    bump — the merge hook the KernelService no-downgrade policy uses."""
    db = MeasureDB(str(tmp_path))
    db.put_winner(*KEY, {"speedup": 1.0, "measured_s": 1e-6})
    kept = db.update_winner(*KEY, lambda old: None)
    assert kept["generation"] == 1 and kept["measured_s"] == 1e-6
    assert db.get_winner(*KEY)["generation"] == 1


def test_threaded_update_race_counts_exactly(tmp_path):
    """The per-key lock makes read-modify-write atomic: N threads each
    incrementing a counter M times must land exactly N*M increments
    and generation N*M — a lost update would show up as a gap."""
    db = MeasureDB(str(tmp_path))
    N, M = 8, 10

    def bump(old):
        return {"count": (0 if old is None else old["count"]) + 1}

    def worker():
        for _ in range(M):
            db.update_winner(*KEY, bump)

    ts = [threading.Thread(target=worker) for _ in range(N)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    rec = db.get_winner(*KEY)
    assert rec["count"] == N * M
    assert rec["generation"] == N * M
    assert db.stats["lock_timeouts"] == 0


def test_threaded_put_get_no_torn_reads(tmp_path):
    """Writers replacing one winner record while a reader hammers
    get_winner: every read parses and is internally consistent
    (id matches its blob) — os.replace atomicity, surfaced."""
    db = MeasureDB(str(tmp_path))
    stop = threading.Event()
    bad = []

    def writer(wid):
        for _i in range(30):
            db.put_winner(*KEY, {"id": wid, "blob": f"x{wid}" * 500})

    def reader():
        rdb = MeasureDB(str(tmp_path))   # own cache: disk reads
        while not stop.is_set():
            rec = rdb.get_winner(*KEY)
            if rec is None:
                continue
            if rec["blob"] != f"x{rec['id']}" * 500:
                bad.append(rec)
    rt = threading.Thread(target=reader)
    rt.start()
    ws = [threading.Thread(target=writer, args=(w,)) for w in range(4)]
    for t in ws:
        t.start()
    for t in ws:
        t.join()
    stop.set()
    rt.join()
    assert not bad
    assert db.get_winner(*KEY)["generation"] == 4 * 30


# ---------------------------------------------------------------------------
# multi-process races (spawn: children import only repro.measure.db)
# ---------------------------------------------------------------------------

def _mp_bump_worker(db_dir, n_iters, barrier):
    from repro.measure.db import MeasureDB
    key = ("taskfp0000000000", "cpu_generic", "envfp0")
    db = MeasureDB(db_dir)

    def bump(old):
        return {"count": (0 if old is None else old["count"]) + 1}
    barrier.wait()
    for _ in range(n_iters):
        db.update_winner(*key, bump)


def _mp_sample_worker(db_dir, wid, n, barrier):
    from repro.measure.db import MeasureDB, MeasureSample
    db = MeasureDB(db_dir)
    barrier.wait()
    for i in range(n):
        db.put(MeasureSample(
            task_fp=f"t{wid:02d}{i:04d}", prog_fp="p0",
            target="cpu_generic", env_fp="envfp0", time_s=1.0,
            samples=(1.0,), n_rejected=0, mode="xla",
            analytic_s=1.0, bottleneck="compute"))


@pytest.mark.slow
def test_multiprocess_update_race_converges(tmp_path):
    """3 separate processes racing read-modify-writes on one winner key:
    the lock FILE serializes them, so the count is exact and the
    generation counts every write — last-write-wins convergence with
    no torn state."""
    ctx = mp.get_context("spawn")
    P, M = 3, 12
    barrier = ctx.Barrier(P)
    procs = [ctx.Process(target=_mp_bump_worker,
                         args=(str(tmp_path), M, barrier))
             for _ in range(P)]
    for p in procs:
        p.start()
    for p in procs:
        p.join(120)
        assert p.exitcode == 0
    rec = MeasureDB(str(tmp_path)).get_winner(*KEY)
    assert rec["count"] == P * M
    assert rec["generation"] == P * M


@pytest.mark.slow
def test_multiprocess_samples_all_land_and_parse(tmp_path):
    """Concurrent sample writers from separate processes: every sample
    lands (content-addressed keys never collide across writers) and
    iter_samples parses all of them — no torn files."""
    ctx = mp.get_context("spawn")
    P, N = 3, 10
    barrier = ctx.Barrier(P)
    procs = [ctx.Process(target=_mp_sample_worker,
                         args=(str(tmp_path), w, N, barrier))
             for w in range(P)]
    for p in procs:
        p.start()
    for p in procs:
        p.join(120)
        assert p.exitcode == 0
    db = MeasureDB(str(tmp_path))
    seen = {s.task_fp for s in db.iter_samples(target="cpu_generic")}
    assert len(seen) == P * N
    assert db.stats["corrupt_records"] == 0


# ---------------------------------------------------------------------------
# peer pickup, reaping, crash safety, corruption counting
# ---------------------------------------------------------------------------

def test_peer_write_picked_up_by_stamp(tmp_path):
    """Two MeasureDB instances over one directory (two replicas): a
    winner landed by one is observed by the other on its NEXT read —
    the stamp revalidation, no refresh() needed — and the supersession
    is counted in winner_refreshes."""
    a = MeasureDB(str(tmp_path))
    b = MeasureDB(str(tmp_path))
    a.put_winner(*KEY, {"speedup": 1.0})
    assert b.get_winner(*KEY)["speedup"] == 1.0   # cold read, cached
    a.put_winner(*KEY, {"speedup": 2.0})
    assert b.get_winner(*KEY)["speedup"] == 2.0   # stamp changed
    assert b.stats["winner_refreshes"] == 1
    # unchanged stamp: served from cache, not recounted
    assert b.get_winner(*KEY)["speedup"] == 2.0
    assert b.stats["winner_refreshes"] == 1


def test_get_winner_forgets_deleted_record(tmp_path):
    db = MeasureDB(str(tmp_path))
    db.put_winner(*KEY, {"speedup": 1.0})
    assert db.get_winner(*KEY) is not None
    db.clear()
    assert db.get_winner(*KEY) is None


def test_reap_stale_tmp_on_init(tmp_path):
    """Orphans of dead writers (pid in the filename) and ancient tmps
    are deleted on construction; a live writer's fresh tmp survives."""
    win = tmp_path / "winners"
    win.mkdir(parents=True)
    (win / "aaaa.json.999999999.1.tmp").write_text("{")   # dead pid
    old = win / "bbbb.json.notapid.tmp"
    old.write_text("{")
    os.utime(old, (1, 1))                                 # ancient
    mine = win / f"cccc.json.{os.getpid()}.1.tmp"
    mine.write_text("{")                                  # live writer
    db = MeasureDB(str(tmp_path))
    assert db.stats["tmp_reaped"] == 2
    assert not (win / "aaaa.json.999999999.1.tmp").exists()
    assert not old.exists()
    assert mine.exists()
    # explicit reap with ttl 0 takes the live writer's too
    assert db.reap_stale_tmp(ttl_s=0.0) == 1
    assert not mine.exists()


def test_write_failure_leaves_no_tmp(tmp_path):
    db = MeasureDB(str(tmp_path))
    with pytest.raises(TypeError):
        db.put_winner(*KEY, {"bad": object()})   # unserializable
    litter = [fn for fn in os.listdir(tmp_path / "winners")
              if fn.endswith(".tmp")]
    assert litter == []
    assert db.get_winner(*KEY) is None           # nothing half-landed


def test_corrupt_record_reads_as_counted_miss(tmp_path):
    db = MeasureDB(str(tmp_path))
    db.put_winner(*KEY, {"speedup": 1.0})
    path = os.path.join(str(tmp_path), "winners",
                        db.winner_key(*KEY) + ".json")
    with open(path, "w") as f:
        f.write('{"speedup": 1.')                # torn-looking JSON
    db.refresh()
    assert db.get_winner(*KEY) is None
    assert db.stats["corrupt_records"] == 1
    # a rewrite heals it; json is whole again
    db.put_winner(*KEY, {"speedup": 2.0})
    with open(path) as f:
        assert json.load(f)["speedup"] == 2.0


def test_clear_removes_locks_and_tmps(tmp_path):
    db = MeasureDB(str(tmp_path))
    db.put_winner(*KEY, {"speedup": 1.0})
    win = tmp_path / "winners"
    (win / "zz.json.1.1.tmp").write_text("{")
    (win / "zz.lock").write_text("1")
    db.clear()
    assert os.listdir(win) == []


# ---------------------------------------------------------------------------
# cross-replica KernelService semantics (jax; service-level)
# ---------------------------------------------------------------------------

def test_cross_replica_warm_start(tmp_path):
    """Replica B answers a repeat of what replica A served — from A's
    winner record through the shared directory, zero search work."""
    from repro.serve.engine import KernelService
    task = _tiny("xrep", 256)
    kw = dict(measure=True, measure_db=str(tmp_path / "db"),
              rerank_top_k=0, measure_cfg=_measure_cfg(), max_steps=3)
    a = KernelService(**kw)
    ra = a.optimize(task)
    a.close()
    b = KernelService(**kw)
    rb = b.optimize(task)
    st = b.stats()
    b.close()
    assert ra.correct and rb.correct
    assert rb.program.fingerprint() == ra.program.fingerprint()
    assert st["warm_starts"] == 1
    assert st["fresh_applies"] == 0         # no search ran on B


def test_stale_winner_force_overwrites_cross_replica(tmp_path):
    """A record that fails the live oracle must be overwritten by the
    fallback search EVEN when it claims to be measured (force beats
    the no-downgrade merge policy), and the overwrite is visible to a
    peer replica."""
    from repro.core.kernel_ir import (chain_program, program_from_json,
                                      program_to_json)
    from repro.serve.engine import KernelService
    task = _tiny("stale", 256)
    kw = dict(measure=True, measure_db=str(tmp_path / "db"),
              rerank_top_k=0, measure_cfg=_measure_cfg(), max_steps=3)
    a = KernelService(**kw)
    wrong = chain_program("stale", {"a": (256, 256), "b": (256, 256)},
                          [("y", "relu", ("a",))])
    key = a._winner_db_key(task, None, None)
    a.harness.db.put_winner(*key, {
        "task": task.name, "program": program_to_json(wrong),
        "speedup": 9.9, "steps": 1, "measured_s": 1e-6,
        "measured_baseline_s": 1e-6, "reranked": True})
    res = a.optimize(task)
    a.close()
    assert res.correct
    # the peer sees the fresh (analytic, generation-2) record
    b = KernelService(**kw)
    rec = b.harness.db.get_winner(*key)
    rb = b.optimize(task)
    stb = b.stats()
    b.close()
    assert rec["generation"] == 2
    assert program_from_json(rec["program"]).eval_fingerprint() \
        == task.eval_fingerprint()
    assert rb.correct and stb["warm_starts"] == 1


def test_analytic_result_never_downgrades_measured_record(tmp_path):
    """The service merge policy: once a measured winner is on disk, a
    replica's analytic pick for the same question keeps the record
    (returns None from the merge hook) — no write, no generation
    bump."""
    from repro.serve.engine import KernelService
    task = _tiny("nodg", 256)
    db_dir = str(tmp_path / "db")
    r = KernelService(measure=True, measure_db=db_dir, rerank_top_k=2,
                      measure_cfg=_measure_cfg(), max_steps=3)
    rr = r.optimize(task)
    key = r._winner_db_key(task, None, None)
    rec0 = r.harness.db.get_winner(*key)
    assert rr.measured_s is not None and rec0["measured_s"] is not None
    # an analytic replica re-records its (unmeasured) answer
    a = KernelService(measure=True, measure_db=db_dir, rerank_top_k=0,
                      measure_cfg=_measure_cfg(), max_steps=3)
    analytic = rr.__class__(
        rr.task, rr.program, rr.correct, rr.speedup, rr.steps, 0, (),
        measured_s=None, measured_baseline_s=None, reranked=False)
    a._record_winner(task, None, None, analytic)
    rec1 = a.harness.db.get_winner(*key)
    r.close()
    a.close()
    assert rec1["measured_s"] == rec0["measured_s"]
    assert rec1["generation"] == rec0["generation"]


def test_refiner_hot_swaps_analytic_record(tmp_path):
    """The fleet hot-swap chain, service by service: an analytic
    replica lands an unmeasured record; a measuring service REFUSES to
    warm-start from it, re-searches, and upgrades the record; the next
    analytic replica then warm-starts with the measured answer."""
    from repro.serve.engine import KernelService
    task = _tiny("swap", 256)
    db_dir = str(tmp_path / "db")
    kw = dict(measure=True, measure_db=db_dir,
              measure_cfg=_measure_cfg(), max_steps=3)
    a = KernelService(rerank_top_k=0, **kw)
    ra = a.optimize(task)
    key = a._winner_db_key(task, None, None)
    assert ra.measured_s is None
    assert a.harness.db.get_winner(*key)["measured_s"] is None
    a.close()
    ref = KernelService(rerank_top_k=2, **kw)
    rr = ref.optimize(task)
    st_ref = ref.stats()
    rec = ref.harness.db.get_winner(*key)
    ref.close()
    assert st_ref["warm_starts"] == 0       # refused the unmeasured rec
    assert rr.measured_s is not None
    assert rec["measured_s"] is not None and rec["generation"] == 2
    b = KernelService(rerank_top_k=0, **kw)
    rb = b.optimize(task)
    stb = b.stats()
    b.close()
    assert stb["warm_starts"] == 1
    assert rb.measured_s is not None        # the swapped-in answer


# ---------------------------------------------------------------------------
# the Fleet layer
# ---------------------------------------------------------------------------

def test_fleet_config_validation(tmp_path):
    from repro.serve.fleet import Fleet, FleetConfig
    with pytest.raises(ValueError):
        Fleet(str(tmp_path), FleetConfig(replicas=0))
    with pytest.raises(ValueError):
        Fleet(str(tmp_path), FleetConfig(route="random"))


@pytest.mark.slow
def test_fleet_serves_and_hot_swaps(tmp_path):
    """End to end: replicas answer analytically, the background refiner
    upgrades the record, a repeat request serves the measured answer —
    counted as a hot swap."""
    from repro.serve.fleet import Fleet, FleetConfig
    fl = Fleet(str(tmp_path / "db"),
               FleetConfig(replicas=2, rerank_top_k=2),
               measure_cfg=_measure_cfg(), max_steps=3)
    task = _tiny("fleet", 256)
    r1 = fl.optimize(task, tenant="alice")
    assert r1.correct and r1.measured_s is None
    assert fl.drain_refinement(timeout=180)
    r2 = fl.optimize(task, tenant="bob")
    st = fl.stats()
    fl.close()
    assert r2.measured_s is not None
    assert st["hot_swaps"] == 1
    assert st["refined"] == 1
    assert st["warm_starts"] >= 1
    assert st["tenants"] == {"alice": 1, "bob": 1}


def test_fleet_admission_control(tmp_path):
    from repro.serve.fleet import AdmissionError, Fleet, FleetConfig
    fl = Fleet(str(tmp_path / "db"),
               FleetConfig(replicas=1, max_pending=2, refine=False),
               measure_cfg=_measure_cfg(), max_steps=2,
               auto_start=False)
    task = _tiny("adm", 128)
    f1 = fl.submit(task, tenant="a")
    f2 = fl.submit(task, tenant="b")
    with pytest.raises(AdmissionError):
        fl.submit(task, tenant="c")
    st = fl.stats()
    assert st["rejected"] == 1 and st["admitted"] == 2
    fl.start()                  # dispatch the queue; both must resolve
    assert f1.result(300).correct and f2.result(300).correct
    fl.close()


def test_fleet_tenant_round_robin(tmp_path):
    """One tenant flooding the queue cannot starve another: with A
    holding 6 queued requests and B holding 2, B's requests dispatch
    within the first 4 turns (strict per-turn round-robin)."""
    from repro.serve.fleet import Fleet, FleetConfig
    fl = Fleet(str(tmp_path / "db"),
               FleetConfig(replicas=1, refine=False),
               measure_cfg=_measure_cfg(), max_steps=2,
               auto_start=False)
    task = _tiny("fair", 128)
    futs = [fl.submit(task, tenant="flood") for _ in range(6)]
    futs += [fl.submit(task, tenant="meek") for _ in range(2)]
    fl.start()
    for f in futs:
        assert f.result(300).correct
    log = fl.dispatch_log
    fl.close()
    assert len(log) == 8
    assert sorted(i for i, t in enumerate(log) if t == "meek") \
        == [1, 3]


def test_fleet_close_without_drain_fails_queued(tmp_path):
    from repro.serve.fleet import Fleet, FleetClosed, FleetConfig
    fl = Fleet(str(tmp_path / "db"),
               FleetConfig(replicas=1, refine=False),
               measure_cfg=_measure_cfg(), max_steps=2,
               auto_start=False)
    task = _tiny("cls", 128)
    futs = [fl.submit(task, tenant="a") for _ in range(3)]
    fl.close(drain=False)
    for f in futs:
        with pytest.raises(FleetClosed):
            f.result(10)
    with pytest.raises(FleetClosed):
        fl.submit(task)
    fl.close()                  # idempotent
