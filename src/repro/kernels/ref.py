"""Pure-jnp reference oracles for every Pallas kernel.

These double as the CPU / dry-run execution path (identical math), and as
the ground truth for the per-kernel ``assert_allclose`` sweeps in tests.

Semantics notes
---------------
RWKV6 (Finch) recurrence, per head, state S in R^{dk x dv}:

    o_t = r_t @ (S_t + diag(u) k_t (x) v_t)
    S_{t+1} = diag(w_t) S_t + k_t (x) v_t          (w_t in (0,1), per dk)

The chunked form used by the TPU kernel evaluates, per chunk with inclusive
log-decay cumsum ``ccum`` and exclusive ``ecum``:

    inter:  (r_t * exp(ecum_t)) @ S_chunkstart
    intra:  A[t,i] = sum_k r[t,k] k[i,k] exp(ecum_t[k] - ccum_i[k]), i<t
            A[t,t] = sum_k r[t,k] u[k] k[t,k]
    state:  S' = exp(ccum_last) * S + sum_i (k_i exp(ccum_last - ccum_i)) (x) v_i

All exponents are <= 0, so the chunked form is numerically safe for any
decay magnitude (see DESIGN.md; this is the TPU-native adaptation of the
fla-style chunked linear attention).

SSM: Mamba-2 / SSD-style scalar-per-head decay (TPU/MXU-native adaptation
of selective scan — see DESIGN.md §2):

    h_t = exp(A_h dt_t) h_{t-1} + dt_t x_t (x) B_t ;   y_t = h_t @ C_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# matmul + epilogues
# ---------------------------------------------------------------------------

EPILOGUES = ("none", "bias", "relu", "gelu", "silu", "bias_relu",
             "bias_gelu", "row_max")


def apply_epilogue(y, epilogue: str, bias=None):
    if "bias" in epilogue and bias is not None:
        y = y + bias.astype(y.dtype)
    if epilogue.endswith("relu"):
        y = jax.nn.relu(y)
    elif epilogue.endswith("gelu"):
        y = jax.nn.gelu(y)
    elif epilogue.endswith("silu"):
        y = jax.nn.silu(y)
    elif epilogue == "row_max":
        y = jnp.max(y, axis=-1, keepdims=True)
    return y


def matmul(x, w, *, epilogue: str = "none", bias=None):
    y = jnp.einsum("mk,kn->mn", x, w.astype(x.dtype),
                   preferred_element_type=jnp.float32)
    y = apply_epilogue(y, epilogue, bias)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rwkv6
# ---------------------------------------------------------------------------

def rwkv6_scan(r, k, v, w, u, state=None):
    """Step-by-step oracle.  r,k,w: (B,T,H,dk); v: (B,T,H,dv); u: (H,dk)."""
    B, T, H, dk = r.shape
    dv = v.shape[-1]
    f32 = jnp.float32
    if state is None:
        state = jnp.zeros((B, H, dk, dv), f32)
    rs, ks, vs, ws = (a.astype(f32).transpose(1, 0, 2, 3)
                      for a in (r, k, v, w))

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        # op contract: w is clamped away from exact 0 (matches the
        # log-space chunked forms; see rwkv6_chunked)
        w_t = jnp.maximum(w_t, 1e-26)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        o = jnp.einsum("bhk,bhkv->bhv", r_t,
                       S + u.astype(f32)[None, :, :, None] * kv)
        S = w_t[..., None] * S + kv
        return S, o

    S, o = jax.lax.scan(step, state.astype(f32), (rs, ks, vs, ws))
    return o.transpose(1, 0, 2, 3).astype(r.dtype), S


def rwkv6_chunked(r, k, v, w, u, state=None, *, chunk=32):
    """Chunk-parallel form (matches rwkv6_scan; used on CPU for long T)."""
    B, T, H, dk = r.shape
    dv = v.shape[-1]
    f32 = jnp.float32
    if state is None:
        state = jnp.zeros((B, H, dk, dv), f32)
    assert T % chunk == 0, (T, chunk)
    nc, c = T // chunk, chunk
    rs, ks, vs, ws = (a.astype(f32).reshape(B, nc, c, H, -1)
                      .transpose(1, 0, 2, 3, 4) for a in (r, k, v, w))
    uf = u.astype(f32)

    def per_chunk(S, inp):
        rc, kc, vc, wc = inp               # (B,c,H,dk|dv)
        # clamp: w underflowing to 0 must not produce log(0) = -inf
        # (diffs of -inf cumsums are NaN); exp(-60) is already 0 in bf16.
        lw = jnp.log(jnp.maximum(wc, 1e-26))   # <= 0, finite
        ccum = jnp.cumsum(lw, axis=1)      # inclusive
        ecum = ccum - lw                   # exclusive
        # inter-chunk
        o_inter = jnp.einsum("bchk,bhkv->bchv", rc * jnp.exp(ecum), S)
        # intra-chunk: pairwise decay differences (c,c,dk), exponent <= 0
        diff = ecum[:, :, None, :, :] - ccum[:, None, :, :, :]  # (B,c,c,H,dk)
        tri = jnp.tril(jnp.ones((c, c), bool), -1)[None, :, :, None, None]
        dec = jnp.where(tri, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
        A = jnp.einsum("bthk,bihk,btihk->bthi", rc, kc, dec)
        # diagonal bonus term
        diag = jnp.einsum("bthk,hk,bthk->bth", rc, uf, kc)
        A += jnp.einsum("bth,ti->bthi", diag, jnp.eye(c, dtype=f32))
        o_intra = jnp.einsum("bthi,bihv->bthv", A, vc)
        # state update
        rem = ccum[:, -1:, :, :] - ccum                     # >= 0? no: <=0
        kd = kc * jnp.exp(rem)
        S_new = jnp.exp(ccum[:, -1])[..., None] * S + \
            jnp.einsum("bchk,bchv->bhkv", kd, vc)
        return S_new, o_inter + o_intra

    S, o = jax.lax.scan(per_chunk, state.astype(f32), (rs, ks, vs, ws))
    o = o.transpose(1, 0, 2, 3, 4).reshape(B, T, H, dv)
    return o.astype(r.dtype), S


# ---------------------------------------------------------------------------
# ssm (SSD-style scalar decay per head)
# ---------------------------------------------------------------------------

def ssm_scan_step(x, dt, A, B_, C, state=None):
    """Single/loop scan oracle.  x: (B,T,H,P); dt: (B,T,H); A: (H,);
    B_,C: (B,T,N); state: (B,H,P,N)."""
    Bb, T, H, P = x.shape
    N = B_.shape[-1]
    f32 = jnp.float32
    if state is None:
        state = jnp.zeros((Bb, H, P, N), f32)
    xs = x.astype(f32).transpose(1, 0, 2, 3)
    dts = dt.astype(f32).transpose(1, 0, 2)
    Bs = B_.astype(f32).transpose(1, 0, 2)
    Cs = C.astype(f32).transpose(1, 0, 2)
    Af = A.astype(f32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        a = jnp.exp(Af[None, :] * dt_t)                    # (B,H)
        upd = jnp.einsum("bhp,bn->bhpn", dt_t[..., None] * x_t, b_t)
        h = a[..., None, None] * h + upd
        y = jnp.einsum("bhpn,bn->bhp", h, c_t)
        return h, y

    h, y = jax.lax.scan(step, state.astype(f32), (xs, dts, Bs, Cs))
    return y.transpose(1, 0, 2, 3).astype(x.dtype), h


def ssm_chunked(x, dt, A, B_, C, state=None, *, chunk=32):
    """Chunked SSD form (matches ssm_scan_step)."""
    Bb, T, H, P = x.shape
    N = B_.shape[-1]
    f32 = jnp.float32
    if state is None:
        state = jnp.zeros((Bb, H, P, N), f32)
    assert T % chunk == 0
    nc, c = T // chunk, chunk
    xs = x.astype(f32).reshape(Bb, nc, c, H, P).transpose(1, 0, 2, 3, 4)
    dts = dt.astype(f32).reshape(Bb, nc, c, H).transpose(1, 0, 2, 3)
    Bs = B_.astype(f32).reshape(Bb, nc, c, N).transpose(1, 0, 2, 3)
    Cs = C.astype(f32).reshape(Bb, nc, c, N).transpose(1, 0, 2, 3)
    Af = A.astype(f32)

    def per_chunk(h, inp):
        xc, dtc, bc, cc = inp
        la = Af[None, None, :] * dtc                  # (B,c,H) <= 0
        ccum = jnp.cumsum(la, axis=1)                 # inclusive
        # inter: h_t gets full inclusive decay from chunk start
        y_inter = jnp.einsum("bth,bhpn,btn->bthp",
                             jnp.exp(ccum), h, cc)
        # intra: L[t,i] = exp(ccum_t - ccum_i), i <= t
        diff = ccum[:, :, None, :] - ccum[:, None, :, :]   # (B,c,c,H)
        tri = jnp.tril(jnp.ones((c, c), bool))[None, :, :, None]
        L = jnp.where(tri, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
        S = jnp.einsum("btn,bin->bti", cc, bc)             # (B,c,c)
        G = L * S[..., None]                               # (B,c,c,H)
        y_intra = jnp.einsum("btih,bih,bihp->bthp", G, dtc, xc)
        # state update
        rem = ccum[:, -1:, :] - ccum                       # <= 0
        upd = jnp.einsum("bih,bihp,bin->bhpn",
                         dtc * jnp.exp(rem), xc, bc)
        h = jnp.exp(ccum[:, -1])[..., None, None] * h + upd
        return h, y_inter + y_intra

    h, y = jax.lax.scan(per_chunk, state.astype(f32), (xs, dts, Bs, Cs))
    y = y.transpose(1, 0, 2, 3, 4).reshape(Bb, T, H, P)
    return y.astype(x.dtype), h
