"""Uniform model API + input specs.

``get_model(cfg)`` returns the family module implementing:
    param_tree(cfg, make) / forward(...) -> (logits, aux)
    cache_tree(cfg, make, batch, max_len) / decode_step(...)

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of that (arch x shape) cell — weak-type-correct, shardable, no
device allocation (the multi-pod dry-run lowers against these).
Modality frontends (vision patches / audio frames) are stubs: precomputed
embeddings appear directly as inputs, per the assignment spec.
"""
from __future__ import annotations

from types import ModuleType

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import makers


def get_model(cfg: ModelConfig) -> ModuleType:
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models import transformer
        return transformer
    if cfg.family == "rwkv":
        from repro.models import rwkv6
        return rwkv6
    if cfg.family == "hybrid":
        from repro.models import hymba
        return hymba
    if cfg.family == "encdec":
        from repro.models import encdec
        return encdec
    raise ValueError(cfg.family)


def init_params(cfg: ModelConfig, key: jax.Array, dtype=None):
    m = get_model(cfg)
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    return m.param_tree(cfg, makers.init_maker(key, dtype))


def abstract_params(cfg: ModelConfig, dtype=None):
    m = get_model(cfg)
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    return m.param_tree(cfg, makers.abstract_maker(dtype))


def param_pspecs(cfg: ModelConfig, rules):
    m = get_model(cfg)
    return m.param_tree(cfg, makers.pspec_maker(rules))


def param_shardings(cfg: ModelConfig, rules):
    m = get_model(cfg)
    return m.param_tree(cfg, makers.sharding_maker(rules))


# ---------------------------------------------------------------------------
# batch construction (abstract + concrete)
# ---------------------------------------------------------------------------

def batch_struct(cfg: ModelConfig, shape: ShapeConfig, *,
                 with_targets: bool | None = None) -> dict:
    """ShapeDtypeStructs for the forward/train batch of one shape cell."""
    B, S = shape.global_batch, shape.seq_len
    if with_targets is None:
        with_targets = shape.kind == "train"
    i32 = jnp.int32
    cdt = jnp.dtype(cfg.compute_dtype)
    batch: dict = {}
    if cfg.family == "vlm":
        P = cfg.prefix_len
        batch["tokens"] = jax.ShapeDtypeStruct((B, S - P), i32)
        batch["prefix_embeds"] = jax.ShapeDtypeStruct((B, P, cfg.d_model),
                                                      cdt)
    elif cfg.family == "encdec":
        batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        batch["enc_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_len, cfg.d_model), cdt)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    if with_targets:
        batch["targets"] = jax.ShapeDtypeStruct((B, S), i32)
    return batch


def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig, rules,
                 batch: dict | None = None) -> dict:
    batch = batch or batch_struct(cfg, shape)
    out = {}
    for name, s in batch.items():
        axes = ("batch",) + (None,) * (len(s.shape) - 1)
        out[name] = rules.spec(s.shape, axes)
    return out


def concrete_batch(cfg: ModelConfig, shape: ShapeConfig, key: jax.Array,
                   **overrides) -> dict:
    """Random concrete batch (smoke tests / examples)."""
    out = {}
    for name, s in batch_struct(cfg, shape).items():
        k = jax.random.fold_in(key, abs(hash(name)) % (2 ** 31))
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(k, s.shape, 0,
                                           cfg.true_vocab_size, s.dtype)
        else:
            out[name] = jax.random.normal(k, s.shape, s.dtype)
    out.update(overrides)
    return out


# ---------------------------------------------------------------------------
# decode-side specs
# ---------------------------------------------------------------------------

def abstract_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=None):
    m = get_model(cfg)
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    return m.cache_tree(cfg, makers.abstract_maker(dtype), batch, max_len)


def cache_pspecs(cfg: ModelConfig, batch: int, max_len: int, rules):
    m = get_model(cfg)
    return m.cache_tree(cfg, makers.pspec_maker(rules), batch, max_len)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, key=None,
               dtype=None):
    m = get_model(cfg)
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    key = key if key is not None else jax.random.PRNGKey(0)
    return m.cache_tree(cfg, makers.init_maker(key, dtype), batch, max_len)


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract inputs for serve_step on one decode cell."""
    B, S = shape.global_batch, shape.seq_len
    return {
        "cache": abstract_cache(cfg, B, S),
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
