"""Serving launcher: continuous-batching demo over mixed-length
prompts, or — with ``--fleet N`` — a multi-replica kernel-optimization
fleet over one shared measurement DB (DESIGN.md §13).

  python -m repro.launch.serve --arch qwen2_5_3b --reduced --requests 8
  python -m repro.launch.serve --fleet 3 --db /tmp/fleet_db \
      --requests 60 --tenants 4
"""
from __future__ import annotations

import argparse


def run_engine_demo(args) -> None:
    import jax
    import jax.numpy as jnp

    from repro.configs.registry import get_config, reduced
    from repro.models import api
    from repro.serve.engine import Engine, Request

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if cfg.family not in ("dense", "moe", "vlm"):
        raise SystemExit(f"Engine demo supports transformer families; "
                         f"{cfg.family} decodes via its serve_step "
                         f"(see launch/dryrun.py decode cells)")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, max_len=args.max_len,
                    batch_slots=args.slots, eos_id=args.eos)
    key = jax.random.PRNGKey(1)
    reqs = [Request(jax.random.randint(jax.random.fold_in(key, i),
                                       (3 + i % 4,), 1, 100, jnp.int32),
                    max_new_tokens=args.max_new + i % 3,
                    eos_id=args.eos)
            for i in range(args.requests)]
    engine.run(reqs)
    for i, r in enumerate(reqs):
        trunc = " [truncated]" if r.truncated else ""
        print(f"req{i} (len {len(r.prompt)}, budget "
              f"{r.max_new_tokens}): {r.out}{trunc}")
    st = engine.stats
    occ = st["occupancy_sum"] / max(st["decode_steps"], 1)
    print(f"steps={st['decode_steps']} tokens={st['decode_tokens']} "
          f"prefills={st['prefills']} occupancy={occ:.2f} "
          f"truncations={st['truncations']}")


def run_fleet_demo(args) -> None:
    """N replicas + a background refiner over ``--db``: a Zipf-skewed
    multi-tenant request stream, answered analytically first, upgraded
    to measured winners in the background.  Re-running against the same
    ``--db`` (or running a second copy concurrently) warm-starts from
    the records the previous/peer run landed."""
    import time

    import numpy as np

    from repro.core import OptimizeConfig
    from repro.core import tasks as T
    from repro.measure.harness import MeasureConfig
    from repro.serve.fleet import Fleet, FleetConfig

    suite = T.kb_level1() + T.kb_level2() + T.kb_level3()
    tenants = [f"tenant{i}" for i in range(max(1, args.tenants))]
    rng = np.random.default_rng(args.seed)
    picks = [(int(z) - 1) % len(suite)
             for z in rng.zipf(1.5, args.requests)]
    tens = [tenants[i]
            for i in rng.integers(0, len(tenants), args.requests)]

    fl = Fleet(args.db,
               FleetConfig(replicas=args.fleet,
                           max_pending=args.max_pending),
               measure_cfg=MeasureConfig(repeats=1, warmup=0),
               config=OptimizeConfig(mode="greedy_cost",
                                     max_steps=args.max_steps))
    t0 = time.perf_counter()
    futs = [fl.submit(suite[p], tenant=t)
            for p, t in zip(picks, tens)]
    res = [f.result() for f in futs]
    wall = time.perf_counter() - t0
    fl.drain_refinement(timeout=600)
    st = fl.stats()
    fl.close()
    assert all(r.correct for r in res)
    print(f"fleet: {args.requests} requests, {args.fleet} replicas, "
          f"{len(tenants)} tenants over {args.db}")
    print(f"  wall {wall:.2f}s ({args.requests / wall:.1f} req/s), "
          f"mean speedup "
          f"{float(np.mean([r.speedup for r in res])):.2f}x")
    print(f"  warm_starts={st['warm_starts']} "
          f"coalesced={st['coalesced']} refined={st['refined']} "
          f"hot_swaps={st['hot_swaps']} rejected={st['rejected']}")
    print(f"  tenants={st['tenants']}")
    print(f"  db: corrupt={st['db_corrupt_records']} "
          f"tmp_reaped={st['db_tmp_reaped']} "
          f"lock_timeouts={st['db_lock_timeouts']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="Engine demo: transformer config name")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--eos", type=int, default=None,
                    help="optional EOS token id applied to every request")
    ap.add_argument("--fleet", type=int, default=0,
                    help="run the kernel-fleet demo with N replicas "
                         "instead of the Engine demo")
    ap.add_argument("--db", default="/tmp/repro_fleet_db",
                    help="shared measurement-DB directory (fleet)")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--max-steps", type=int, default=3)
    ap.add_argument("--max-pending", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.fleet > 0:
        run_fleet_demo(args)
        return
    if args.arch is None:
        ap.error("--arch is required unless --fleet N is given")
    run_engine_demo(args)


if __name__ == "__main__":
    main()
