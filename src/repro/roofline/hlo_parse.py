"""Parse collective traffic out of post-SPMD-partitioned HLO text.

``compiled.as_text()`` is the per-device module after GSPMD partitioning;
collective ops carry their per-device result shapes and replica groups.
We sum OPERAND bytes per collective kind (spec definition), deriving the
operand size from the result where HLO only shows the result type:

  all-reduce / all-to-all / collective-permute : operand == result
  all-gather                                    : operand == result / G
  reduce-scatter                                : operand == result * G

Collectives inside while bodies (jax.lax.scan over layers / microbatch
accumulation) execute trip-count times: we reconstruct the computation
call graph, read each while loop's trip bound from the constant in its
condition computation, and multiply nested collectives accordingly.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

import numpy as np

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4,
               "u32": 4, "s64": 8, "u64": 8, "s16": 2, "u16": 2,
               "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
               "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            n = int(np.prod([int(d) for d in dims.split(",")]))
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int = 1) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    operand_bytes: int
    group_size: int
    computation: str
    multiplier: int = 1


def split_computations(txt: str) -> dict[str, list[str]]:
    """Computation headers start at column 0 and end with '{'; body lines
    are indented (op metadata may contain '->' and '{', so only column-0
    structure is trusted)."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in txt.splitlines():
        if not line.startswith((" ", "\t")) and \
                line.rstrip().endswith("{") and "=" not in line.split(
                    "(", 1)[0]:
            m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def parse_def(line: str):
    """Parse '%name = TYPE op(operands), attrs' (tuple types included).
    Returns (name, type_str, op, operands, attrs) or None."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%") or " = " not in s:
        return None
    name, _, rest = s.partition(" = ")
    name = name.lstrip("%")
    if rest.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        tstr, rest2 = rest[:end + 1], rest[end + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        tstr, rest2 = rest[:sp], rest[sp + 1:]
    m = re.match(r"([\w\-]+)\(([^)]*)\)(.*)$", rest2)
    if not m:
        return None
    return name, tstr, m.group(1), m.group(2), m.group(3)


def while_structure(comps: dict[str, list[str]]
                    ) -> tuple[dict[str, str], dict[str, int]]:
    """Returns (body_comp -> parent_comp, body_comp -> trip_count)."""
    parent: dict[str, str] = {}
    trips: dict[str, int] = {}
    for cname, lines in comps.items():
        for line in lines:
            m = re.search(r"while\(.*?\).*?condition=%?([\w.\-]+),\s*"
                          r"body=%?([\w.\-]+)", line)
            if not m:
                m = re.search(r"while\(.*?\).*?body=%?([\w.\-]+),\s*"
                              r"condition=%?([\w.\-]+)", line)
                if m:
                    body, cond = m.group(1), m.group(2)
                else:
                    continue
            else:
                cond, body = m.group(1), m.group(2)
            parent[body] = cname
            trips[body] = _trip_count(comps.get(cond, []))
    return parent, trips


def _trip_count(cond_lines: list[str]) -> int:
    best = 1
    for line in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


def multiplier_of(comp: str, parent: dict[str, str],
                  trips: dict[str, int]) -> int:
    mult = 1
    seen = set()
    while comp in parent and comp not in seen:
        seen.add(comp)
        mult *= max(trips.get(comp, 1), 1)
        comp = parent[comp]
    return mult


def parse_collectives(txt: str) -> list[CollectiveOp]:
    """NOTE: XLA:CPU canonicalizes bf16 arithmetic to f32 (converted
    inputs, f32 dots, f32 reduces), so collective/memory bytes here are
    up to 2x what a bf16-native TPU moves.  The factor is systematic
    across cells and before/after comparisons; reported unadjusted and
    documented in EXPERIMENTS.md §Roofline notes."""
    comps = split_computations(txt)
    parent, trips = while_structure(comps)
    out: list[CollectiveOp] = []
    for cname, lines in comps.items():
        mult = multiplier_of(cname, parent, trips)
        for line in lines:
            kind = next((k for k in COLLECTIVES
                         if re.search(rf"= [^=]*\b{k}\(", line)), None)
            if kind is None:
                continue
            if f"{kind}-done" in line:
                continue          # async pair: count the -start only
            d = parse_def(line)
            if d is None:
                continue
            _, type_str, _, operands, _ = d
            rbytes = _type_bytes(type_str)       # full tuple-aware bytes
            g = _group_size(line)
            if kind == "all-gather":
                operand = rbytes // max(g, 1)
            elif kind == "reduce-scatter":
                operand = rbytes * g
            else:
                operand = rbytes
            out.append(CollectiveOp(kind, operand, g, cname, mult))
    return out


def collective_bytes(txt: str) -> dict[str, float]:
    """Per-device collective operand bytes by kind (trip-count scaled)."""
    agg: dict[str, float] = defaultdict(float)
    for op in parse_collectives(txt):
        agg[op.kind] += float(op.operand_bytes) * op.multiplier
    agg["total"] = sum(agg.values())
    return dict(agg)


# ---------------------------------------------------------------------------
# flops / HBM-bytes with loop multipliers
#
# XLA's compiled.cost_analysis() counts every while body ONCE (verified —
# see EXPERIMENTS.md §Dry-run notes), which under-counts a scanned L-layer
# model by ~L x accum.  We therefore re-derive both terms from the
# partitioned HLO text: dot flops exactly (2 * result_elems *
# contracted_size), elementwise/transcendental at 1/8 flops per element,
# and HBM bytes as operand+result bytes of top-level (non-fused-body)
# ops, all scaled by the computation's loop-nest multiplier.
# ---------------------------------------------------------------------------

_TRANSCENDENTAL = ("exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "cosine", "sine", "logistic")
_ELEMENTWISE_1F = ("add", "subtract", "multiply", "divide", "maximum",
                   "minimum", "select", "compare", "and", "or", "negate",
                   "abs", "floor", "clamp")
# Ops whose operands/results genuinely stream through HBM on a TPU.
# Broadcast/iota/convert/elementwise are excluded: XLA:TPU fuses them
# into consumers (XLA:CPU fuses less, so counting them would import CPU
# fusion decisions into a TPU roofline).
_MEM_OPS = ("fusion", "dot", "copy", "dynamic-slice",
            "dynamic-update-slice", "reduce", "reduce-window",
            "transpose", "concatenate", "scatter", "gather",
            "sort", "reverse", "convolution")
_FREE_OPS = ("bitcast", "reshape", "get-tuple-element", "parameter",
             "constant", "tuple", "after-all")

def _call_graph(comps: dict[str, list[str]]):
    """comp -> list[(callee, site_multiplier)] from fusion/call/while."""
    edges: dict[str, list[tuple[str, int]]] = defaultdict(list)
    for cname, lines in comps.items():
        for line in lines:
            m = parse_def(line)
            if not m:
                continue
            _, _, op_name, _, attrs = m
            if op_name == "while":
                mb = re.search(r"body=%?([\w.\-]+)", attrs)
                mc = re.search(r"condition=%?([\w.\-]+)", attrs)
                if mb:
                    trip = _trip_count(comps.get(
                        mc.group(1) if mc else "", []))
                    edges[cname].append((mb.group(1), max(trip, 1)))
            else:
                for mm in re.finditer(r"calls=%?([\w.\-]+)", attrs):
                    edges[cname].append((mm.group(1), 1))
                mm = re.search(r"to_apply=%?([\w.\-]+)", attrs)
                if mm:
                    edges[cname].append((mm.group(1), 1))
    return edges


def _entry_name(txt: str, comps) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", txt, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    return max(comps, key=lambda c: len(comps[c]))


def _reach_multipliers(txt: str, comps) -> dict[str, float]:
    """Loop-nest multiplier per computation: Kahn topological propagation
    over the call DAG (entry=1; while bodies multiply by trip count)."""
    edges = _call_graph(comps)
    entry = _entry_name(txt, comps)
    # restrict to subgraph reachable from entry
    reach = {entry}
    stack = [entry]
    while stack:
        c = stack.pop()
        for callee, _ in edges.get(c, []):
            if callee not in reach:
                reach.add(callee)
                stack.append(callee)
    indeg = defaultdict(int)
    for c in reach:
        for callee, _ in edges.get(c, []):
            if callee in reach:
                indeg[callee] += 1
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    queue = [entry]
    while queue:
        c = queue.pop()
        for callee, m in edges.get(c, []):
            if callee not in reach:
                continue
            mult[callee] += mult[c] * m
            indeg[callee] -= 1
            if indeg[callee] == 0:
                queue.append(callee)
    return mult


def _fused_bodies(comps) -> set[str]:
    bodies = set()
    for lines in comps.values():
        for line in lines:
            for mm in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)",
                                  line):
                bodies.add(mm.group(1))
    return bodies


_ATTN_META = ("bkgqs", "bqkgh", "bhqk")   # attention einsum signatures


def _rank(tstr: str) -> int:
    m = _SHAPE_RE.search(tstr)
    if not m or not m.group(2):
        return 0
    return len(m.group(2).split(","))


def hlo_flops_bytes(txt: str) -> dict[str, float]:
    """Also separates attention-score-region bytes (``attn_bytes``): ops
    tagged by attention-einsum metadata, propagated through rank>=5
    intermediates (the S x S score tensors).  On a real TPU these live in
    VMEM inside the Pallas flash kernel; ``attn_io_bytes`` (the rank-4
    q/k/v/o traffic of the region) is what the fused kernel actually
    streams — analysis.py uses both to report the kernel-substituted
    memory term."""
    comps = split_computations(txt)
    mult = _reach_multipliers(txt, comps)
    fused = _fused_bodies(comps)
    # fused computations that wrap a dynamic-(update-)slice: their big
    # buffer operand/result is aliased in place on TPU — only the slice
    # moves through HBM
    dus_bodies = {c for c, lines in comps.items()
                  if any(" dynamic-update-slice(" in l or
                         " dynamic-slice(" in l for l in lines)}
    # computations whose BODY carries attention-einsum metadata: fusion
    # ops calling them belong to the scores region even when the calling
    # line itself has no metadata (prefill graphs fuse differently)
    attn_comps = {c for c, lines in comps.items()
                  if any(s in l for l in lines for s in _ATTN_META)}
    flops = 0.0
    byts = 0.0
    attn_bytes = 0.0
    attn_io = 0.0
    transcend = 0.0
    for cname, lines in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        symbols: dict[str, str] = {}
        pending: list[tuple] = []
        for line in lines:
            d = parse_def(line)
            if not d:
                continue
            name, tstr, op, operands, attrs = d
            symbols[name] = tstr
            pending.append((name, tstr, op, operands, attrs))
        is_body = cname in fused
        has_attn_meta = any(s in line for line in lines
                            for s in _ATTN_META)
        tagged: set[str] = set()
        # chain collapsing: XLA:TPU fuses elementwise chains that XLA:CPU
        # leaves as separate kLoop fusions.  A fusion whose single
        # consumer is another fusion is "virtual" — its value never hits
        # HBM on TPU; neither its write nor that read is counted.
        uses: dict[str, int] = defaultdict(int)
        consumers: dict[str, list[str]] = defaultdict(list)
        op_of = {name: op for name, _, op, _, _ in pending}
        for _name, _tstr, op, operands, _attrs in pending:
            for oname in re.findall(r"%([\w.\-]+)", operands):
                uses[oname] += 1
                consumers[oname].append(op)
        virtual: set[str] = set()
        for name, _tstr, op, _operands, _attrs in pending:
            if op == "fusion" and uses[name] == 1 and \
                    consumers[name] == ["fusion"]:
                virtual.add(name)
        for name, tstr, op, operands, attrs in pending:
            out_elems = _type_bytes(tstr) / max(
                _dtype_size_of(tstr), 1)
            opnames = re.findall(r"%([\w.\-]+)", operands)
            is_attn = any(s in attrs for s in _ATTN_META)
            callee_m = re.search(r"calls=%?([\w.\-]+)", attrs)
            if not is_attn and callee_m and \
                    callee_m.group(1) in attn_comps:
                is_attn = True
            if not is_attn and _rank(tstr) >= 5 and (
                    has_attn_meta or any(o in tagged for o in opnames)):
                is_attn = True
            if is_attn:
                tagged.add(name)
            if op == "dot":
                k = _contracted_size(operands, attrs, symbols)
                flops += m * 2.0 * out_elems * k
            elif op == "convolution":
                flops += m * 2.0 * out_elems * 128  # unused by models
            elif op in _TRANSCENDENTAL:
                transcend += m * 8.0 * out_elems
            elif op in _ELEMENTWISE_1F or op in ("reduce",
                                                 "reduce-window"):
                flops += m * out_elems
            if not is_body and op in _MEM_OPS and op not in _FREE_OPS:
                rb = _type_bytes(tstr)
                opbytes = [(_type_bytes(symbols.get(o, "")), o)
                           for o in opnames if o not in virtual]
                callee = re.search(r"calls=%?([\w.\-]+)", attrs)
                is_dus = (op in ("dynamic-update-slice",
                                 "dynamic-slice")
                          or (op == "fusion" and callee and
                              callee.group(1) in dus_bodies))
                if is_dus and opbytes:
                    # in-place slice update/read: the aliased big buffer
                    # doesn't stream on TPU; only the slice moves.
                    #   dynamic-slice:        read+write the slice (=rb)
                    #   dynamic-update-slice: read+write the update
                    #                         (= the small operands)
                    big = max(b for b, _ in opbytes)
                    small = sum(b for b, _ in opbytes) - big
                    b = 2.0 * rb if rb < big else 2.0 * small
                    io_b = b
                else:
                    b = 0.0
                    io_b = 0.0
                    if name not in virtual:
                        b += rb
                        if _rank(tstr) <= 4:
                            io_b += rb
                    for ob, oname in opbytes:
                        b += ob
                        if _rank(symbols.get(oname, "")) <= 4:
                            io_b += ob
                if is_attn:
                    attn_bytes += m * b
                    attn_io += m * io_b
                else:
                    byts += m * b
    return {"flops": flops + transcend, "dot_flops": flops,
            "transcendental_flops": transcend,
            "bytes": byts + attn_bytes,
            "attn_bytes": attn_bytes, "attn_io_bytes": attn_io,
            "bytes_sans_attn": byts}


def _dtype_size_of(tstr: str) -> int:
    m = _SHAPE_RE.search(tstr)
    return DTYPE_BYTES.get(m.group(1), 4) if m else 4


def _contracted_size(operands: str, attrs: str, symbols: dict) -> float:
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", attrs)
    ops = re.findall(r"%([\w.\-]+)", operands)
    if not m or not ops:
        return 1.0
    lhs_t = symbols.get(ops[0], "")
    sm = _SHAPE_RE.search(lhs_t)
    if not sm:
        return 1.0
    dims = [int(x) for x in sm.group(2).split(",")] if sm.group(2) else []
    k = 1.0
    for ci in m.group(1).split(","):
        if ci and int(ci) < len(dims):
            k *= dims[int(ci)]
    return k
