"""repro.analysis: verifier + legality + soundness + gating tests.

The crafted-corpus golden tests pin EXACT diagnostic codes, spans and
fix-hints (rendered form) for one representative broken program per
failure family — the MT0xx codes are a stable public surface (the lint
CLI prints them; CI greps them), so any drift must be a conscious
golden update (set ``REPRO_BLESS=1`` to regenerate).
"""
import os
import subprocess
import sys

import pytest

from _hyp import given, settings, strategies as st

from repro.analysis import (AnalysisError, CODES, Diagnostic,
                            analyze_program, check_program,
                            soundness_report, verify_program)
from repro.core import rules, tasks
from repro.core.engine import TranspositionStore
from repro.core.kernel_ir import KernelProgram, OpNode, TensorSpec
from repro.kernels.schedule import KernelSchedule

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "analysis")

F32 = TensorSpec((256, 256))


def _mm(name="mm", sched=None, **kw):
    """One-matmul program with overridable pieces."""
    d = dict(
        name=name,
        inputs=(("x", F32), ("w", F32)),
        nodes=(OpNode("y", "matmul", ("x", "w")),),
        outputs=("y",),
        fusion_groups=(("y",),),
        schedules=((("y", sched),) if sched is not None else ()))
    d.update(kw)
    return KernelProgram(**d)


# -- the crafted corpus: name -> broken program ------------------------------

def _cyclic():
    return _mm(nodes=(OpNode("a", "relu", ("b",)),
                      OpNode("b", "relu", ("a",))),
               outputs=("b",), fusion_groups=(("a",), ("b",)))


def _dtype_mismatch():
    return _mm(inputs=(("x", TensorSpec((256, 256), "float64")),
                       ("w", TensorSpec((256, 256), "bfloat16"))))


def _vmem_overflow():
    big = TensorSpec((4096, 4096))
    return _mm(inputs=(("x", big), ("w", big)),
               sched=KernelSchedule(blocks={"bm": 4096, "bn": 4096,
                                            "bk": 4096}))


def _misaligned_tile():
    return _mm(sched=KernelSchedule(blocks={"bm": 4, "bn": 128,
                                            "bk": 128}))


def _indivisible_tile():
    return _mm(sched=KernelSchedule(blocks={"bm": 96}))


def _dead_node():
    return _mm(nodes=(OpNode("y", "matmul", ("x", "w")),
                      OpNode("z", "relu", ("y",))),
               fusion_groups=(("y",), ("z",)))


def _undefined_ref():
    return _mm(nodes=(OpNode("y", "matmul", ("x", "nope")),))


def _unknown_op():
    return _mm(nodes=(OpNode("y", "conv3d", ("x", "w")),))


def _bad_arity():
    return _mm(nodes=(OpNode("y", "matmul", ("x", "w", "x")),))


def _shape_mismatch():
    return _mm(inputs=(("x", TensorSpec((256, 64))), ("w", F32)))


def _missing_output():
    return _mm(outputs=("y", "ghost"))


def _duplicate_name():
    return _mm(nodes=(OpNode("x", "relu", ("x",)),),
               outputs=("x",), fusion_groups=(("x",),))


def _bad_fusion_pattern():
    return _mm(nodes=(OpNode("sm", "softmax", ("x",)),
                      OpNode("y", "matmul", ("sm", "w")),),
               fusion_groups=(("sm", "y"),),
               schedules=())


def _non_partition():
    return _mm(nodes=(OpNode("y", "matmul", ("x", "w")),
                      OpNode("z", "relu", ("y",)),),
               outputs=("z",),
               fusion_groups=(("y",),))          # z unassigned


def _disconnected_group():
    return _mm(nodes=(OpNode("y", "matmul", ("x", "w")),
                      OpNode("z", "matmul", ("x", "w")),),
               outputs=("y", "z"), fusion_groups=(("y", "z"),))


def _sched_nonroot():
    return _mm(schedules=(("w", KernelSchedule()),))


def _tile_not_applicable():
    return _mm(sched=KernelSchedule(blocks={"bq": 128}))


def _bad_depth():
    return _mm(sched=KernelSchedule(pipeline_depth=9))


def _bad_loop_order():
    return _mm(sched=KernelSchedule(loop_order=("m", "n", "q")))


def _bad_split_k():
    x = TensorSpec((32, 100))
    w = TensorSpec((100, 256))
    return _mm(inputs=(("x", x), ("w", w)),
               sched=KernelSchedule(flags=("split_k=4",)))


def _bad_epilogue():
    return _mm(sched=KernelSchedule(epilogue="cube"))


def _unused_input():
    return _mm(inputs=(("x", F32), ("w", F32), ("spare", F32)))


CORPUS = {
    "cyclic": _cyclic,
    "dtype_mismatch": _dtype_mismatch,
    "vmem_overflow": _vmem_overflow,
    "misaligned_tile": _misaligned_tile,
    "indivisible_tile": _indivisible_tile,
    "dead_node": _dead_node,
    "undefined_ref": _undefined_ref,
    "unknown_op": _unknown_op,
    "bad_arity": _bad_arity,
    "shape_mismatch": _shape_mismatch,
    "missing_output": _missing_output,
    "duplicate_name": _duplicate_name,
    "bad_fusion_pattern": _bad_fusion_pattern,
    "non_partition": _non_partition,
    "disconnected_group": _disconnected_group,
    "sched_nonroot": _sched_nonroot,
    "tile_not_applicable": _tile_not_applicable,
    "bad_depth": _bad_depth,
    "bad_loop_order": _bad_loop_order,
    "bad_split_k": _bad_split_k,
    "bad_epilogue": _bad_epilogue,
    "unused_input": _unused_input,
}

# every corpus entry must trip at least this code (sanity on coverage)
EXPECT_CODE = {
    "cyclic": "MT013", "dtype_mismatch": "MT015",
    "vmem_overflow": "MT023", "misaligned_tile": "MT022",
    "indivisible_tile": "MT021",
    "dead_node": "MT008", "undefined_ref": "MT002",
    "unknown_op": "MT003", "bad_arity": "MT004",
    "shape_mismatch": "MT005", "missing_output": "MT007",
    "duplicate_name": "MT001", "bad_fusion_pattern": "MT011",
    "non_partition": "MT010", "disconnected_group": "MT014",
    "sched_nonroot": "MT012", "tile_not_applicable": "MT020",
    "bad_depth": "MT024", "bad_loop_order": "MT025",
    "bad_split_k": "MT027", "bad_epilogue": "MT028",
    "unused_input": "MT009",
}


@pytest.mark.parametrize("name", sorted(CORPUS))
def test_crafted_corpus_golden(name):
    prog = CORPUS[name]()
    got = "\n".join(d.render(name) for d in analyze_program(prog))
    path = os.path.join(GOLDEN, f"{name}.txt")
    if os.environ.get("REPRO_BLESS"):
        os.makedirs(GOLDEN, exist_ok=True)
        with open(path, "w") as f:
            f.write(got + "\n")
    with open(path) as f:
        want = f.read().rstrip("\n")
    assert got == want
    assert any(d.code == EXPECT_CODE[name]
               for d in analyze_program(prog))


def test_corpus_covers_every_wellformedness_and_legality_code():
    hit = {d.code for fn in CORPUS.values()
           for d in analyze_program(fn())}
    registered = {c for c in CODES
                  if c.startswith(("MT00", "MT01", "MT02"))
                  and not c.startswith("MT03")}
    missing = registered - hit - {"MT006", "MT026"}
    # MT006 needs a mixed-dtype matmul reachable only when inputs are
    # error-free; MT026 is target-specific — both covered below
    assert not missing, f"codes never exercised: {sorted(missing)}"


def test_mt006_mixed_matmul_dtype_warning():
    prog = _mm(inputs=(("x", TensorSpec((256, 256), "bfloat16")),
                       ("w", F32)))
    ds = verify_program(prog)
    assert [d.code for d in ds] == ["MT006"]
    assert not ds[0].is_error and ds[0].span == ("y",)


def test_mt026_compute_dtype_vs_target():
    prog = _mm(nodes=(OpNode("y", "matmul", ("x", "w"),
                             attrs=(("compute_dtype", "float16"),
                                    ("out_dtype", "float16"))),))
    # fp16 has a tensor-core rate on gpu_a100, none on tpu_v5e
    assert not [d for d in analyze_program(prog, "gpu_a100")
                if d.code == "MT026"]
    bad = [d for d in analyze_program(prog, "tpu_v5e")
           if d.code == "MT026"]
    assert bad and bad[0].is_error and bad[0].span == ("y",)
    # the envelope (target=None) stays target-agnostic
    assert not [d for d in analyze_program(prog)
                if d.code == "MT026"]


def test_committed_suites_are_error_free():
    for fn in ("kb_level1", "kb_level2", "kb_level3", "tb_t", "tb_g",
               "ext_tasks", "train_tasks"):
        for t in getattr(tasks, fn)():
            prog = t.program if hasattr(t, "program") else t
            errs = [d for d in analyze_program(prog) if d.is_error]
            assert not errs, (fn, prog.name,
                              [d.render() for d in errs])


# -- property: the legal action space stays inside the analyzer -------------

_ALL_TASKS = [t.program if hasattr(t, "program") else t
              for fn in ("kb_level1", "kb_level2", "tb_t", "tb_g",
                         "ext_tasks")
              for t in getattr(tasks, fn)()]


@settings(max_examples=12, deadline=None)
@given(idx=st.integers(0, len(_ALL_TASKS) - 1))
def test_legal_actions_produce_analyzable_programs(idx):
    task = _ALL_TASKS[idx]
    for act in rules.candidate_actions(task, extended=True):
        if rules.is_terminal(act):
            continue
        try:
            child = rules.apply_rule(task, act)
        except rules.CompileError:
            continue      # self-rejection is legal (floated legality)
        errs = [d for d in analyze_program(child) if d.is_error]
        assert not errs, (task.name, rules.describe(act),
                          [d.render() for d in errs])


def test_rule_soundness_harness_over_all_suites():
    progs = [t.program if hasattr(t, "program") else t
             for fn in ("kb_level1", "kb_level2", "kb_level3", "tb_t",
                        "tb_g", "ext_tasks", "train_tasks")
             for t in getattr(tasks, fn)()]
    ds = soundness_report(progs, extended=True)
    errs = [d for d in ds if d.is_error]
    assert not errs, [d.render() for d in errs[:5]]
    # self-rejections exist (BAD_TILES-adjacent presets) and are warnings
    assert all(d.code == "MT031" for d in ds)


# -- diagnostics registry ----------------------------------------------------

def test_diagnostic_registry_contract():
    with pytest.raises(ValueError):
        Diagnostic("MT999", "nope")
    d = Diagnostic("MT013", "loop", span=("a", "b"))
    assert d.severity == "error" and d.is_error
    assert d.render("p") == "p:a,b: error MT013: loop"
    w = Diagnostic("MT008", "dead")
    assert w.severity == "warning" and not w.is_error
    assert w.render() == "<program>: warning MT008: dead"
    e = AnalysisError((d,), program="p")
    assert "MT013" in str(e) and e.diagnostics == (d,)


def test_compile_errors_carry_diagnostics():
    prog = _mm()
    with pytest.raises(rules.CompileError) as ei:
        rules.check_tiles(prog, ("y",), {"bm": 100})
    assert ei.value.diagnostic.code == "MT021"
    assert ei.value.diagnostic.span == ("y",)
    with pytest.raises(rules.CompileError) as ei:
        rules.check_tiles(prog, ("y",), {"bq": 128})
    assert ei.value.diagnostic.code == "MT020"
    with pytest.raises(rules.CompileError) as ei:
        rules.check_tiles(prog, ("y",), {"bm": 4})
    assert ei.value.diagnostic.code == "MT022"
    with pytest.raises(rules.CompileError) as ei:
        rules.check_fusion_pattern(_bad_fusion_pattern(), ("sm", "y"))
    assert ei.value.diagnostic.code == "MT011"
    assert ei.value.diagnostic.span == ("sm", "y")


# -- gating integrations -----------------------------------------------------

def test_store_check_gates_before_oracle():
    task = _ALL_TASKS[0]
    store = TranspositionStore()
    bad = _undefined_ref()
    assert store.check(task, bad) is False
    assert store.stats["analysis_rejects"] == 1
    assert store.stats["oracle_runs"] == 0        # never priced an eval
    # verdicts memoize by fingerprint
    assert store.analysis_ok(bad) is False
    assert store.stats["analysis_hits"] >= 1
    # a sound program still flows through to the oracle path
    assert store.check(task, task) is True
    assert store.stats["analysis_rejects"] == 1
    # eviction drops the verdict with the program slab
    store.intern(task)
    assert store.fingerprint(task) in store.analysis
    store.evict_lru(0)
    assert store.fingerprint(task) not in store.analysis


def test_harness_refuses_statically_rejected_programs():
    from repro.measure.harness import ExecutionHarness, MeasureError
    h = ExecutionHarness(runner=lambda t, p, tgt: 1e-3)
    task = _ALL_TASKS[0]
    with pytest.raises(MeasureError) as ei:
        h.measure(task, _undefined_ref())
    assert "MT002" in str(ei.value)
    assert h.stats["analysis_rejects"] == 1
    assert h.stats["measured"] == 0
    h.measure(task, task)                  # sound program still times
    assert h.stats["measured"] == 1


def test_service_rejects_illformed_submission_with_diagnostics():
    from repro.core import OptimizeConfig
    from repro.serve.engine import KernelService
    svc = KernelService(config=OptimizeConfig(mode="greedy_cost",
                                              max_steps=2,
                                              validate=False),
                        serve_workers=1)
    try:
        with pytest.raises(AnalysisError) as ei:
            svc.submit(_cyclic())
        assert any(d.code == "MT013" for d in ei.value.diagnostics)
        st = svc.stats()
        assert st["submit_analysis_rejects"] == 1
        assert st["requests"] == 0          # never took a queue slot
        # well-formed traffic is unaffected
        fut = svc.submit(_ALL_TASKS[0])
        res = svc.result(fut, timeout=120)
        assert res.program.fingerprint()
    finally:
        svc.close()


def test_fleet_rejects_illformed_submission_at_admission(tmp_path):
    from repro.serve.fleet import Fleet, FleetConfig
    fl = Fleet(str(tmp_path / "db"),
               FleetConfig(replicas=1, refine=False),
               auto_start=False, serve_workers=1)
    try:
        with pytest.raises(AnalysisError):
            fl.submit(_bad_arity())
        st = fl.stats()
        assert st["analysis_rejects"] == 1
        assert st["admitted"] == 0
    finally:
        fl.close()


# -- the lint CLI ------------------------------------------------------------

def test_lint_cli_clean_on_committed_artifacts():
    from repro.analysis import lint
    rc = lint.main(["-q", "--suites", "ext",
                    "--db", os.path.join(REPO, "tests", "fixtures",
                                         "measure_db")])
    assert rc == 0


def test_lint_cli_flags_broken_program_file(tmp_path, capsys):
    import json
    from repro.analysis import lint
    from repro.core.kernel_ir import program_to_json
    p = tmp_path / "bad.json"
    p.write_text(json.dumps(program_to_json(_undefined_ref())))
    rc = lint.main(["-q", "--suites", "", str(p)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "MT002" in out


def test_lint_cli_module_entrypoint():
    r = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "-q",
         "--suites", "kb"],
        capture_output=True, text=True, timeout=600,
        env=dict(os.environ,
                 PYTHONPATH=os.path.join(REPO, "src")
                 + os.pathsep + os.environ.get("PYTHONPATH", "")),
        cwd=REPO)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 errors" in r.stdout
