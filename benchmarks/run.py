"""Benchmark harness — one function per paper table.

Prints ``name,us_per_call,derived`` CSV rows.  Modeled kernel times come
from the roofline cost model for the selected hardware target (this
container has no TPU); accuracy is real (every optimized program is
executed and checked against the task oracle on CPU).

  PYTHONPATH=src python -m benchmarks.run [--tables 3,4,5,6,7,8,9]
                                          [--retrain] [--fast]

Run from the repo root (or put the repo root on PYTHONPATH): the
package uses relative imports and never mutates sys.path.
"""
from __future__ import annotations

import argparse
import json
import os

from .common import RESULTS, cached_policy


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tables", default="3,4,5,6,7,8,9")
    ap.add_argument("--retrain", action="store_true")
    ap.add_argument("--fast", action="store_true",
                    help="fewer PPO iters (CI smoke)")
    ap.add_argument("--workers", type=int, default=None,
                    help="engine worker threads per suite eval "
                         "(default: all cores)")
    args = ap.parse_args()
    tables = set(args.tables.split(","))
    if args.workers is not None:
        from . import common
        common.WORKERS = args.workers

    kw = dict(iters=4, episodes=4) if args.fast else {}
    policy = cached_policy(retrain=args.retrain, **kw)
    rows: list[str] = []
    print("name,us_per_call,derived")

    def emit(new_rows):
        for r in new_rows:
            print(r, flush=True)
        rows.extend(new_rows)

    if "3" in tables:
        from . import table3_kernelbench
        emit(table3_kernelbench.run(policy))
    if "4" in tables:
        from . import table4_tritonbench
        emit(table4_tritonbench.run(policy))
    if "5" in tables:
        from . import table5_target
        emit(table5_target.run(policy))
    if "6" in tables:
        from . import table6_hier
        emit(table6_hier.run(policy))
    if "7" in tables:
        from . import table7_policy
        emit(table7_policy.run(policy))
    if "8" in tables:
        from . import table8_targets
        emit(table8_targets.run(policy))
    if "9" in tables:
        from . import table9_rules
        emit(table9_rules.run(policy))
    if "11" in tables:
        # not in the default set: its rows live in results/coder_bench.csv
        # (standalone, like serve/measure bench) so the committed
        # benchmarks.csv baseline stays comparable across PRs
        from . import table11_coder
        emit(table11_coder.run(policy, fast=args.fast))

    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "benchmarks.csv"), "w") as f:
        f.write("name,us_per_call,derived\n" + "\n".join(rows) + "\n")
    if getattr(policy, "train_log", None):
        with open(os.path.join(RESULTS, "policy_training.json"),
                  "w") as f:
            json.dump({"meta": getattr(policy, "meta", {}),
                       "log": policy.train_log}, f, indent=1)
    from .common import STORE
    print("# engine store:", json.dumps(STORE.stats_dict()))


if __name__ == "__main__":
    main()
