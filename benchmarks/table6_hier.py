"""Paper Table 6 — hierarchical stepwise vs single-pass ("w/o Hier"):
feeding the whole optimization plan at once degrades accuracy/speedup."""
from __future__ import annotations

from .common import eval_mode, fmt_row
from repro.core import tasks as T


def run(policy) -> list[str]:
    rows = []
    for level, suite_fn in [("L1", T.kb_level1), ("L2", T.kb_level2),
                            ("L3", T.kb_level3)]:
        suite = suite_fn()
        m = eval_mode(suite, "policy", policy)
        rows.append(fmt_row("table6", f"{level}/ours_stepwise", m))
        m = eval_mode(suite, "single_pass", None)
        rows.append(fmt_row("table6", f"{level}/single_pass_w/o_hier", m))
    return rows
