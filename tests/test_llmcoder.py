"""LLM micro-coder subsystem: protocol conformance, transcripts, the
verify-and-repair loop, and the coder seam through config/engine/serve.

The conformance suite runs the SAME properties against
``StructuredMicroCoder`` and ``LLMMicroCoder(ReplayBackend)`` over the
committed fixtures in ``tests/fixtures/llm_transcripts/`` — fully
offline (the CI ``coder-replay`` job runs this file with zero network).
"""
import dataclasses
import json
import os
import sys
import threading
import time

import pytest

from repro.core import actions as A
from repro.core import rules as R
from repro.core import tasks as T
from repro.core.config import OptimizeConfig
from repro.core.engine import EngineConfig, EvalEngine, TranspositionStore
from repro.core.kernel_ir import program_to_json
from repro.core.micro_coding import (ApplyResult, StructuredMicroCoder,
                                     get_coder)
from repro.llmcoder import (BackendError, CoderBackend, CoderRequest,
                            LLMMicroCoder, LoopConfig, ReplayBackend,
                            TranscriptStore, make_coder, make_record,
                            transcript_key)
from repro.llmcoder.prompts import (ResponseParseError, build_prompt,
                                    extract_json, parse_response,
                                    render_program)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures",
                        "llm_transcripts")
STATUSES = {"ok", "compile_error", "wrong_result"}


def _task(name="L1_matmul_0"):
    by_name = {t.name: t for t in T.kb_level1() + T.open_tasks()}
    return by_name[name]


def _replay_coder() -> LLMMicroCoder:
    return make_coder(f"llm-replay:{FIXTURES}")


def _applicable_action(task):
    """(action, rewritten-child JSON) for the first root action the
    registry can implement — a known-good scripted response."""
    for act in R.candidate_actions(task):
        if R.is_terminal(act):
            continue
        try:
            child = R.apply_rule(task, act)
        except R.CompileError:
            continue
        return act, json.dumps(program_to_json(child), sort_keys=True)
    raise AssertionError(f"no applicable action on {task.name}")


# ---------------------------------------------------------------------------
# protocol conformance: one property suite, both coders
# ---------------------------------------------------------------------------

def _coders():
    return [("structured", StructuredMicroCoder()),
            ("llm-replay", _replay_coder())]


@pytest.mark.parametrize("name,coder", _coders())
def test_conformance_status_vocabulary(name, coder):
    task = _task()
    if hasattr(coder, "bind_task"):
        coder.bind_task(task)
    for act in R.candidate_actions(task):
        res = coder.apply(task, act)
        assert isinstance(res, ApplyResult)
        assert res.status in STATUSES, (name, res.status)
        if res.status == "ok":
            assert res.program is not None
        else:
            assert res.program is None and res.detail


@pytest.mark.parametrize("name,coder", _coders())
def test_conformance_ok_children_verified(name, coder):
    """Every ``ok`` child passes the engine's full check (analysis gate
    + numeric oracle) and carries sane identity/provenance."""
    task = _task()
    if hasattr(coder, "bind_task"):
        coder.bind_task(task)
    store = TranspositionStore()
    n_ok = 0
    for act in R.candidate_actions(task):
        if R.is_terminal(act):
            continue
        res = coder.apply(task, act)
        if res.status != "ok":
            continue
        n_ok += 1
        child = res.program
        assert child.name == task.name
        assert child.history == task.history + (act.describe(),)
        assert dict(child.inputs) == dict(task.inputs)
        assert store.check(task, child), (name, act.describe())
    assert n_ok > 0


def test_conformance_store_cache_parity():
    """Fingerprint-keyed store results identical across coders on the
    closed rule space — the property that lets a replica swap coders
    without poisoning shared transposition-store edges."""
    task = _task()
    llm = _replay_coder()
    llm.bind_task(task)
    outcomes = {}
    for tag, coder in (("s", StructuredMicroCoder()), ("l", llm)):
        store = TranspositionStore()
        for act in R.candidate_actions(task):
            res = store.apply(coder, task, act)
            fp = res.program.fingerprint() if res.status == "ok" else None
            outcomes.setdefault(R.describe(act), {})[tag] = (res.status, fp)
    for desc, o in outcomes.items():
        assert o["s"] == o["l"], (desc, o)


def test_replay_serves_fixtures_without_misses():
    task = _task()
    llm = _replay_coder()
    llm.bind_task(task)
    for act in R.candidate_actions(task):
        llm.apply(task, act)
    stats = llm.stats_dict()
    assert stats["coder_backend_misses"] == 0
    assert stats["coder_backend_replays"] > 0


# ---------------------------------------------------------------------------
# transcript store
# ---------------------------------------------------------------------------

def test_transcript_key_is_attempt_scoped():
    k0 = transcript_key("t", "p", "a", 0)
    k1 = transcript_key("t", "p", "a", 1)
    assert k0 != k1 and len(k0) == 24
    assert transcript_key("t", "p", "a", 0) == k0


def test_transcript_store_roundtrip_and_idempotence(tmp_path):
    root = str(tmp_path / "ts")
    st = TranscriptStore(root)
    rec = make_record("t1", "p1", "act", 0, prompt="q", response="r")
    st.put(rec)
    st.put(dict(rec, response="DIFFERENT"))  # same key: first wins
    again = TranscriptStore(root)
    assert len(again) == 1
    got = again.lookup("t1", "p1", "act", 0)
    assert got["response"] == "r"
    assert "q" not in json.dumps(got)  # prompt stored as hash only
    assert again.lookup("t1", "p1", "act", 1) is None


def test_transcript_any_task_fallback(tmp_path):
    st = TranscriptStore(str(tmp_path))
    st.put(make_record("taskA", "p1", "act", 0, response="r"))
    assert st.lookup("taskB", "p1", "act", 0) is None
    assert st.lookup_any("p1", "act", 0)["response"] == "r"


def test_replay_backend_replays_recorded_refusals(tmp_path):
    st = TranscriptStore(str(tmp_path))
    st.put(make_record("t", "p", "act", 0, error="cannot implement"))
    be = ReplayBackend(st)
    req = CoderRequest("t", "p", "act", 0, "", {}, None)
    with pytest.raises(BackendError, match="cannot implement"):
        be.complete(req)
    with pytest.raises(BackendError, match="no recorded transcript"):
        be.complete(CoderRequest("t", "p", "other", 0, "", {}, None))
    assert be.stats["misses"] == 1


# ---------------------------------------------------------------------------
# prompts / parsing
# ---------------------------------------------------------------------------

def test_render_program_is_route_independent():
    task = _task()
    a = task.replace(name="x", history=("step1",))
    b = task.replace(name="y", history=())
    assert render_program(a) == render_program(b)


def test_build_prompt_embeds_feedback():
    task = _task()
    act, _ = _applicable_action(task)
    p0 = build_prompt(task, act)
    p1 = build_prompt(task, act, ("MT021: tile does not divide",))
    assert p0 != p1 and "MT021" in p1 and "failed verification" in p1


def test_extract_json_tolerates_fences_and_prose():
    payload = {"a": [1, 2], "s": "brace } in string"}
    text = f"Sure thing:\n```json\n{json.dumps(payload)}\n```\ndone"
    assert extract_json(text) == payload
    with pytest.raises(ResponseParseError):
        extract_json("no json here")
    with pytest.raises(ResponseParseError):
        extract_json('{"unterminated": ')


def test_parse_response_roundtrips_program_json():
    task = _task()
    text = json.dumps(program_to_json(task))
    prog = parse_response(text)
    assert prog.fingerprint() == task.fingerprint()
    with pytest.raises(ResponseParseError):
        parse_response("")
    with pytest.raises(ResponseParseError):
        parse_response('{"not": "a program"}')


# ---------------------------------------------------------------------------
# the verify-and-repair loop
# ---------------------------------------------------------------------------

class _ScriptedBackend(CoderBackend):
    """Returns queued responses/exceptions in order."""
    name = "scripted"
    instant = True

    def __init__(self, script):
        self.script = list(script)
        self.requests = []

    def complete(self, req):
        self.requests.append(req)
        item = self.script.pop(0)
        if isinstance(item, Exception):
            raise item
        return item


def test_loop_parse_reject_then_repair():
    task = _task()
    act, good = _applicable_action(task)
    be = _ScriptedBackend(["utter garbage", good])
    coder = LLMMicroCoder(be)
    res = coder.apply(task, act)
    assert res.status == "ok" and res.detail == "repaired"
    assert coder.counters["parse_rejects"] == 1
    assert coder.counters["repaired_ok"] == 1
    assert coder.repair_depth == {1: 1}
    # the repair prompt carried the parse feedback
    assert be.requests[1].attempt == 1
    assert be.requests[1].feedback


def test_loop_rejects_contract_changes():
    task = _task()
    act, _ = _applicable_action(task)
    broken = task.replace(
        inputs=task.inputs + (("zz_pad", task.inputs[0][1]),))
    be = _ScriptedBackend([json.dumps(program_to_json(broken))] * 3)
    coder = LLMMicroCoder(be)
    res = coder.apply(task, act)
    assert res.status == "compile_error"
    assert "contract" in res.detail
    assert coder.counters["gave_up"] == 1
    assert coder.counters["analysis_rejects"] == 3


def test_loop_oracle_rejects_wrong_numerics():
    """A graph rewrite that changes results must be caught by the
    numeric oracle and reported as wrong_result after attempts run out."""
    task = _task("L1_matmul_0")  # square 512x512: operands swappable
    act, _ = _applicable_action(task)
    # same contract, same shapes, different math: matmul(b, a)
    wrong = task.replace(nodes=tuple(
        dataclasses.replace(n, inputs=("b", "a")) if n.op == "matmul"
        else n for n in task.nodes))
    assert wrong.eval_fingerprint() != task.eval_fingerprint()
    be = _ScriptedBackend([json.dumps(program_to_json(wrong))] * 3)
    coder = LLMMicroCoder(be)
    res = coder.apply(task, act)
    assert res.status == "wrong_result"
    assert "max|delta|" in res.detail
    assert coder.counters["oracle_rejects"] == 3
    assert coder.counters["gave_up"] == 1
    # the repair prompts carried the mismatch summary forward
    assert any("mismatch" in f for f in be.requests[-1].feedback)


def test_loop_transient_backoff_does_not_burn_attempts():
    task = _task()
    act, good = _applicable_action(task)
    be = _ScriptedBackend([BackendError("rate limited", transient=True),
                           BackendError("rate limited", transient=True),
                           good])
    coder = LLMMicroCoder(be, LoopConfig(backoff_base_s=0.001))
    res = coder.apply(task, act)
    assert res.status == "ok" and res.detail == ""  # no repair round
    assert [r.attempt for r in be.requests] == [0, 0, 0]
    assert coder.counters["repairs"] == 0
    assert coder.repair_depth == {0: 1}


def test_loop_nontransient_backend_error_is_compile_error():
    task = _task()
    act, _ = _applicable_action(task)
    be = _ScriptedBackend([BackendError("cannot implement that")])
    coder = LLMMicroCoder(be)
    res = coder.apply(task, act)
    assert res.status == "compile_error" and "backend" in res.detail
    assert coder.counters["backend_errors"] == 1
    assert len(be.requests) == 1  # a refusal is terminal, no retry


def test_loop_attempt_timeout():
    task = _task()
    act, good = _applicable_action(task)

    class Slow(CoderBackend):
        name = "slow"
        instant = False  # opt into the timeout thread

        def complete(self, req):
            time.sleep(0.5)
            return good

    coder = LLMMicroCoder(Slow(), LoopConfig(
        attempt_timeout_s=0.02, transient_retries=1,
        backoff_base_s=0.001, max_attempts=1))
    res = coder.apply(task, act)
    assert res.status == "compile_error"
    assert "timed out" in res.detail


def test_loop_terminal_action_shortcut():
    task = _task()
    be = _ScriptedBackend([])  # must never be called
    res = LLMMicroCoder(be).apply(task, A.STOP)
    assert res.status == "ok" and res.program is task
    assert not be.requests


def test_bind_task_is_thread_local():
    task_a, task_b = T.kb_level1()[0], T.kb_level1()[1]
    coder = _replay_coder()
    seen = {}

    def worker(task):
        coder.bind_task(task)
        time.sleep(0.02)
        seen[task.name] = coder._task_fp(task)

    ts = [threading.Thread(target=worker, args=(t,))
          for t in (task_a, task_b)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert seen[task_a.name] == task_a.fingerprint()
    assert seen[task_b.name] == task_b.fingerprint()


# ---------------------------------------------------------------------------
# open space: verified programs the closed rule space cannot produce
# ---------------------------------------------------------------------------

def test_open_space_repair_recovers_analyzer_reject():
    """On a ragged task the replayed LLM coder lands a verified tiling
    the structured coder refuses — via a repair round recovering the
    first attempt's analyzer reject (the acceptance-criteria counters)."""
    task = _task("OPEN_ragged_gemm")
    llm = _replay_coder()
    llm.bind_task(task)
    sc = StructuredMicroCoder()
    store = TranspositionStore()
    landed = None
    for act in R.candidate_actions(task):
        if R.is_terminal(act):
            continue
        rs, rl = sc.apply(task, act), llm.apply(task, act)
        if rs.status == "compile_error" and rl.status == "ok":
            landed = rl.program
            break
    assert landed is not None, "no open-space landing replayed"
    assert store.check(task, landed)
    blocks = {v for _, s in landed.schedules for _, v in s.blocks}
    assert blocks - {64, 128, 256, 512}, "landed tiles are preset-shaped"
    stats = llm.stats_dict()
    assert stats["coder_analysis_rejects"] >= 1
    assert stats["coder_repaired_ok"] >= 1
    assert stats["coder_repair_depth"].get(1, 0) >= 1


def test_template_adapt_matches_replay_on_open_space():
    """The committed open-space transcripts are exactly what the adapt
    template backend produces live (fixture-freshness guard)."""
    task = _task("OPEN_ragged_gemm")
    live = make_coder("llm-adapt")
    rep = _replay_coder()
    for coder in (live, rep):
        coder.bind_task(task)
    for act in R.candidate_actions(task)[:6]:
        a, b = live.apply(task, act), rep.apply(task, act)
        assert a.status == b.status, R.describe(act)
        if a.status == "ok":
            assert a.program.fingerprint() == b.program.fingerprint()


# ---------------------------------------------------------------------------
# the coder seam: get_coder / config / engine / serve
# ---------------------------------------------------------------------------

def test_get_coder_dispatch():
    assert isinstance(get_coder(None), StructuredMicroCoder)
    assert isinstance(get_coder("structured"), StructuredMicroCoder)
    llm = get_coder("llm-template")
    assert isinstance(llm, LLMMicroCoder)
    assert get_coder(llm) is llm  # instance passthrough
    with pytest.raises(ValueError):
        get_coder("bogus")
    with pytest.raises(ValueError):
        make_coder("llm-replay:")


def test_coder_names():
    assert StructuredMicroCoder().name == "structured"
    assert make_coder("llm-template").name == "llm-template"
    assert make_coder("llm-adapt").name == "llm-template-adapt"
    assert _replay_coder().name == "llm-replay"


def test_engine_config_coder_roundtrip():
    oc = OptimizeConfig(coder="llm-template")
    ec = EngineConfig.from_optimize(oc)
    assert ec.coder == "llm-template"
    assert ec.to_optimize().coder == "llm-template"
    # instance-valued coder collapses to its name in the legacy record
    inst = make_coder("llm-template")
    assert EngineConfig.from_optimize(
        OptimizeConfig(coder=inst)).coder == "llm-template"
    assert EngineConfig().coder == "structured"


def test_engine_shares_one_coder_and_exposes_stats():
    eng = EvalEngine(None, config=OptimizeConfig(
        mode="greedy_cost", max_steps=2,
        coder=f"llm-replay:{FIXTURES}"))
    assert eng.pipeline()._coder is eng.coder
    eng.evaluate_suite([_task()])
    stats = eng.stats()
    assert stats["coder_name"] == "llm-replay"
    assert stats["coder_proposals"] > 0
    assert stats["coder_backend_misses"] == 0
    # store counters still present and unshadowed by the coder_ prefix
    assert "edges" in stats and "analysis_rejects" in stats


def test_engine_default_coder_is_structured():
    eng = EvalEngine(None, config=OptimizeConfig(max_steps=2))
    assert isinstance(eng.coder, StructuredMicroCoder)
    assert eng.stats()["coder_name"] == "structured"


def test_service_serves_replay_coder_and_stats():
    from repro.serve.engine import KernelService
    svc = KernelService(None, config=OptimizeConfig(
        mode="greedy_cost", max_steps=2,
        coder=f"llm-replay:{FIXTURES}"))
    try:
        res = svc.submit(_task()).result()
        assert res.correct
        stats = svc.stats()
        assert stats["coder_name"] == "llm-replay"
        assert stats["coder_proposals"] > 0
    finally:
        svc.close()


def test_winner_db_key_coder_suffix(tmp_path):
    from repro.serve.engine import KernelService
    task = _task()
    keys = {}
    for spec in ("structured", "llm-template"):
        svc = KernelService(None, measure=True,
                            measure_db=str(tmp_path / spec),
                            config=OptimizeConfig(
                                mode="greedy_cost", max_steps=2,
                                coder=spec))
        try:
            keys[spec] = svc._winner_db_key(task, None, None)[0]
        finally:
            svc.close()
    # a non-default coder is a different warm-start question; the
    # default leaves pre-existing winner records readable
    assert keys["structured"] != keys["llm-template"]
    assert "llm-template" in keys["llm-template"]
    assert "llm-template" not in keys["structured"]


# ---------------------------------------------------------------------------
# lint --transcripts + repolint backend gate
# ---------------------------------------------------------------------------

def test_lint_transcripts_clean_on_fixtures(capsys):
    from repro.analysis import lint
    rc = lint.main(["-q", "--suites", "", "--transcripts", FIXTURES])
    out = capsys.readouterr().out
    assert rc == 0, out
    assert "repaired first-attempt rejects" in out


def test_lint_transcripts_flags_corrupt_and_bad_final(tmp_path):
    from repro.analysis import lint
    tdir = str(tmp_path / "tr")
    st = TranscriptStore(tdir)
    # a chain ending on an unparseable response must fail the sweep
    st.put(make_record("t", "p", "act", 0, response="not json"))
    with open(os.path.join(tdir, "t.jsonl"), "a") as f:
        f.write("{truncated\n")
    rc = lint.main(["-q", "--suites", "", "--transcripts", tdir])
    assert rc == 1


def test_no_backend_imports_outside_llmcoder():
    """Acceptance guard: concrete coder backends are protocol-private.
    The gate lives in tools/repolint.py (shared with CI); this pins it
    into tier 1."""
    repo = os.path.join(os.path.dirname(__file__), "..")
    sys.path.insert(0, os.path.join(repo, "tools"))
    try:
        import repolint
    finally:
        sys.path.pop(0)
    offenders = repolint.lint_backend_imports(repo)
    assert not offenders, "\n".join(offenders)
    # and the gate actually bites: a synthetic offender is caught
    probe = os.path.join(repo, "src", "repro", "_lint_probe.py")
    try:
        with open(probe, "w") as f:
            f.write("from repro.llmcoder.backend import ReplayBackend\n")
        assert repolint.lint_backend_imports(repo)
    finally:
        os.remove(probe)
