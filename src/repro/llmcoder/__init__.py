"""LLM micro-coder subsystem: propose → lower → verify → repair.

The paper's Micro Coding stage is a general-purpose LLM implementing
one Macro proposal at a time; this package is that stage behind the
``MicroCoder`` protocol.  ``make_coder`` maps the spec strings accepted
by ``OptimizeConfig.coder`` to configured ``LLMMicroCoder`` instances —
the only constructor the rest of the repo uses (``tools/repolint.py``
forbids importing concrete backend classes outside this package):

  "llm" / "llm-template"   deterministic strict template backend —
                           registry-faithful, fingerprint-identical to
                           the structured coder on the closed rule space;
  "llm-adapt"              template backend that repairs illegal tiling
                           requests after analyzer feedback (the
                           open-space path);
  "llm-replay:DIR"         serve recorded transcripts from DIR — the
                           hermetic CI backend.

Pass ``record=DIR`` to capture any backend's exchanges as replay
fixtures (how ``benchmarks/table11_coder.py --record`` produces
``tests/fixtures/llm_transcripts/``).
"""
from __future__ import annotations

from repro.llmcoder.backend import (BackendError, CoderBackend,
                                    CoderRequest, RecordingBackend,
                                    ReplayBackend, TemplateBackend)
from repro.llmcoder.loop import LLMMicroCoder, LoopConfig
from repro.llmcoder.prompts import (ResponseParseError, build_prompt,
                                    parse_response)
from repro.llmcoder.transcript import (TranscriptStore, make_record,
                                       transcript_key)

__all__ = [
    "BackendError", "CoderBackend", "CoderRequest", "LLMMicroCoder",
    "LoopConfig", "RecordingBackend", "ReplayBackend",
    "ResponseParseError", "TemplateBackend", "TranscriptStore",
    "build_prompt", "make_coder", "make_record", "parse_response",
    "transcript_key",
]


def make_coder(spec: str, *, record: str | None = None,
               loop: LoopConfig | None = None) -> LLMMicroCoder:
    """Build an ``LLMMicroCoder`` from an ``OptimizeConfig.coder`` spec
    string (see module docstring for the vocabulary)."""
    if spec in ("llm", "llm-template"):
        backend: CoderBackend = TemplateBackend()
    elif spec == "llm-adapt":
        backend = TemplateBackend(adapt=True)
    elif spec.startswith("llm-replay:"):
        path = spec.split(":", 1)[1]
        if not path:
            raise ValueError("llm-replay spec needs a directory: "
                             "'llm-replay:path/to/transcripts'")
        backend = ReplayBackend(path)
    else:
        raise ValueError(
            f"unknown coder spec {spec!r}: expected 'structured', 'llm', "
            f"'llm-template', 'llm-adapt' or 'llm-replay:DIR'")
    if record:
        backend = RecordingBackend(backend, record)
    return LLMMicroCoder(backend, loop)
