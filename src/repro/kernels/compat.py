"""jax/Pallas version compatibility.

The TPU compiler-params dataclass was renamed across jax releases
(``TPUCompilerParams`` -> ``CompilerParams``); resolve whichever this
environment ships so the kernels import everywhere.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) or \
    _pltpu.TPUCompilerParams
