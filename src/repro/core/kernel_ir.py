"""Typed kernel IR — the program representation MTMC optimizes.

A ``KernelProgram`` is an op graph (topological ``nodes``) partitioned into
``fusion_groups`` (each group = one fused TPU kernel), with a
``KernelSchedule`` per group.  This is the TPU-native analogue of the
paper's "kernel code": Macro Thinking proposes semantic actions over it,
Micro Coding rewrites it, the cost model prices it, and the evaluator
executes it with the jnp reference ops (correctness oracle).

Op vocabulary (covers the KernelBench/TritonBench-style task suites):
  matmul(a, b)            attrs: none
  bias(x, b) / add(x, y) / mul(x, y)
  relu(x) / gelu(x) / silu(x) / square(x)
  softmax(x)              last axis
  rmsnorm(x, scale)
  row_max(x) / row_sum(x) last axis, keepdims
  attention(q, k, v)      attrs: causal, window  (B,S,H,hd) layout
  qk_scores(q, k)         unfused attention scores (scaled, masked)
  av(probs, v)            unfused attention value matmul
  rwkv_chunk(r, k, v, w, u)
  ssm_chunk(x, dt, a, b, c)
  grouped_matmul(x, w)    (E,C,D)x(E,D,F)

The qk_scores -> softmax -> av triple is the canonical Fusion target:
merging the three rewrites them into a single ``attention`` node (the
flash kernel).  Partial fusion (qk_scores+softmax) is a legal
softmax-epilogue matmul.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections.abc import Mapping
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.schedule import KernelSchedule, default_schedule
from repro.models import layers

ELEMENTWISE = ("bias", "add", "mul", "relu", "gelu", "silu", "square")
REDUCTIONS = ("row_max", "row_sum", "softmax", "rmsnorm")


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    shape: tuple[int, ...]
    dtype: str = "float32"

    @property
    def bytes(self) -> int:
        return int(np.prod(self.shape)) * jnp.dtype(self.dtype).itemsize

    @property
    def elems(self) -> int:
        return int(np.prod(self.shape))


@dataclasses.dataclass(frozen=True)
class OpNode:
    name: str
    op: str
    inputs: tuple[str, ...]
    attrs: tuple[tuple[str, Any], ...] = ()

    def attr(self, key: str, default=None):
        return dict(self.attrs).get(key, default)


@dataclasses.dataclass(frozen=True)
class KernelProgram:
    name: str
    inputs: tuple[tuple[str, TensorSpec], ...]
    nodes: tuple[OpNode, ...]
    outputs: tuple[str, ...]
    fusion_groups: tuple[tuple[str, ...], ...]
    schedules: tuple[tuple[str, KernelSchedule], ...]   # group-root -> sched
    history: tuple[str, ...] = ()

    # ---- convenience ----------------------------------------------------
    @property
    def input_specs(self) -> dict[str, TensorSpec]:
        return dict(self.inputs)

    @property
    def node_map(self) -> dict[str, OpNode]:
        return {n.name: n for n in self.nodes}

    @property
    def schedule_map(self) -> dict[str, KernelSchedule]:
        return dict(self.schedules)

    def group_of(self, node_name: str) -> tuple[str, ...]:
        for g in self.fusion_groups:
            if node_name in g:
                return g
        raise KeyError(node_name)

    def group_root(self, group: tuple[str, ...]) -> str:
        return group[0]

    def schedule_for(self, group: tuple[str, ...]) -> KernelSchedule:
        return self.schedule_map.get(self.group_root(group),
                                     KernelSchedule())

    def replace(self, **kw) -> KernelProgram:
        return dataclasses.replace(self, **kw)

    def with_schedule(self, group_root: str,
                      sched: KernelSchedule) -> KernelProgram:
        sm = self.schedule_map
        sm[group_root] = sched
        return self.replace(schedules=tuple(sorted(sm.items())))

    def fingerprint(self) -> str:
        # memoized on the (frozen, immutable) instance: hot path for the
        # evaluation engine's transposition store
        fp = self.__dict__.get("_fp")
        if fp is None:
            h = hashlib.sha1(repr((self.inputs, self.nodes, self.outputs,
                                   self.fusion_groups,
                                   self.schedules)).encode())
            fp = h.hexdigest()[:16]
            object.__setattr__(self, "_fp", fp)
        return fp

    def eval_fingerprint(self) -> str:
        """Fingerprint of the computation graph only — schedules and
        fusion grouping excluded.  ``evaluate`` is a pure function of
        exactly these fields, so two programs with equal
        eval-fingerprints produce identical outputs on identical inputs
        (schedule-only rewrites never change the math)."""
        fp = self.__dict__.get("_efp")
        if fp is None:
            h = hashlib.sha1(repr((self.inputs, self.nodes,
                                   self.outputs)).encode())
            fp = h.hexdigest()[:16]
            object.__setattr__(self, "_efp", fp)
        return fp

    # ---- shape inference -------------------------------------------------
    def shapes(self) -> dict[str, TensorSpec]:
        # memoized like fingerprint(): enumeration and pricing call this
        # per group per visit, and the program is immutable.  A shallow
        # copy is returned so a caller mutating its dict cannot corrupt
        # the cache (specs themselves are frozen).
        env = self.__dict__.get("_shapes")
        if env is None:
            env = dict(self.inputs)
            for n in self.nodes:
                env[n.name] = infer_shape(n, env)
            object.__setattr__(self, "_shapes", env)
        return dict(env)


def infer_shape(n: OpNode, env: Mapping[str, TensorSpec]) -> TensorSpec:
    a = env[n.inputs[0]]
    if n.op == "matmul":
        b = env[n.inputs[1]]
        return TensorSpec(a.shape[:-1] + (b.shape[-1],),
                          n.attr("out_dtype", a.dtype))
    if n.op == "grouped_matmul":
        b = env[n.inputs[1]]
        return TensorSpec((a.shape[0], a.shape[1], b.shape[-1]),
                          n.attr("out_dtype", a.dtype))
    if n.op in ("row_max", "row_sum"):
        return TensorSpec(a.shape[:-1] + (1,), a.dtype)
    if n.op == "attention":
        return a  # (B,S,H,hd) -> same
    if n.op == "qk_scores":
        b = env[n.inputs[1]]
        B, Sq, H, hd = a.shape
        return TensorSpec((B, H, Sq, b.shape[1]), a.dtype)
    if n.op == "av":
        v = env[n.inputs[1]]
        B, H, Sq, Sk = a.shape
        return TensorSpec((B, Sq, H, v.shape[-1]), a.dtype)
    if n.op == "rwkv_chunk":
        v = env[n.inputs[2]]
        return TensorSpec(v.shape, a.dtype)
    if n.op == "ssm_chunk":
        return a
    return a  # elementwise / softmax / rmsnorm / bias


# ---------------------------------------------------------------------------
# evaluator (correctness oracle; jnp reference semantics)
# ---------------------------------------------------------------------------

def make_inputs(prog: KernelProgram, key: jax.Array) -> dict[str, jax.Array]:
    out = {}
    for i, (name, spec) in enumerate(prog.inputs):
        k = jax.random.fold_in(key, i)
        if name.endswith("_decay"):       # rwkv w must be in (0,1)
            out[name] = jnp.exp(-jnp.exp(
                jax.random.normal(k, spec.shape))).astype(spec.dtype)
        elif name.endswith("_dt"):
            out[name] = jax.nn.softplus(
                jax.random.normal(k, spec.shape)).astype(spec.dtype)
        elif name.endswith("_A"):
            out[name] = -jnp.exp(
                jax.random.normal(k, spec.shape)).astype(spec.dtype)
        else:
            out[name] = jax.random.normal(k, spec.shape, spec.dtype)
    return out


def evaluate(prog: KernelProgram, inputs: Mapping[str, jax.Array]
             ) -> list[jax.Array]:
    env: dict[str, jax.Array] = dict(inputs)
    for n in prog.nodes:
        args = [env[i] for i in n.inputs]
        env[n.name] = _eval_op(n, args)
    return [env[o] for o in prog.outputs]


def _matmul_dtypes(n: OpNode):
    """(compute_dtype, out_dtype) attrs of a matmul-family node — set by
    the ``dtype`` rewrite rule (core/rules.py): compute in the reduced
    dtype with float32 accumulation, store the output in ``out_dtype``."""
    return n.attr("compute_dtype"), n.attr("out_dtype")


def _eval_op(n: OpNode, a: list[jax.Array]) -> jax.Array:
    op = n.op
    if op == "matmul":
        cd, od = _matmul_dtypes(n)
        x, w = a
        if cd:
            out = jnp.matmul(x.astype(cd), w.astype(cd),
                             preferred_element_type=jnp.float32)
        else:
            out = jnp.matmul(x, w)
        return out.astype(od) if od else out
    if op == "grouped_matmul":
        cd, od = _matmul_dtypes(n)
        x, w = a
        if cd:
            out = jnp.einsum("ecd,edf->ecf", x.astype(cd), w.astype(cd),
                             preferred_element_type=jnp.float32)
        else:
            out = jnp.einsum("ecd,edf->ecf", x, w)
        return out.astype(od) if od else out
    if op == "bias" or op == "add":
        # result keeps the first operand's dtype (a bf16 activation plus
        # an f32 bias stays bf16 — mixed only via the dtype rule; pure
        # f32 programs are unaffected)
        return (a[0] + a[1]).astype(a[0].dtype)
    if op == "mul":
        return (a[0] * a[1]).astype(a[0].dtype)
    if op == "relu":
        return jax.nn.relu(a[0])
    if op == "gelu":
        return jax.nn.gelu(a[0])
    if op == "silu":
        return jax.nn.silu(a[0])
    if op == "square":
        return jnp.square(a[0])
    if op == "softmax":
        return jax.nn.softmax(a[0], axis=-1)
    if op == "rmsnorm":
        return layers.rms_norm(a[0], a[1])
    if op == "row_max":
        return jnp.max(a[0], axis=-1, keepdims=True)
    if op == "row_sum":
        return jnp.sum(a[0], axis=-1, keepdims=True)
    if op == "attention":
        return layers.attention(a[0], a[1], a[2],
                                causal=bool(n.attr("causal", True)),
                                window=int(n.attr("window", 0)))
    if op == "qk_scores":
        q, k = a
        scale = q.shape[-1] ** -0.5
        s = jnp.einsum("bqhd,bkhd->bhqk", q * scale, k)
        if bool(n.attr("causal", True)):
            sq, sk = s.shape[-2], s.shape[-1]
            mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
            s = jnp.where(mask, s, -1e30)
        return s
    if op == "av":
        return jnp.einsum("bhqk,bkhd->bqhd", a[0], a[1])
    if op == "rwkv_chunk":
        o, _ = ref.rwkv6_chunked(a[0], a[1], a[2], a[3], a[4],
                                 chunk=min(32, a[0].shape[1]))
        return o
    if op == "ssm_chunk":
        y, _ = ref.ssm_chunked(a[0], a[1], a[2], a[3], a[4],
                               chunk=min(32, a[0].shape[1]))
        return y
    raise ValueError(f"unknown op {op}")


# ---------------------------------------------------------------------------
# NumPy oracle mirror (compile-free validation path)
# ---------------------------------------------------------------------------

def make_inputs_np(prog: KernelProgram, seed: int
                   ) -> dict[str, np.ndarray]:
    """NumPy mirror of ``make_inputs``: same per-name distributions
    (decay in (0,1), softplus dt, negative A), deterministic in
    (input specs, seed), no XLA dispatch.  The random STREAM differs
    from the threefry one — any fixed inputs are equally valid for the
    self-consistent task-vs-rewrite comparison the oracle performs."""
    out = {}
    for i, (name, spec) in enumerate(prog.inputs):
        rng = np.random.default_rng((seed, i))
        n = rng.standard_normal(spec.shape, dtype=np.float32)
        if name.endswith("_decay"):
            arr = np.exp(-np.exp(n))
        elif name.endswith("_dt"):
            arr = np.logaddexp(0.0, n)        # softplus
        elif name.endswith("_A"):
            arr = -np.exp(n)
        else:
            arr = n
        out[name] = arr.astype(spec.dtype)
    return out

def evaluate_np(prog: KernelProgram, inputs: Mapping[str, np.ndarray]
                ) -> list[np.ndarray]:
    """NumPy mirror of ``evaluate`` for the non-scan op vocabulary.

    Numerically float32-faithful to the jnp reference (same formulas,
    same masking constants, same GQA grouping) — differences are at
    rounding level, far below the 2e-3 validation tolerance.  Used by
    the evaluation engine's oracle so fresh-suite validation spends no
    time in XLA compilation.  Raises NotImplementedError for ops without
    a mirror (the chunked scans); callers fall back to ``evaluate``.
    """
    env: dict[str, np.ndarray] = {k: np.asarray(v) for k, v in
                                  inputs.items()}
    for n in prog.nodes:
        env[n.name] = _eval_op_np(n, [env[i] for i in n.inputs])
    return [env[o] for o in prog.outputs]


def _np_softmax(x: np.ndarray) -> np.ndarray:
    m = np.max(x, axis=-1, keepdims=True)
    e = np.exp(x - m)
    return e / np.sum(e, axis=-1, keepdims=True)


def _np_qk_scores(n: OpNode, q: np.ndarray, k: np.ndarray) -> np.ndarray:
    scale = np.float32(q.shape[-1] ** -0.5)
    s = np.einsum("bqhd,bkhd->bhqk", q * scale, k,
                  dtype=np.float32, optimize=True)
    if bool(n.attr("causal", True)):
        sq, sk = s.shape[-2], s.shape[-1]
        mask = np.arange(sq)[:, None] >= np.arange(sk)[None, :]
        s = np.where(mask, s, np.float32(-1e30))
    return s.astype(q.dtype)


def _np_attention(n: OpNode, q, k, v) -> np.ndarray:
    """Mirror of models.layers.attention (GQA, causal, window)."""
    scale = np.float32(q.shape[-1] ** -0.5)
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = (q * scale).reshape(b, sq, kv, g, hd)
    scores = np.einsum("bqkgh,bskh->bkgqs", qg, k,
                       dtype=np.float32, optimize=True)
    sk = k.shape[1]
    qpos = np.arange(sq)
    kpos = np.arange(sk)
    mask = np.ones((sq, sk), dtype=bool)
    if bool(n.attr("causal", True)):
        mask &= qpos[:, None] >= kpos[None, :]
    window = int(n.attr("window", 0))
    if window:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    scores = np.where(mask, scores, np.float32(-1e30))
    probs = _np_softmax(scores).astype(v.dtype)
    out = np.einsum("bkgqs,bskh->bqkgh", probs, v, optimize=True)
    return out.reshape(b, sq, kv * g, -1).astype(q.dtype)


def _np_dtype(name: str):
    """np dtype for an IR dtype string; bfloat16 needs ml_dtypes (ships
    with jax).  NotImplementedError -> caller falls back to the jitted
    jnp oracle, same as for the chunked scans."""
    if name == "bfloat16":
        try:
            import ml_dtypes
        except ImportError:  # pragma: no cover - ml_dtypes ships w/ jax
            raise NotImplementedError(
                "bfloat16 mirror needs ml_dtypes") from None
        return ml_dtypes.bfloat16
    return np.dtype(name)


def _np_matmul_cast(n: OpNode, x: np.ndarray, w: np.ndarray):
    """Mirror the dtype rule's reduced-precision compute: round the
    operands through the compute dtype, accumulate in float32."""
    cd, od = _matmul_dtypes(n)
    if cd:
        t = _np_dtype(cd)
        x = x.astype(t).astype(np.float32)
        w = w.astype(t).astype(np.float32)
    return x, w, od


def _eval_op_np(n: OpNode, a: list[np.ndarray]) -> np.ndarray:
    op = n.op
    if op == "matmul":
        x, w, od = _np_matmul_cast(n, a[0], a[1])
        out = np.matmul(x, w)
        return out.astype(_np_dtype(od)) if od else out
    if op == "grouped_matmul":
        x, w, od = _np_matmul_cast(n, a[0], a[1])
        out = np.einsum("ecd,edf->ecf", x, w, optimize=True)
        return out.astype(_np_dtype(od)) if od else out
    if op in ("bias", "add"):
        return (a[0].astype(np.float32)
                + a[1].astype(np.float32)).astype(a[0].dtype)
    if op == "mul":
        return (a[0].astype(np.float32)
                * a[1].astype(np.float32)).astype(a[0].dtype)
    if op == "relu":
        return np.maximum(a[0], 0)
    if op == "gelu":       # jax.nn.gelu(approximate=True)
        x = a[0].astype(np.float32)
        y = 0.5 * x * (1.0 + np.tanh(np.sqrt(2.0 / np.pi)
                                     * (x + 0.044715 * x ** 3)))
        return y.astype(a[0].dtype)
    if op == "silu":
        x = a[0]
        with np.errstate(over="ignore"):   # exp(|x|) -> inf is exact here
            return x / (1.0 + np.exp(-x.astype(np.float32))).astype(x.dtype)
    if op == "square":
        return np.square(a[0])
    if op == "softmax":
        return _np_softmax(a[0].astype(np.float32)).astype(a[0].dtype)
    if op == "rmsnorm":    # mirror of models.layers.rms_norm
        x = a[0].astype(np.float32)
        var = np.mean(np.square(x), axis=-1, keepdims=True)
        y = x / np.sqrt(var + 1e-6) * a[1].astype(np.float32)
        return y.astype(a[0].dtype)
    if op == "row_max":
        return np.max(a[0], axis=-1, keepdims=True)
    if op == "row_sum":
        return np.sum(a[0], axis=-1, keepdims=True)
    if op == "attention":
        return _np_attention(n, a[0], a[1], a[2])
    if op == "qk_scores":
        return _np_qk_scores(n, a[0], a[1])
    if op == "av":
        return np.einsum("bhqk,bkhd->bqhd", a[0], a[1],
                         optimize=True)
    raise NotImplementedError(f"no numpy mirror for op {op}")


# ---------------------------------------------------------------------------
# serialization (measurement DB winner records, offline artifacts)
# ---------------------------------------------------------------------------

def program_to_json(prog: KernelProgram) -> dict:
    """JSON-safe dict; ``program_from_json`` round-trips it to a program
    with an IDENTICAL fingerprint (tuple/int/bool structure is restored
    exactly — the fingerprint hashes ``repr`` of these fields).

    Attr values must be JSON scalars: a tuple-valued attr would come
    back as a list and silently change the fingerprint, so it is
    refused loudly here instead (extend both functions together if an
    op ever needs a structured attr)."""
    for n in prog.nodes:
        for k, v in n.attrs:
            if not isinstance(v, (str, int, float, bool, type(None))):
                raise TypeError(
                    f"attr {k}={v!r} on node {n.name!r} is not a JSON "
                    "scalar; round-trip would not preserve the "
                    "fingerprint")
    return {
        "name": prog.name,
        "inputs": [[n, {"shape": list(s.shape), "dtype": s.dtype}]
                   for n, s in prog.inputs],
        "nodes": [{"name": n.name, "op": n.op, "inputs": list(n.inputs),
                   "attrs": [[k, v] for k, v in n.attrs]}
                  for n in prog.nodes],
        "outputs": list(prog.outputs),
        "fusion_groups": [list(g) for g in prog.fusion_groups],
        "schedules": [[root, {"blocks": [[k, int(v)] for k, v in s.blocks],
                              "loop_order": list(s.loop_order),
                              "pipeline_depth": int(s.pipeline_depth),
                              "epilogue": s.epilogue,
                              "flags": list(s.flags)}]
                      for root, s in prog.schedules],
        "history": list(prog.history),
    }


def program_from_json(d: dict) -> KernelProgram:
    return KernelProgram(
        name=d["name"],
        inputs=tuple((n, TensorSpec(tuple(int(x) for x in s["shape"]),
                                    s["dtype"]))
                     for n, s in d["inputs"]),
        nodes=tuple(OpNode(n["name"], n["op"], tuple(n["inputs"]),
                           tuple((k, v) for k, v in n["attrs"]))
                    for n in d["nodes"]),
        outputs=tuple(d["outputs"]),
        fusion_groups=tuple(tuple(g) for g in d["fusion_groups"]),
        schedules=tuple(
            (root, KernelSchedule(
                blocks=tuple((k, int(v)) for k, v in s["blocks"]),
                loop_order=tuple(s["loop_order"]),
                pipeline_depth=int(s["pipeline_depth"]),
                epilogue=s["epilogue"], flags=tuple(s["flags"])))
            for root, s in d["schedules"]),
        history=tuple(d["history"]))


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def chain_program(name: str, inputs: dict[str, tuple[int, ...]],
                  ops: list[tuple[str, str, tuple[str, ...]]],
                  outputs: tuple[str, ...] | None = None,
                  dtype: str = "float32") -> KernelProgram:
    """Each op: (node_name, op, input_names).  Unfused by default."""
    nodes = tuple(OpNode(nm, op, ins) for nm, op, ins in ops)
    outs = outputs or (nodes[-1].name,)
    groups = tuple((n.name,) for n in nodes)
    scheds = tuple((n.name, default_schedule(sched_kind(n.op)))
                   for n in nodes)
    return KernelProgram(
        name=name,
        inputs=tuple((k, TensorSpec(v, dtype)) for k, v in inputs.items()),
        nodes=nodes, outputs=outs, fusion_groups=groups, schedules=scheds)


def sched_kind(op: str) -> str:
    """Kernel-library schedule family implementing ``op`` (public API —
    the rewrite-rule registry, micro-coding and the measure harness all
    key behavior on it)."""
    return {"matmul": "matmul", "attention": "flash_attention",
            "qk_scores": "matmul", "av": "matmul",
            "rmsnorm": "rmsnorm", "rwkv_chunk": "rwkv6_scan",
            "ssm_chunk": "ssm_scan",
            "grouped_matmul": "grouped_matmul"}.get(op, "elementwise")


def sched_kind_of_group(prog: KernelProgram,
                        group: tuple[str, ...]) -> str:
    """Schedule family of a fusion group: its first non-elementwise
    anchor's kind, else elementwise."""
    nm = prog.node_map
    for name in group:
        k = sched_kind(nm[name].op)
        if k != "elementwise":
            return k
    return "elementwise"
