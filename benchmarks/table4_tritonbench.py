"""Paper Table 4 — TritonBench-like (TB-T common ops / TB-G real-world):
call+execute accuracy and speedups, MTMC vs baselines."""
from __future__ import annotations

from .common import eval_mode, fmt_row
from repro.core import MacroPolicy
from repro.core import tasks as T


def run(policy) -> list[str]:
    rows = []
    for name, suite_fn in [("T", T.tb_t), ("G", T.tb_g)]:
        suite = suite_fn()
        for mode, p in [("ours", policy), ("untrained", MacroPolicy()),
                        ("random", None)]:
            m = eval_mode(suite, "policy" if mode == "ours" else
                          ("untrained" if mode == "untrained" else
                           "random"), p if mode != "random" else None)
            rows.append(fmt_row("table4", f"TB-{name}/{mode}", m))
    return rows
