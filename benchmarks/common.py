"""Shared benchmark plumbing: cached policy training + suite evaluation."""
from __future__ import annotations

import os
import pickle
import time

import jax
import numpy as np

from repro.core import (CollectConfig, EvalEngine, MacroPolicy,
                        PPOConfig, PPOTrainer, PolicyConfig,
                        TranspositionStore, collect_suite)
from repro.core import tasks as T

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
POLICY_PATH = os.path.join(RESULTS, "macro_policy.pkl")

# One transposition store for the whole benchmark process: every table,
# mode and ablation sweeps the same suites, so rewrites, cost pricing
# and oracle outputs are shared across all of them.
STORE = TranspositionStore()
WORKERS = max(2, (os.cpu_count() or 2))


def train_policy(iters: int = 24, episodes: int = 8, seed: int = 0,
                 pcfg: PolicyConfig = PolicyConfig()) -> MacroPolicy:
    trees = collect_suite(
        T.train_tasks(),
        CollectConfig(episodes_random=5, episodes_greedy=6, seed=seed),
        store=STORE)
    trainer = PPOTrainer(
        trees, pcfg=pcfg,
        cfg=PPOConfig(iters=iters, episodes_per_iter=episodes, seed=seed,
                      max_candidates=32, lr=1e-3, entropy_coef=0.02))
    policy = trainer.train()
    policy.train_log = trainer.log
    return policy


def cached_policy(retrain: bool = False, **kw) -> MacroPolicy:
    os.makedirs(RESULTS, exist_ok=True)
    if not retrain and os.path.exists(POLICY_PATH):
        with open(POLICY_PATH, "rb") as f:
            blob = pickle.load(f)
        pol = MacroPolicy(blob["cfg"], params=jax.tree.map(
            jax.numpy.asarray, blob["params"]))
        pol.train_log = blob.get("log", [])
        return pol
    pol = train_policy(**kw)
    with open(POLICY_PATH, "wb") as f:
        pickle.dump({"cfg": pol.cfg,
                     "params": jax.tree.map(np.asarray, pol.params),
                     "log": getattr(pol, "train_log", [])}, f)
    return pol


def eval_mode(suite, mode: str, policy=None, curated: bool = True,
              seed: int = 0, max_steps: int = 8,
              workers: int | None = None) -> dict:
    """Evaluate one (suite x mode) cell through the batched engine.

    Metrics match the serial ``evaluate_suite`` path (seed_stride=0:
    same per-task seeds; the store memoizes only pure functions) — see
    the golden regression in tests/test_engine.py and the oracle-input
    caveat in core/engine.py.
    """
    eng = EvalEngine(policy, store=STORE, mode=mode, curated=curated,
                     seed=seed, max_steps=max_steps,
                     workers=WORKERS if workers is None else workers)
    t0 = time.time()
    out = eng.evaluate_suite(suite)
    out["wall_s"] = time.time() - t0
    return out


def fmt_row(table: str, name: str, metrics: dict,
            target=None) -> str:
    """CSV: name,us_per_call,derived (spec format); ``target`` selects
    which chip the modeled times are priced against."""
    times = [1e6 * _prog_time(r.program, target)
             for r in metrics["results"]]
    return (f"{table}/{name},{np.mean(times):.1f},"
            f"acc={metrics['accuracy']:.2f};"
            f"fast1={metrics['fast1']:.2f};fast2={metrics['fast2']:.2f};"
            f"speedup={metrics['mean_speedup']:.2f}")


def _prog_time(prog, target=None) -> float:
    from repro.core import program_cost
    return program_cost(prog, target).total_s
