"""Mixture-of-Experts MLP (phi3.5-moe, dbrx).

Token-choice top-k routing with per-expert capacity (GShard-style, dropped
tokens fall through the residual), dispatched as a dense (E, C, D) gather +
grouped matmul — the TPU-native formulation: the grouped matmul maps onto
``kernels/grouped_matmul`` (MXU), and dispatch/combine are scatters that
GSPMD turns into all-to-all-ish collectives across the data axis.

Expert parallelism: the expert axis maps onto the "model" mesh axis
(16 experts / 16-way TP => 1 expert per shard).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.layers import normal_init, depth_scale


def moe_mlp_tree(cfg: ModelConfig, make, L: int, prefix: str = ""):
    D, FF, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    w = normal_init(0.02)
    wo_init = normal_init(depth_scale(0.02, L))
    p = prefix
    return {
        "router": make(p + "router", (L, D, E), ("layers", "embed", None),
                       w),
        "w_gate": make(p + "w_gate", (L, E, D, FF),
                       ("layers", "expert", "embed", "mlp"), w),
        "w_up": make(p + "w_up", (L, E, D, FF),
                     ("layers", "expert", "embed", "mlp"), w),
        "w_down": make(p + "w_down", (L, E, FF, D),
                       ("layers", "expert", "mlp", "embed"), wo_init),
    }


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    pad = 128 if n_tokens >= 128 else 8
    return max(pad, -(-c // pad) * pad)


def moe_mlp(cfg: ModelConfig, p: dict, h: jax.Array, rules=None):
    """h: (B,S,D) normed -> (delta (B,S,D), aux_loss scalar).

    GShard-style GROUP-LOCAL dispatch (§Perf H4): tokens are split into
    G = dp groups matching the data-axis sharding; routing, capacity and
    the dispatch gather/scatter all stay within a group, so no token
    crosses a data shard.  Group axis -> data mesh axes, expert axis ->
    model mesh axis (EP).  Without grouping, either the (E,C,D) dispatch
    buffers replicate across data shards (16x redundant expert compute —
    measured, EXPERIMENTS.md H4) or the gather all-to-alls every token.
    """
    B, S, D = h.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    G = rules.dp if rules is not None else 1
    while G > 1 and (T % G != 0 or (T // G) % 8 != 0):
        G //= 2
    Tg = T // G
    C = capacity(cfg, Tg)
    xt = h.reshape(G, Tg, D)

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                 # (G,Tg,E)
    gate, idx = jax.lax.top_k(probs, K)                     # (G,Tg,K)
    gate = gate / (jnp.sum(gate, -1, keepdims=True) + 1e-9)

    # position of each (token, k) slot within its group-local expert queue
    e_flat = idx.reshape(G, Tg * K)                         # token-major
    oh = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)         # (G,TgK,E)
    pos = (jnp.cumsum(oh, axis=1) * oh).max(-1) - 1         # (G,TgK)
    keep = pos < C
    dest_e = jnp.where(keep, e_flat, E)                     # E = drop row
    dest_p = jnp.where(keep, pos, 0)

    gi = jnp.arange(G)[:, None]
    tok_ids = jnp.broadcast_to(jnp.arange(Tg * K) // K, (G, Tg * K))
    # dispatch table: scatter of int32 token ids only (tiny)
    table = jnp.full((G, E + 1, C), Tg, jnp.int32) \
        .at[gi, dest_e, dest_p].set(tok_ids)[:, :E]         # (G,E,C)

    xpad = jnp.concatenate(
        [xt, jnp.zeros((G, 1, D), xt.dtype)], axis=1)       # (G,Tg+1,D)
    flat_idx = table.reshape(G, E * C)
    xg = jnp.take_along_axis(xpad, flat_idx[..., None],
                             axis=1).reshape(G, E, C, D)
    if rules is not None:
        xg = rules.constrain(xg, ("batch", "expert", None, None))
    g = ops.grouped_matmul(xg, p["w_gate"])
    u = ops.grouped_matmul(xg, p["w_up"])
    hact = jax.nn.silu(g) * u                               # (G,E,C,FF)
    y = ops.grouped_matmul(hact, p["w_down"])               # (G,E,C,D)
    if rules is not None:
        y = rules.constrain(y, ("batch", "expert", None, None))

    # combine by SLOT GATHER (no scatter-add: each (token, k) gathers
    # its slot's output; dropped slots hit the zero pad row — GSPMD
    # lowers gathers far better than big scatter-adds, §Perf H4.3)
    slot_of = jnp.where(keep, dest_e * C + dest_p, E * C)   # (G,TgK)
    y_pad = jnp.concatenate(
        [y.reshape(G, E * C, D),
         jnp.zeros((G, 1, D), y.dtype)], axis=1)
    picked = jnp.take_along_axis(y_pad, slot_of[..., None], axis=1)
    out = jnp.sum(picked.reshape(G, Tg, K, D)
                  * gate[..., None].astype(y.dtype), axis=2)

    # Switch-style load-balancing aux loss (global)
    me = jnp.mean(probs, axis=(0, 1))                       # (E,)
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0], E,
                                 dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    return out.reshape(B, S, D), aux.astype(jnp.float32)
