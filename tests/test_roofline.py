"""HLO-text analyzer unit tests: loop multipliers, dot flops, collective
bytes — verified against tiny programs with known ground truth.

(These run on the default single-device CPU backend; collective tests
build tiny meshes only if >1 device is available, otherwise they verify
the text-parsing layer on canned HLO snippets.)
"""
import jax
import jax.numpy as jnp

from repro.roofline import hlo_parse as H


def _compiled_text(f, *specs):
    return jax.jit(f).lower(*specs).compile().as_text()


def test_scan_flops_multiplied():
    """XLA cost_analysis counts a scanned body once; ours multiplies by
    the trip count."""
    n, L = 128, 10

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=L)
        return y

    s = jax.ShapeDtypeStruct((n, n), jnp.float32)
    txt = _compiled_text(f, s, s)
    fb = H.hlo_flops_bytes(txt)
    expect = 2.0 * n ** 3 * L
    assert abs(fb["dot_flops"] - expect) / expect < 0.05, \
        (fb["dot_flops"], expect)


def test_nested_scan_multipliers():
    n, L1, L2 = 64, 3, 5

    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=L2)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=L1)
        return y

    s = jax.ShapeDtypeStruct((n, n), jnp.float32)
    txt = _compiled_text(f, s, s)
    fb = H.hlo_flops_bytes(txt)
    expect = 2.0 * n ** 3 * L1 * L2
    assert abs(fb["dot_flops"] - expect) / expect < 0.05


def test_plain_matmul_flops_and_bytes():
    m, k, n = 256, 512, 128

    def f(a, b):
        return a @ b

    txt = _compiled_text(f, jax.ShapeDtypeStruct((m, k), jnp.float32),
                         jax.ShapeDtypeStruct((k, n), jnp.float32))
    fb = H.hlo_flops_bytes(txt)
    assert abs(fb["dot_flops"] - 2 * m * k * n) / (2 * m * k * n) < 0.01
    io = 4 * (m * k + k * n + m * n)
    assert fb["bytes"] >= io * 0.9
    assert fb["bytes"] <= io * 3          # upper bound, not unbounded


def test_type_bytes_tuples():
    assert H._type_bytes("f32[128,512]{1,0}") == 128 * 512 * 4
    assert H._type_bytes("bf16[16]") == 32
    assert H._type_bytes("(f32[4,4]{1,0}, bf16[8]{0})") == 64 + 16
    assert H._type_bytes("pred[2,3]") == 6


def test_group_size_parsing():
    line = ("%ar = f32[8]{0} all-reduce(%x), replica_groups=[2,4]<=[8],"
            " to_apply=%add")
    assert H._group_size(line) == 4
    line2 = "%ag = f32[8]{0} all-gather(%x), replica_groups={{0,1,2,3}}"
    assert H._group_size(line2) == 4


def test_collective_parsing_canned():
    txt = """HloModule m

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (x: f32[128,512]) -> f32[128,512] {
  %x = f32[128,512]{1,0} parameter(0)
  %ar = f32[128,512]{1,0} all-reduce(%x), replica_groups=[2,8]<=[16], to_apply=%add
  ROOT %cp = f32[128,512]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    cb = H.collective_bytes(txt)
    sz = 128 * 512 * 4
    assert cb["all-reduce"] == sz
    assert cb["collective-permute"] == sz
    assert cb["total"] == 2 * sz


def test_collective_in_loop_multiplied():
    txt = """HloModule m

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%cond (t: (s32[], f32[64])) -> pred[] {
  %t = (s32[], f32[64]{0}) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (t: (s32[], f32[64])) -> (s32[], f32[64]) {
  %t = (s32[], f32[64]{0}) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %x = f32[64]{0} get-tuple-element(%t), index=1
  %ar = f32[64]{0} all-reduce(%x), replica_groups=[1,4]<=[4], to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %out = (s32[], f32[64]{0}) tuple(%ni, %ar)
}

ENTRY %main (x: f32[64]) -> (s32[], f32[64]) {
  %x = f32[64]{0} parameter(0)
  %zero = s32[] constant(0)
  %t = (s32[], f32[64]{0}) tuple(%zero, %x)
  ROOT %w = (s32[], f32[64]{0}) while(%t), condition=%cond, body=%body
}
"""
    cb = H.collective_bytes(txt)
    assert cb["all-reduce"] == 64 * 4 * 12       # x trip count


def test_parse_def_tuple_types():
    d = H.parse_def("  %w = (f32[8]{0}, bf16[4]{0}) while(%t), "
                    "condition=%c, body=%b")
    assert d is not None
    name, tstr, op, operands, attrs = d
    assert op == "while" and name == "w"
    assert H._type_bytes(tstr) == 32 + 8
    assert "condition=%c" in attrs
