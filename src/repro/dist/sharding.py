"""Logical-axis sharding rules with a divisibility-or-replicate policy.

``ShardingRules`` maps LOGICAL axis names (the ones ``param_tree`` /
``cache_tree`` / the forward passes annotate: "batch", "embed", "heads",
"kv_heads", "mlp", "vocab", "expert", "kv_seq", "seq", ...) to MESH axis
names ("pod", "data", "model").  A spec is produced per-tensor, and two
safety policies are applied at that point:

  * divisibility-or-replicate — a dimension is only sharded over the
    longest prefix of its mesh axes whose size product divides it; an
    unshardable dim silently replicates (normalize_for_mesh pads heads /
    vocab so the hot tensors stay shardable; everything else degrades
    gracefully — e.g. hymba's 25 q-heads on tp=16 replicate);
  * first-come-wins — within one spec a mesh axis is used at most once
    (expert and mlp both map to "model": whichever dim comes first gets
    it, the later one replicates), since a PartitionSpec naming the same
    mesh axis twice is illegal.

The default rule set is mesh-aware: "batch" takes every pod/data axis the
mesh actually has, tensor-parallel logical axes take "model" when present.
``with_fsdp`` additionally shards "embed" over "data" (the FSDP weight
split); ``replace`` overrides individual rules (e.g. decode's
``kv_seq=("data", "model")`` flash-decode split); ``with_flags`` attaches
free-form feature toggles ("bf16_reduce") read by the model code.
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# logical axes that shard over the tensor-parallel ("model") mesh axis
_TP_AXES = ("heads", "kv_heads", "mlp", "vocab", "expert")
# logical axes that default to replicated
_REPLICATED = ("embed", "kv_seq", "seq", "layers")


def _default_rules(mesh: Mesh) -> dict[str, tuple[str, ...]]:
    names = mesh.axis_names
    data = tuple(a for a in ("pod", "data") if a in names)
    model = ("model",) if "model" in names else ()
    rules: dict[str, tuple[str, ...]] = {"batch": data}
    for ax in _TP_AXES:
        rules[ax] = model
    for ax in _REPLICATED:
        rules[ax] = ()
    return rules


class ShardingRules:
    """Immutable logical-axis -> mesh-axes mapping bound to one mesh."""

    def __init__(self, mesh: Mesh,
                 rules: dict[str, tuple[str, ...]] | None = None,
                 flags: frozenset[str] = frozenset()):
        self.mesh = mesh
        self.rules = dict(_default_rules(mesh) if rules is None else rules)
        self.flags = frozenset(flags)

    # -- derived parallel degrees -----------------------------------------
    @property
    def tp(self) -> int:
        """Tensor-parallel degree (size of the "model" mesh axis)."""
        if "model" in self.mesh.axis_names:
            return int(self.mesh.shape["model"])
        return 1

    @property
    def dp(self) -> int:
        """Data-parallel degree (product of the batch rule's axes)."""
        return int(np.prod([self.mesh.shape[a]
                            for a in self.rules.get("batch", ())] or [1]))

    # -- functional updates ------------------------------------------------
    def replace(self, **kw) -> ShardingRules:
        """Override individual logical-axis rules (values: mesh-axis
        tuples), e.g. ``rules.replace(kv_seq=("data", "model"))``."""
        new = dict(self.rules)
        for k, v in kw.items():
            new[k] = tuple(v)
        return ShardingRules(self.mesh, new, self.flags)

    def with_fsdp(self) -> ShardingRules:
        """Shard the embed (weight-column) axis over data: FSDP."""
        return self.replace(embed=("data",) if "data" in
                            self.mesh.axis_names else ())

    def with_flags(self, *flags: str) -> ShardingRules:
        return ShardingRules(self.mesh, self.rules,
                             self.flags | set(flags))

    # -- spec construction -------------------------------------------------
    def spec(self, shape: tuple[int, ...],
             axes: tuple[str | None, ...]) -> PartitionSpec:
        """PartitionSpec for ``shape`` under the logical ``axes`` names.

        Applies divisibility-or-replicate per dim and first-come-wins
        de-duplication of mesh axes across dims.
        """
        used: set[str] = set()
        entries = []
        for dim, ax in zip(shape, axes):
            if ax is None:
                entries.append(None)
                continue
            mesh_axes = tuple(a for a in self.rules.get(ax, ())
                              if a not in used)
            chosen: tuple[str, ...] = ()
            prod = 1
            for a in mesh_axes:
                size = int(self.mesh.shape[a])
                if dim % (prod * size) != 0:
                    break
                prod *= size
                chosen += (a,)
            if not chosen:
                entries.append(None)
            elif len(chosen) == 1:
                entries.append(chosen[0])
            else:
                entries.append(chosen)
            used.update(chosen)
        return PartitionSpec(*entries)

    def sharding(self, shape: tuple[int, ...],
                 axes: tuple[str | None, ...]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(shape, axes))

    def constrain(self, x: jax.Array,
                  axes: tuple[str | None, ...]) -> jax.Array:
        """with_sharding_constraint under this mesh (jit-traceable)."""
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(x.shape, axes)))

    def __repr__(self) -> str:
        return (f"ShardingRules(mesh={dict(self.mesh.shape)}, "
                f"rules={self.rules}, flags={sorted(self.flags)})")
