"""Three-term roofline from a compiled dry-run artifact.

  compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
  memory term     = HLO_bytes / (chips x 819 GB/s)
  collective term = collective_bytes / (chips x 50 GB/s/link)

``compiled.cost_analysis()`` on the partitioned module reports PER-DEVICE
flops/bytes, so chips-worth of totals are per_device x chips and the
division by chips cancels — we compute terms directly from per-device
numbers (documented in EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import dataclasses

from repro.core.hardware import resolve as _resolve_target
from repro.roofline.hlo_parse import collective_bytes

# chip constants come from the hardware-target registry (core/hardware),
# shared with the kernel-level cost model so whole-step and per-kernel
# rooflines can never disagree about the chip
PEAK_FLOPS = _resolve_target(None).matmul_flops("bf16")   # bf16 / chip
HBM_BW = _resolve_target(None).hbm_bw                     # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_per_device: dict
    model_flops: float                 # 6*N*D (or 6*N_active*D)
    peak_mem_bytes: float              # per-device (args+out+temp)
    attn_bytes: float = 0.0            # score-region bytes (XLA fallback)
    attn_io_bytes: float = 0.0         # q/k/v/o traffic of that region

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def memory_s_kernelized(self) -> float:
        """Memory term with the Pallas flash-attention kernel substituted
        for the XLA score materialization: the S x S intermediates stay
        in VMEM; the kernel still streams the region's q/k/v/o traffic
        (attn_io_bytes counts every pass's rank-4 reads incl. the
        per-q-block KV re-reads, so it directly models the kernel)."""
        b = (self.bytes_per_device - self.attn_bytes
             + self.attn_io_bytes)
        return b / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_per_device.get("total", 0.0) / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO flops (remat/padding/dispatch waste)."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chips' peak the step achieves, counting only
        model flops as useful: (model_flops/chips/peak) / step_time."""
        if self.step_s == 0:
            return 0.0
        ideal = self.model_flops / self.chips / PEAK_FLOPS
        return ideal / self.step_s

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "memory_s_kernelized": self.memory_s_kernelized,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops_per_dev": self.flops_per_device,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_mem_gb": self.peak_mem_bytes / 2**30,
            "collectives": self.collective_per_device,
        }


def model_flops_for(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D for training, 2*N*D for inference (per spec:
    6*N*D dense / 6*N_active*D MoE), D = tokens processed this step."""
    n = cfg.n_params(active_only=(cfg.family == "moe"))
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch * 1          # decode: one token
    return 2.0 * n * tokens


def analyze(compiled, *, arch: str, shape, mesh_name: str, chips: int,
            cfg, kind: str) -> Roofline:
    # NOTE: compiled.cost_analysis() counts while (scan) bodies once, so
    # flops/bytes come from our HLO-text analyzer with loop multipliers
    # (hlo_parse.hlo_flops_bytes); verified against 6ND (EXPERIMENTS.md).
    from repro.roofline.hlo_parse import hlo_flops_bytes
    txt = compiled.as_text()
    fb = hlo_flops_bytes(txt)
    colls = collective_bytes(txt)
    mem = compiled.memory_analysis()
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes +
            mem.temp_size_in_bytes)
    return Roofline(arch, shape.name, mesh_name, chips, fb["flops"],
                    fb["bytes"], colls,
                    model_flops_for(cfg, shape, kind), peak,
                    attn_bytes=fb.get("attn_bytes", 0.0),
                    attn_io_bytes=fb.get("attn_io_bytes", 0.0))
