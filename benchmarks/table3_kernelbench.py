"""Paper Table 3 — KernelBench-like: accuracy / fast_p / mean speedup by
level, MTMC (trained policy) vs baselines (untrained-LM proxy for
general-purpose LLMs, random policy)."""
from __future__ import annotations

from .common import eval_mode, fmt_row
from repro.core import tasks as T


def run(policy) -> list[str]:
    rows = []
    for level, suite_fn in [("L1", T.kb_level1), ("L2", T.kb_level2),
                            ("L3", T.kb_level3)]:
        suite = suite_fn()
        for mode, pol in [("ours", policy), ("untrained", None),
                          ("random", None)]:
            from repro.core import MacroPolicy
            p = pol if mode == "ours" else (
                MacroPolicy() if mode == "untrained" else None)
            m = eval_mode(suite, "policy" if mode == "ours" else
                          ("untrained" if mode == "untrained" else
                           "random"), p)
            rows.append(fmt_row("table3", f"{level}/{mode}", m))
    return rows
