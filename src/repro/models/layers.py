"""Shared neural-net building blocks (pure JAX, no flax).

Parameter trees are declared once via a ``make(name, shape, axes, init)``
callback; three makers derive real params, abstract ShapeDtypeStructs and
PartitionSpecs from the same declaration (see ``makers.py``).

All sequence layers are written to be scanned over the layer axis: their
parameter trees carry a leading ``layers`` dimension added by the model
builders, and forwards take per-layer slices.
"""
from __future__ import annotations

from collections.abc import Callable

import jax
import jax.numpy as jnp
import numpy as np

Maker = Callable[..., jax.Array]

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def normal_init(scale: float = 0.02):
    def init(key, shape, dtype):
        return (scale * jax.random.normal(key, shape)).astype(dtype)
    return init


def zeros_init():
    return lambda key, shape, dtype: jnp.zeros(shape, dtype)


def ones_init():
    return lambda key, shape, dtype: jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n, head_dim); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (reference / XLA path; Pallas path lives in repro.kernels)
# ---------------------------------------------------------------------------

def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B,Sq,H,hd)  k: (B,Sk,KV,hd)  -> scores (B,KV,G,Sq,Sk)."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    return jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                      preferred_element_type=jnp.float32)


def _gqa_out(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs: (B,KV,G,Sq,Sk)  v: (B,Sk,KV,hd) -> (B,Sq,H,hd)."""
    b, kv, g, sq, sk = probs.shape
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(b, sq, kv * g, -1)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int = 0,
              q_offset: int | jax.Array = 0,
              chunk: int = 0, kv_mask: jax.Array | None = None) -> jax.Array:
    """Masked multi-head attention with GQA grouping.

    window > 0 => sliding-window mask (local attention).
    chunk > 0  => online-softmax over query chunks (memory-bounded: used
    for long prefill and as the XLA-level 'flash' fallback of the Pallas
    kernel).  q_offset is the absolute position of q[0] (decode/prefill).
    kv_mask (B,Sk) bool marks which key/value positions are valid: pad
    positions of a left-padded mixed-length batch are masked out so
    shorter rows never attend to their padding.
    """
    if chunk and q.shape[1] > chunk and q.shape[1] % chunk == 0:
        return _chunked_attention(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset, chunk=chunk,
                                  kv_mask=kv_mask)
    scale = q.shape[-1] ** -0.5
    scores = _gqa_scores(q * scale, k)                  # (B,KV,G,Sq,Sk) f32
    sq, sk = scores.shape[-2], scores.shape[-1]
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    mask = _apply_window(mask, qpos, kpos, window)
    if kv_mask is not None:
        # (B,Sk) -> (B,1,1,Sq,Sk) against the (Sq,Sk) structural mask
        mask = mask[None, None, None] & \
            kv_mask[:, None, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return _gqa_out(probs, v)


def _apply_window(mask, qpos, kpos, window):
    """Sliding-window mask; ``window`` may be a traced scalar (scanned
    per-layer windows, hymba) where 0 means global attention."""
    if isinstance(window, int):
        if window == 0:
            return mask
        return mask & (kpos[None, :] > (qpos[:, None] - window))
    w = jnp.asarray(window)
    wm = (kpos[None, :] > (qpos[:, None] - w)) | (w == 0)
    return mask & wm


def _chunked_attention(q, k, v, *, causal, window, q_offset, chunk,
                       kv_mask=None):
    b, sq, h, hd = q.shape
    nc = sq // chunk
    qc = q.reshape(b, nc, chunk, h, hd).transpose(1, 0, 2, 3, 4)

    def body(carry, q_i):
        i, = carry
        off = q_offset + i * chunk
        o = attention(q_i, k, v, causal=causal, window=window,
                      q_offset=off, chunk=0, kv_mask=kv_mask)
        return (i + 1,), o

    _, out = jax.lax.scan(body, (jnp.int32(0),), qc)
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window: int = 0,
                     start: jax.Array | None = None) -> jax.Array:
    """Single-step attention against a KV cache.

    q: (B,1,H,hd); caches: (B,S,KV,hd); pos: index of the new token —
    a scalar shared by the whole batch, or a (B,) vector of per-row
    positions (continuous batching: every slot decodes at its own
    depth).  start (scalar or (B,)) masks cache positions below it
    (left-padded prefills park garbage K/V there); freed/idle slots are
    likewise fenced by their own pos, since rows never read each
    other's cache lines.
    """
    scale = q.shape[-1] ** -0.5
    scores = _gqa_scores(q * scale, k_cache)            # (B,KV,G,1,S)
    s = k_cache.shape[1]
    kpos = jnp.arange(s)
    p = jnp.reshape(pos, (-1, 1))                       # (1,1) or (B,1)
    mask = kpos <= p                                    # (1|B, S)
    if start is not None:
        mask &= kpos >= jnp.reshape(start, (-1, 1))
    if isinstance(window, int):
        if window:
            mask &= kpos > (p - window)
    else:
        w = jnp.asarray(window)
        mask &= (kpos > (p - w)) | (w == 0)
    scores = jnp.where(mask[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    return _gqa_out(probs, v_cache)


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------

def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(x.dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u,
                      w_down.astype(x.dtype))


def linear(x: jax.Array, w: jax.Array, b: jax.Array | None = None):
    y = jnp.einsum("...d,dk->...k", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, targets: jax.Array,
                 true_vocab: int) -> jax.Array:
    """Mean cross-entropy; padded vocab columns masked out.

    logits: (B,S,Vp) (possibly TP-padded), targets: (B,S) int32.
    """
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vp != true_vocab:
        col = jnp.arange(vp)
        logits = jnp.where(col < true_vocab, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, targets[..., None],
                                 axis=-1)[..., 0]
    return jnp.mean(lse - picked)


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------

def fold_key(key: jax.Array, name: str) -> jax.Array:
    return jax.random.fold_in(key, abs(hash(name)) % (2 ** 31))


def depth_scale(base: float, n_layers: int) -> float:
    return base / np.sqrt(2 * max(n_layers, 1))

