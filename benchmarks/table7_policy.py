"""Paper Table 7 — Macro Thinking ablation grid:
  w/ policy + AS      : trained policy (x2 backbone sizes)
  w/o policy + AS     : random / untrained-LM over the curated space
  w/o policy + w/o AS : untrained-LM over unrestricted proposals
on a 10%-style subset of the benchmark tasks (paper's protocol).

Plus the budget-matched search grid (DESIGN.md §14): beam search vs
``PolicySearch`` (the trained policy pruning the frontier) on the same
subset at equal depth over the extended action space.  The row reports
the geomean speedup of each and the two ratios ``check_regression.py``
gates: ``policy_expansion_ratio`` (policy node expansions / beam's —
lower is better, must stay <= 0.5) and ``policy_speedup_ratio``
(policy geomean / beam geomean — must stay >= 1.0): the trained policy
must match beam's solution quality at a fraction of its search budget.
"""
from __future__ import annotations

import numpy as np

from .common import STORE, eval_mode, fmt_row
from repro.core import MacroPolicy
from repro.core import tasks as T
from repro.core.micro_coding import StructuredMicroCoder
from repro.core.search import get_strategy

# budget-grid gates, asserted here AND regression-gated on the CSV
MAX_EXPANSION_RATIO = 0.5
MIN_SPEEDUP_RATIO = 1.0


def _subset():
    return [T.kb_level1()[0], T.kb_level1()[5], T.kb_level2()[0],
            T.kb_level2()[3], T.kb_level3()[0]]


def _geomean(xs) -> float:
    return float(np.exp(np.mean(np.log(np.asarray(xs, np.float64)))))


def budget_grid(policy) -> dict:
    """Beam vs policy-guided search, budget-matched: same tasks, same
    store, same depth (8), same extended action space — only the
    expansion rule differs."""
    suite = _subset()
    coder = StructuredMicroCoder()
    out = {}
    for sname in ("beam", "policy"):
        strat = get_strategy(sname)
        n_exp, speedups, n_ok = 0, [], 0
        for t in suite:
            r = strat.search(t, coder=coder, store=STORE, max_steps=8,
                             seed=0, curated=True, extended=True,
                             policy=policy)
            n_exp += r.n_expanded
            speedups.append(r.baseline_s / r.cost_s)
            n_ok += int(STORE.check(t, r.program))
        out[sname] = {"expanded": n_exp,
                      "geomean_speedup": _geomean(speedups),
                      "accuracy": n_ok / len(suite)}
    out["expansion_ratio"] = (out["policy"]["expanded"]
                              / max(out["beam"]["expanded"], 1))
    out["speedup_ratio"] = (out["policy"]["geomean_speedup"]
                            / out["beam"]["geomean_speedup"])
    return out


def _budget_rows(policy) -> list[str]:
    g = budget_grid(policy)
    assert g["expansion_ratio"] <= MAX_EXPANSION_RATIO, (
        f"policy search expanded {g['policy']['expanded']} nodes vs "
        f"beam's {g['beam']['expanded']} "
        f"(ratio {g['expansion_ratio']:.2f} > {MAX_EXPANSION_RATIO})")
    assert g["speedup_ratio"] >= MIN_SPEEDUP_RATIO - 1e-9, (
        f"policy search geomean speedup "
        f"{g['policy']['geomean_speedup']:.3f} below beam's "
        f"{g['beam']['geomean_speedup']:.3f}")
    rows = []
    for sname in ("beam", "policy"):
        s = g[sname]
        rows.append(
            f"table7/budget/{sname},{s['expanded']:.1f},"
            f"acc={s['accuracy']:.2f};"
            f"geomean_speedup={s['geomean_speedup']:.3f}")
    rows.append(
        f"table7/budget/ratio,{g['policy']['expanded']:.1f},"
        f"acc={g['policy']['accuracy']:.2f};"
        f"policy_expansion_ratio={g['expansion_ratio']:.3f};"
        f"policy_speedup_ratio={g['speedup_ratio']:.3f}")
    return rows


def run(policy, small_policy=None) -> list[str]:
    suite = _subset()
    rows = []
    rows.append(fmt_row("table7", "w_policy_AS/ds-coder-proxy",
                        eval_mode(suite, "policy", policy)))
    if small_policy is not None:
        rows.append(fmt_row("table7", "w_policy_AS/llama-proxy-small",
                            eval_mode(suite, "policy", small_policy)))
    rows.append(fmt_row("table7", "wo_policy_AS/random",
                        eval_mode(suite, "random", None)))
    rows.append(fmt_row("table7", "wo_policy_AS/untrained-lm",
                        eval_mode(suite, "untrained", MacroPolicy())))
    rows.append(fmt_row("table7", "wo_policy_woAS/untrained-lm",
                        eval_mode(suite, "untrained", MacroPolicy(),
                                  curated=False)))
    rows.extend(_budget_rows(policy))
    return rows
