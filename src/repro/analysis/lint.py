"""``python -m repro.analysis.lint`` — lint suites, DBs, program JSON.

Runs the full static analysis (verifier + legality, DESIGN.md §15)
over every program it can find in the named sources and prints one
rendered diagnostic per line.  Exit status 1 when any ERROR diagnostic
is produced (``--strict``: warnings fail too), so CI can gate on it.

Sources:

  --suites kb,tb,ext,train   committed task suites (default: all)
  --db DIR                   a MeasureDB directory: every winner
                             record's embedded program is analyzed;
                             sample records are structurally validated
                             (required keys present, numbers finite)
                             and a sample's embedded training program
                             (post-§17 records) is analyzed too
  --artifact PATH            a pickled model artifact (learned cost
                             model, macro policy): must unpickle, be
                             structurally sound (finite parameters)
                             and carry provenance ``meta``; a learned
                             cost model's feature schema must match
                             the current ``FEATURE_VERSION`` — a stale
                             artifact would silently price everything
                             through the analytic fallback
  --transcripts DIR          recorded LLM micro-coder transcripts
                             (``llmcoder.TranscriptStore`` jsonl
                             shards): every embedded program is
                             analyzed.  Repair chains are graded by
                             their OUTCOME — a chain's highest-attempt
                             response must analyze clean (or the chain
                             must end in a recorded backend refusal);
                             analyzer errors on earlier attempts are
                             the repair loop working as designed and
                             are counted, not failed
  --soundness                additionally run the rule-soundness
                             differential harness over the suite
                             programs x every registered rule
  --target NAME              analyze against one registered
                             HardwareTarget instead of the portability
                             envelope
  PATH...                    JSON files: one ``program_to_json`` dict,
                             or a winner-style record with a
                             ``program`` key

Examples:

  PYTHONPATH=src python -m repro.analysis.lint
  PYTHONPATH=src python -m repro.analysis.lint --db tests/fixtures/measure_db \
      --db results/policy_reward_db --soundness
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
import sys

from repro.analysis.diagnostics import Diagnostic
from repro.analysis.legality import analyze_program

SUITES = {
    "kb": ("kb_level1", "kb_level2", "kb_level3"),
    "tb": ("tb_t", "tb_g"),
    "ext": ("ext_tasks",),
    "train": ("train_tasks",),
}

# keys a MeasureDB sample record must carry (measure/db.py layout)
SAMPLE_KEYS = ("analytic_s", "env_fp", "mode", "prog_fp", "samples",
               "target", "task_fp", "time_s")


def _suite_programs(names) -> list[tuple[str, "object"]]:
    from repro.core import tasks
    out = []
    for short in names:
        for fn_name in SUITES[short]:
            for t in getattr(tasks, fn_name)():
                prog = t.program if hasattr(t, "program") else t
                out.append((f"{fn_name}/{prog.name}", prog))
    return out


def _load_program(payload: dict, where: str):
    from repro.core.kernel_ir import program_from_json
    if "program" in payload and isinstance(payload["program"], dict):
        payload = payload["program"]
    try:
        return program_from_json(payload), ""
    except Exception as e:
        return None, f"{where}: unreadable program JSON: {e}"


def _check_sample(rec: dict, where: str) -> list[str]:
    probs = [f"{where}: sample record missing key {k!r}"
             for k in SAMPLE_KEYS if k not in rec]
    for k in ("analytic_s", "time_s"):
        v = rec.get(k)
        if isinstance(v, (int, float)) and not math.isfinite(v):
            probs.append(f"{where}: non-finite {k}={v}")
    if isinstance(rec.get("time_s"), (int, float)) and rec["time_s"] < 0:
        probs.append(f"{where}: negative time_s={rec['time_s']}")
    return probs


def _db_sources(db_dir: str):
    """(kind, path, record) for every JSON record under a DB dir."""
    for sub, kind in (("winners", "winner"), ("samples", "sample")):
        for p in sorted(glob.glob(os.path.join(db_dir, sub, "*.json"))):
            try:
                with open(p) as f:
                    yield kind, p, json.load(f)
            except (OSError, json.JSONDecodeError) as e:
                yield "corrupt", p, {"error": str(e)}


def _check_artifact(path: str) -> list[str]:
    """Provenance/structure problems with a pickled model artifact."""
    import pickle

    import numpy as np
    try:
        with open(path, "rb") as f:
            blob = pickle.load(f)
    except Exception as e:
        return [f"{path}: unreadable artifact: {type(e).__name__}: {e}"]
    if not isinstance(blob, dict):
        return [f"{path}: artifact is {type(blob).__name__}, not a "
                f"provenance-carrying dict"]
    probs = []
    meta = blob.get("meta")
    if not isinstance(meta, dict) or not meta:
        probs.append(f"{path}: artifact carries no provenance meta")
        meta = {}
    if blob.get("kind") == "learned_cost_model" \
            or meta.get("kind") == "learned_cost_model":
        from repro.measure.learned import FEATURE_NAMES, FEATURE_VERSION
        if meta.get("feature_version") != FEATURE_VERSION:
            probs.append(
                f"{path}: feature_version "
                f"{meta.get('feature_version')!r} != current "
                f"{FEATURE_VERSION} (stale artifact: every prediction "
                f"would fall back to analytic)")
        names = tuple(blob.get("feature_names", ()))
        if names != FEATURE_NAMES:
            probs.append(f"{path}: feature names disagree with the "
                         f"current featurizer ({len(names)} vs "
                         f"{len(FEATURE_NAMES)})")
        for k in ("n_samples", "n_groups", "targets", "env_fps"):
            if k not in meta:
                probs.append(f"{path}: meta missing {k!r}")
        if isinstance(meta.get("n_samples"), int) \
                and meta["n_samples"] <= 0:
            probs.append(f"{path}: trained on zero samples")
        for k in ("weights", "mean", "std", "lo", "hi"):
            v = blob.get(k)
            if v is None or not np.all(np.isfinite(
                    np.asarray(v, dtype=np.float64))):
                probs.append(f"{path}: non-finite or missing {k!r}")
    else:
        # macro_policy.pkl-style blobs: every numeric leaf of the
        # (possibly nested) params tree must be finite
        def walk(node, where):
            if isinstance(node, dict):
                for k, v in sorted(node.items()):
                    walk(v, f"{where}[{k!r}]")
                return
            try:
                arr = np.asarray(node, dtype=np.float64)
            except (TypeError, ValueError):
                return
            if not np.all(np.isfinite(arr)):
                probs.append(f"{path}: non-finite {where}")

        params = blob.get("params")
        if isinstance(params, dict):
            walk(params, "params")
    return probs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.lint",
        description="static analysis over task suites, measure DBs "
                    "and program JSON files")
    ap.add_argument("paths", nargs="*", help="program JSON files")
    ap.add_argument("--suites", default="kb,tb,ext,train",
                    help=f"comma list of {'/'.join(SUITES)} "
                         "(empty to skip)")
    ap.add_argument("--db", action="append", default=[],
                    help="MeasureDB directory (repeatable)")
    ap.add_argument("--transcripts", action="append", default=[],
                    help="LLM-coder transcript directory (repeatable)")
    ap.add_argument("--artifact", action="append", default=[],
                    help="pickled model artifact to sweep (repeatable)")
    ap.add_argument("--target", default=None,
                    help="HardwareTarget name (default: portability "
                         "envelope)")
    ap.add_argument("--soundness", action="store_true",
                    help="run the rule-soundness harness over the "
                         "suite programs")
    ap.add_argument("--strict", action="store_true",
                    help="warnings fail the run too")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress per-source OK lines")
    args = ap.parse_args(argv)

    n_errors = n_warnings = n_programs = 0
    structural: list[str] = []

    def report(where: str, diags: list[Diagnostic]) -> None:
        nonlocal n_errors, n_warnings
        for d in diags:
            print(d.render(where))
            if d.is_error:
                n_errors += 1
            else:
                n_warnings += 1
        if not diags and not args.quiet:
            print(f"{where}: OK")

    suite_names = [s for s in args.suites.split(",") if s]
    bad = [s for s in suite_names if s not in SUITES]
    if bad:
        ap.error(f"unknown suites {bad}; pick from {sorted(SUITES)}")
    progs = _suite_programs(suite_names)
    for where, prog in progs:
        n_programs += 1
        report(where, analyze_program(prog, args.target))

    for db_dir in args.db:
        if not os.path.isdir(db_dir):
            structural.append(f"{db_dir}: not a directory")
            continue
        for kind, path, rec in _db_sources(db_dir):
            if kind == "corrupt":
                structural.append(f"{path}: corrupt record: "
                                  f"{rec['error']}")
            elif kind == "winner":
                prog, err = _load_program(rec, path)
                if prog is None:
                    structural.append(err)
                else:
                    n_programs += 1
                    report(path, analyze_program(prog, args.target))
            else:
                structural.extend(_check_sample(rec, path))
                if isinstance(rec.get("program"), dict):
                    prog, err = _load_program(rec["program"], path)
                    if prog is None:
                        structural.append(err)
                    else:
                        n_programs += 1
                        report(path,
                               analyze_program(prog, args.target))

    for tdir in args.transcripts:
        if not os.path.isdir(tdir):
            structural.append(f"{tdir}: not a directory")
            continue
        # TranscriptStore skips undecodable lines on load; re-scan so a
        # truncated/hand-mangled committed shard fails the lint
        for shard in sorted(glob.glob(os.path.join(tdir, "*.jsonl"))):
            with open(shard) as f:
                for i, line in enumerate(f, 1):
                    if not line.strip():
                        continue
                    try:
                        json.loads(line)
                    except json.JSONDecodeError as e:
                        structural.append(
                            f"{shard}:{i}: corrupt transcript line: {e}")
        from repro.llmcoder.prompts import (ResponseParseError,
                                            parse_response)
        from repro.llmcoder.transcript import TranscriptStore
        chains: dict[tuple, list[dict]] = {}
        for rec in TranscriptStore(tdir).records():
            ident = (rec.get("task_fp", ""), rec.get("prog_fp", ""),
                     rec.get("action_key", ""))
            chains.setdefault(ident, []).append(rec)
        n_repair_rejects = n_tprogs = 0
        for ident in sorted(chains):
            recs = sorted(chains[ident],
                          key=lambda r: int(r.get("attempt", 0)))
            for rec in recs:
                final = rec is recs[-1]
                where = (f"{tdir}:{rec.get('task_fp', '')[:8]}/"
                         f"{rec.get('action_key', '')}"
                         f"@{rec.get('attempt', 0)}")
                if rec.get("error"):
                    # a recorded refusal: legitimate chain outcome (the
                    # loop maps it to compile_error), nothing to analyze
                    continue
                try:
                    prog = parse_response(rec.get("response") or "")
                except ResponseParseError as e:
                    if final:
                        structural.append(
                            f"{where}: chain ends on an unparseable "
                            f"response: {e}")
                    else:
                        n_repair_rejects += 1
                    continue
                n_programs += 1
                n_tprogs += 1
                diags = analyze_program(prog, args.target)
                errs = [d for d in diags if d.is_error]
                if final:
                    # the outcome the search consumed: must be clean
                    report(where, diags)
                elif errs:
                    # expected: this reject is exactly what the next
                    # attempt's feedback repaired
                    n_repair_rejects += 1
        print(f"{tdir}: {n_tprogs} transcript programs over "
              f"{len(chains)} chains, {n_repair_rejects} repaired "
              f"first-attempt rejects (expected)")

    for path in args.artifact:
        probs = _check_artifact(path)
        structural.extend(probs)
        if not probs and not args.quiet:
            print(f"{path}: artifact OK")

    for path in args.paths:
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            structural.append(f"{path}: unreadable: {e}")
            continue
        prog, err = _load_program(payload, path)
        if prog is None:
            structural.append(err)
        else:
            n_programs += 1
            report(path, analyze_program(prog, args.target))

    if args.soundness and progs:
        from repro.analysis.soundness import soundness_report
        diags = soundness_report([p for _, p in progs],
                                 target=args.target)
        errs = [d for d in diags if d.is_error]
        for d in errs:
            print(d.render("soundness"))
        n_errors += len(errs)
        # MT031 self-rejections are by design (legality floats to
        # rewrite time) — count them, don't print hundreds of lines
        n_self = len(diags) - len(errs)
        print(f"soundness: {len(errs)} errors, {n_self} "
              "self-rejected candidates (expected)")

    for line in structural:
        print(f"{line}")
    n_errors += len(structural)

    print(f"linted {n_programs} programs: {n_errors} errors, "
          f"{n_warnings} warnings")
    if n_errors or (args.strict and n_warnings):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
