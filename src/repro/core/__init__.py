"""MTMC — Macro Thinking Micro Coding (the paper's contribution).

Macro Thinking: RL-trained lightweight LM policy proposing semantic
optimization actions (Tiling / Fusion / Pipeline / Reordering x region).
Micro Coding: stepwise structured rewrites of the kernel IR with
compile/correctness feedback.  See DESIGN.md.
"""
from repro.core import rules                              # noqa: F401
from repro.core.actions import Action, candidate_actions  # noqa: F401
from repro.core.config import (OptimizeConfig,            # noqa: F401
                               reset_deprecation_warnings)
from repro.core.cost_model import program_cost, speedup   # noqa: F401
from repro.core.rules import RewriteRule, register_rule   # noqa: F401
from repro.core.engine import (EngineConfig, EvalEngine,  # noqa: F401
                               TranspositionStore)
from repro.core.env import (AnalyticRewardSource,         # noqa: F401
                            CalibratedRewardSource, EnvConfig,
                            KernelEnv, MeasuredRewardSource, OfflineEnv,
                            OfflineTree, RewardSource, get_reward_source)
from repro.core.hardware import (HardwareTarget, get_target,  # noqa: F401
                                 register_target, registered_targets)
from repro.core.search import (AnnealedSearch, BeamSearch,  # noqa: F401
                               GreedySearch, PolicySearch,
                               SearchStrategy, get_strategy,
                               register_strategy)
from repro.core.kernel_ir import KernelProgram, OpNode, TensorSpec  # noqa: F401
from repro.core.micro_coding import StructuredMicroCoder  # noqa: F401
from repro.core.pipeline import MTMCPipeline, evaluate_suite, suite_metrics  # noqa: F401
from repro.core.policy import MacroPolicy, PolicyConfig   # noqa: F401
from repro.core.ppo import PPOConfig, PPOTrainer          # noqa: F401
from repro.core.trajectories import CollectConfig, collect, collect_suite  # noqa: F401
