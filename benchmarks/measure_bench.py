"""Measured-execution benchmark: does measurement beat the model?

For each hardware target, beam search produces the top-K candidate
programs per task; every candidate is lowered through the Pallas kernel
library (interpret mode on CPU — no TPU in this container) and timed by
the ``measure.ExecutionHarness``.  Reported per target:

* **rho (task-level)** — Spearman(analytic, measured) across the task
  programs themselves, measured as XLA-jitted host callables: does the
  roofline rank *work* correctly?  High and stable (both sides scale
  with FLOPs/bytes), so this is the gated number.
* **rho_cand (candidate-level)** — the same across all top-K schedule
  variants in Pallas-interpret mode.  Low by construction on CPU: the
  candidates sit on analytic-cost plateaus the TPU model prices
  identically while interpret-mode grid overheads split them — exactly
  the gap measured reranking exists to close.  Reported, not gated.
* **rho_cal** — rho_cand after per-bottleneck calibration factors are
  fit from the just-collected samples (``measure.calibrate``), with the
  per-bucket fit report (sample counts, fitted vs fallback) printed so
  a degenerate no-op calibration is visible instead of silent.
* **rho_learn** — the ``LearnedCostModel`` (measure/learned.py) judged
  on the job it is trained for: MEAN PER-TASK Spearman over each task's
  candidate set (reranking only ever compares candidates of one task,
  and the group-normalized fit never sees cross-task contrasts), under
  leave-one-task-out cross validation — each task's candidates are
  predicted by a model fit only on the OTHER tasks' samples, so the
  number measures generalization, never memorization.  The calibrated
  comparator is computed per-task the same way.  Fallback predictions
  (out-of-distribution -> analytic lifted by the model's
  ``fallback_log_scale``) are counted.
* **learned vs calibrated rerank** — per task, the measured time of the
  candidate the (held-out) learned model would surface first vs the one
  calibration would surface: the end-to-end claim that learned
  reranking is never worse.  Gated on the geomean pick ratio (plus an
  absolute per-task ceiling for catastrophic misranks) because single
  picks on plateau tasks swap within ~10% interpret-mode jitter.
* **rho_transfer** — stretch: a model fit on ALL tpu_v5e samples
  ranking the gpu_a100 candidates purely through target-constant
  features (reported; gated via check_regression once committed).
* **winner-changed count**: tasks where the measured-reranked winner is
  a *different program* than the analytic winner (it is never slower —
  reranking returns the measured argmin), with the measured margin.
* **DB warm pass**: every candidate re-measured against the on-disk DB
  must hit (zero fresh timings) — the persistence the KernelService
  warm start relies on.

Gates (non-zero exit, wired into CI bench-smoke):
  * per-target task-level Spearman >= RHO_FLOOR (the committed results
    carry the reference value; benchmarks.check_regression additionally
    compares the fresh ``rho=`` field against the committed CSV),
  * the measured winner differs from the analytic winner on >= 1 task,
  * per target, per-task ``rho_learn`` > per-task calibrated rho (the
    learned model must beat scalar calibration at candidate ranking —
    the whole point), the learned picks are not worse than the
    calibrated picks in aggregate (geomean), and no single learned
    pick is catastrophically slower,
  * the second (warm) pass performs zero fresh measurements.

  PYTHONPATH=src python -m benchmarks.measure_bench [--fast]
      [--out results/measure_bench.txt] [--csv results/measure_bench.csv]
      [--db DIR]  (default: a temp dir; pass a path to persist samples)
"""
from __future__ import annotations

import argparse
import os
import tempfile

import numpy as np

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

TARGETS = ("tpu_v5e", "gpu_a100")
# absolute floor on the task-level Spearman: catches a cost model or
# harness that stopped tracking reality (rho ~ 0) while leaving room
# for wall-clock noise on a loaded CI box (observed run-to-run spread
# on this suite: ~0.45-0.85); the committed rho is additionally gated
# with slack by benchmarks.check_regression
RHO_FLOOR = 0.30
# learned-vs-calibrated pick gate: each pick's time is one
# interpret-mode measurement, and candidates frequently sit on timing
# plateaus where any ordering swaps picks within ~10% jitter — so the
# gate is on the GEOMEAN pick ratio across tasks (the end-to-end
# "never worse" claim), with an absolute per-task ceiling that still
# catches a catastrophic individual misrank.  Per-task labels beyond
# PICK_NOISE_TOL stay visible in the report either way.
PICK_NOISE_TOL = 0.05
PICK_CATASTROPHIC = 1.5


def _suite(fast: bool):
    """Rerank suite: tasks whose candidates stay Pallas-interpret cheap."""
    from repro.core import tasks as T
    kb1, kb2 = T.kb_level1(), T.kb_level2()
    by_name = {t.name: t for t in kb1 + kb2}
    names = ["L1_matmul_0", "L1_rmsnorm", "L1_attention",
             "L2_gemm_bias_relu"]
    if not fast:
        names += ["L1_matmul_1", "L2_norm_gemm", "L2_mlp_gelu_proj"]
    return [by_name[n] for n in names]


def _rank_suite(fast: bool):
    """Task-level rank suite: a work-size spread for the gated rho.

    Same 15 tasks in fast and full mode: each is timed ONCE as an
    XLA-jitted host callable (cheap), and the gated Spearman's run-to-
    run variance shrinks with the point count — a 10-point rho swings
    too much for a CI gate on a noisy box."""
    from repro.core import tasks as T
    by_name = {t.name: t for t in T.kb_level1() + T.kb_level2()
               + T.tb_t()}
    names = ["L1_matmul_0", "L1_matmul_1", "L1_matmul_2", "L1_matmul_3",
             "L1_softmax", "L1_rmsnorm", "L1_relu", "L1_attention",
             "L2_gemm_bias_relu", "L2_swiglu", "L2_mlp", "L2_norm_gemm",
             "T_gemm_0", "T_layernormish", "T_softmax_wide"]
    return [by_name[n] for n in names]


def run(fast: bool, db_dir: str) -> tuple[list[str], list[str],
                                          list[str]]:
    import math

    from repro.core.engine import TranspositionStore
    from repro.core.micro_coding import StructuredMicroCoder
    from repro.core.search import BeamSearch
    from repro.measure.calibrate import fit_calibration, spearman
    from repro.measure.db import MeasureDB
    from repro.measure.harness import ExecutionHarness, MeasureConfig
    from repro.measure.learned import featurize, fit_learned_model

    top_k = 6 if fast else 8
    cfg = MeasureConfig(repeats=3 if fast else 5, warmup=1)
    db = MeasureDB(db_dir)
    harness = ExecutionHarness(db=db, cfg=cfg)
    # separate harness for the task-level rank metric: XLA-jitted host
    # execution (its own env fingerprint, so samples never mix)
    xla_harness = ExecutionHarness(
        db=db, cfg=MeasureConfig(repeats=3 if fast else 5, warmup=1,
                                 mode="xla"))
    store = TranspositionStore()
    coder = StructuredMicroCoder()
    suite = _suite(fast)
    rank_suite = _rank_suite(fast)

    # task-level measured times are target-independent (the host backend
    # executes the same callable whichever chip the analytic side prices
    # for): time each task ONCE and pair it with per-target analytic
    # costs below, instead of re-timing the suite per target
    from repro.core import cost_model
    rank_times = {t.name: xla_harness.measure(t, t,
                                              target=TARGETS[0]).time_s
                  for t in rank_suite}

    rows: list[str] = []
    lines: list[str] = []
    failures: list[str] = []
    all_by_task: dict[str, dict] = {}   # target -> task -> candidates
    for target in TARGETS:
        # task-level rank correlation (gated): XLA-compiled host
        # runtimes vs analytic cost across a work-size spread
        rank_pairs = [(cost_model.program_cost(t, target).total_s,
                       rank_times[t.name]) for t in rank_suite]
        rho_task = spearman([a for a, _ in rank_pairs],
                            [m for _, m in rank_pairs])

        pairs = []              # (analytic_s, measured_s, sample)
        by_task = {}            # task name -> [(c, measured_s, sample, prog)]
        n_changed = 0
        task_lines = []
        for task in suite:
            out = BeamSearch().search(task, coder=coder, store=store,
                                      target=target,
                                      max_steps=3 if fast else 5)
            cands = list(out.candidates[:top_k])
            meas = []
            for c, p in cands:
                s = harness.measure(task, p, target=target)
                pairs.append((c, s.time_s, s))
                by_task.setdefault(task.name, []).append(
                    (c, s.time_s, s, p))
                meas.append((s.time_s, p.fingerprint(), c, p))
            meas.sort(key=lambda e: (e[0], e[1]))
            m_t, m_fp, _, _ = meas[0]
            a_best = min(cands, key=lambda e: (e[0], e[1].fingerprint()))
            a_fp = a_best[1].fingerprint()
            a_t = next(t for t, fp, _, _ in meas if fp == a_fp)
            changed = m_fp != a_fp
            n_changed += changed
            ratio = a_t / max(m_t, 1e-12)
            verdict = (f"WINNER CHANGED x{ratio:.2f}" if changed
                       else "same winner")
            task_lines.append(
                f"    {task.name:<22s} analytic-pick {a_t * 1e3:8.2f} ms"
                f"  measured-pick {m_t * 1e3:8.2f} ms  {verdict}")

        rho = spearman([a for a, _, _ in pairs],
                       [m for _, m, _ in pairs])
        # calibrated analytic: each sample rescaled by the factor of
        # its (target, bottleneck) bucket — the correction
        # CalibratedCostModel applies per fused group during search
        fit = fit_calibration(s for _, _, s in pairs)
        fm = fit.factor_map
        rho_cal = spearman(
            [c * fm.get((target, s.bottleneck), 1.0)
             for c, _, s in pairs],
            [m for _, m, _ in pairs])

        # learned cost model, leave-one-task-out: each task's
        # candidates are predicted by a ridge fit on the OTHER tasks'
        # samples only — generalization, not memorization.  The same
        # held-out predictions drive the learned-vs-calibrated rerank
        # comparison (measured time of each model's top pick).
        # ranking quality is judged per task (mean within-task
        # Spearman, for learned AND calibrated alike): reranking only
        # ever compares one task's candidates against each other, and
        # the group-normalized fit never sees cross-task contrasts, so
        # pooled cross-task correlation would reward/punish an ordering
        # no consumer uses
        n_fallback = 0
        n_learned_worse = 0
        rerank_lines = []
        rho_l_tasks: list[float] = []
        rho_c_tasks: list[float] = []
        pick_ratios: list[float] = []
        for name, rows_t in by_task.items():
            train = [s for n2, rs in by_task.items() if n2 != name
                     for (_, _, s, _) in rs]
            model = fit_learned_model(train)
            scored = []
            for c, m_s, s, p in rows_t:
                pred = (model.predict_log_s(featurize(p, target))
                        if model is not None else None)
                if pred is None:
                    n_fallback += 1
                    # analytic lifted onto the measured scale (same
                    # correction LearnedCostModel applies), so an OOD
                    # candidate competes fairly with predicted ones
                    pred = math.log(max(c, 1e-12)) + (
                        model.fallback_log_scale
                        if model is not None else 0.0)
                scored.append((pred, m_s, s, c, p.fingerprint()))
            rho_l_tasks.append(spearman(
                [e[0] for e in scored], [e[1] for e in scored]))
            rho_c_tasks.append(spearman(
                [e[3] * fm.get((target, e[2].bottleneck), 1.0)
                 for e in scored], [e[1] for e in scored]))
            l_pick = min(scored, key=lambda e: (e[0], e[4]))[1]
            c_pick = min(scored,
                         key=lambda e: (e[3] * fm.get(
                             (target, e[2].bottleneck), 1.0), e[4]))[1]
            ratio = l_pick / max(c_pick, 1e-12)
            pick_ratios.append(ratio)
            worse = ratio > 1.0 + PICK_NOISE_TOL
            n_learned_worse += worse
            rerank_lines.append(
                f"    {name:<22s} learned-pick {l_pick * 1e3:8.2f} ms"
                f"  calibrated-pick {c_pick * 1e3:8.2f} ms  "
                + ("LEARNED WORSE" if worse else
                   f"ok (x{c_pick / max(l_pick, 1e-12):.2f})"))
        rho_learn = float(np.mean(rho_l_tasks))
        rho_cal_task = float(np.mean(rho_c_tasks))
        pick_geomean = float(np.exp(np.mean(np.log(pick_ratios))))

        lines.append(
            f"{target}: {len(rank_suite)} tasks (xla) + {len(suite)} "
            f"tasks x top-{top_k} candidates ({len(pairs)} measured, "
            f"mode {pairs[0][2].mode})")
        lines.extend(task_lines)
        lines.append(
            f"    Spearman(analytic, measured): task-level {rho_task:.3f}"
            f" (gated), candidate-level {rho:.3f} "
            f"(calibrated: {rho_cal:.3f}); per-task mean: calibrated "
            f"{rho_cal_task:.3f}, learned LOTO {rho_learn:.3f} with "
            f"{n_fallback} analytic fallbacks; winner changed on "
            f"{n_changed}/{len(suite)} tasks")
        lines.append("    calibration buckets: "
                     + "; ".join(fit.bucket_report(target)))
        lines.extend(rerank_lines)
        lines.append(
            f"    pick geomean learned/calibrated: x{pick_geomean:.3f}"
            f" (<1 = learned faster; {n_learned_worse} task(s) beyond "
            f"{PICK_NOISE_TOL:.0%} jitter)")
        rows.append(
            f"measure/{target},"
            f"{1e6 * float(np.mean([m for _, m, _ in pairs])):.1f},"
            f"rho={rho_task:.3f};rho_cand={rho:.3f};"
            f"rho_cal={rho_cal:.3f};rho_learn={rho_learn:.3f};"
            f"pick_geomean={pick_geomean:.3f};"
            f"winner_changed={n_changed};cands={len(pairs)}")
        if rho_task < RHO_FLOOR:
            failures.append(f"{target}: task-level Spearman "
                            f"{rho_task:.3f} < floor {RHO_FLOOR}")
        if n_changed < 1:
            failures.append(
                f"{target}: measured reranking never changed a winner")
        if rho_learn <= rho_cal_task:
            failures.append(
                f"{target}: learned per-task rho {rho_learn:.3f} does "
                f"not beat calibrated {rho_cal_task:.3f}")
        if pick_geomean > 1.0 + PICK_NOISE_TOL:
            failures.append(
                f"{target}: learned picks worse than calibrated picks "
                f"in aggregate (geomean ratio x{pick_geomean:.2f} "
                f"beyond {PICK_NOISE_TOL:.0%} timing noise)")
        if max(pick_ratios) > PICK_CATASTROPHIC:
            failures.append(
                f"{target}: a learned pick is x{max(pick_ratios):.2f} "
                f"slower than the calibrated pick (ceiling "
                f"x{PICK_CATASTROPHIC:g})")
        all_by_task[target] = by_task

    # stretch: cross-target transfer — fit on every tpu_v5e sample,
    # rank the gpu_a100 candidates sight-unseen (target constants are
    # features, so one model can price both chips)
    src, dst = TARGETS[0], TARGETS[1]
    train = [s for rs in all_by_task[src].values()
             for (_, _, s, _) in rs]
    t_model = fit_learned_model(train)
    n_t_cands = 0
    n_t_fallback = 0
    t_rhos = []
    for rows_t in all_by_task[dst].values():
        t_pairs = []
        for c, m_s, s, p in rows_t:
            pred = (t_model.predict_log_s(featurize(p, dst))
                    if t_model is not None else None)
            if pred is None:
                n_t_fallback += 1
                pred = math.log(max(c, 1e-12)) + (
                    t_model.fallback_log_scale
                    if t_model is not None else 0.0)
            t_pairs.append((pred, m_s))
        n_t_cands += len(t_pairs)
        t_rhos.append(spearman([a for a, _ in t_pairs],
                               [m for _, m in t_pairs]))
    rho_transfer = float(np.mean(t_rhos))
    lines.append(
        f"transfer {src} -> {dst}: per-task candidate rho "
        f"{rho_transfer:.3f} ({n_t_cands} candidates, {n_t_fallback} "
        f"analytic fallbacks)")
    rows.append(f"measure/transfer,{0.0:.1f},"
                f"rho_transfer={rho_transfer:.3f};"
                f"cands={n_t_cands};fallbacks={n_t_fallback}")

    # warm pass: everything must come back from the DB, zero timings
    before = harness.stats_dict()["measured"]
    warm_hits = 0
    for target in TARGETS:
        for task in suite:
            out = BeamSearch().search(task, coder=coder, store=store,
                                      target=target,
                                      max_steps=3 if fast else 5)
            for _, p in out.candidates[:top_k]:
                harness.measure(task, p, target=target)
                warm_hits += 1
    fresh = harness.stats_dict()["measured"] - before
    lines.append(f"warm pass: {warm_hits} lookups, {fresh} fresh "
                 f"timings (db {db.n_samples} samples on disk)")
    rows.append(f"measure/db_warm,{0.0:.1f},"
                f"fresh={fresh};lookups={warm_hits};"
                f"samples={db.n_samples}")
    if fresh != 0:
        failures.append(f"warm pass re-measured {fresh} programs "
                        "(DB persistence broken)")
    return rows, lines, failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="CI smoke sizes")
    ap.add_argument("--out", default=os.path.join(RESULTS,
                                                  "measure_bench.txt"))
    ap.add_argument("--csv", default=os.path.join(RESULTS,
                                                  "measure_bench.csv"))
    ap.add_argument("--db", default=None,
                    help="measurement-DB dir (default: fresh temp dir)")
    args = ap.parse_args()

    db_dir = args.db or tempfile.mkdtemp(prefix="measure_bench_db_")
    try:
        rows, lines, failures = run(args.fast, db_dir)
    finally:
        if args.db is None:       # only reap the dir we created
            import shutil
            shutil.rmtree(db_dir, ignore_errors=True)

    text = "\n".join(lines) + "\n"
    print(text)
    os.makedirs(RESULTS, exist_ok=True)
    with open(args.out, "w") as f:
        f.write(text)
    with open(args.csv, "w") as f:
        f.write("name,us_per_call,derived\n")
        f.write("\n".join(rows) + "\n")
    for msg in failures:
        print(f"FAIL: {msg}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
