"""Serving launcher: continuous-batching demo over mixed-length prompts.

  python -m repro.launch.serve --arch qwen2_5_3b --reduced --requests 8
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, reduced
from repro.models import api
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--eos", type=int, default=None,
                    help="optional EOS token id applied to every request")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if cfg.family not in ("dense", "moe", "vlm"):
        raise SystemExit(f"Engine demo supports transformer families; "
                         f"{cfg.family} decodes via its serve_step "
                         f"(see launch/dryrun.py decode cells)")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, max_len=args.max_len,
                    batch_slots=args.slots, eos_id=args.eos)
    key = jax.random.PRNGKey(1)
    reqs = [Request(jax.random.randint(jax.random.fold_in(key, i),
                                       (3 + i % 4,), 1, 100, jnp.int32),
                    max_new_tokens=args.max_new + i % 3,
                    eos_id=args.eos)
            for i in range(args.requests)]
    engine.run(reqs)
    for i, r in enumerate(reqs):
        trunc = " [truncated]" if r.truncated else ""
        print(f"req{i} (len {len(r.prompt)}, budget "
              f"{r.max_new_tokens}): {r.out}{trunc}")
    st = engine.stats
    occ = st["occupancy_sum"] / max(st["decode_steps"], 1)
    print(f"steps={st['decode_steps']} tokens={st['decode_tokens']} "
          f"prefills={st['prefills']} occupancy={occ:.2f} "
          f"truncations={st['truncations']}")


if __name__ == "__main__":
    main()
