from repro.ft.straggler import StragglerMonitor  # noqa: F401
from repro.ft.elastic import elastic_plan, remesh_state  # noqa: F401
