"""Serving engine: prefill + decode with slot-based continuous batching.

``serve_step`` (one token for the whole batch against a KV cache) is the
function the decode_* / long_* dry-run cells lower.  The Engine below runs
real generation for the examples/tests (transformer families; rwkv/hymba
decode through their own cache trees).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import api, makers
from repro.models.layers import zeros_init


def make_serve_step(cfg: ModelConfig, *, rules=None):
    model = api.get_model(cfg)

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(cfg, params, cache, tokens, pos,
                                 rules=rules)
    return serve_step


def prefill_transformer(cfg: ModelConfig, params, tokens, max_len: int):
    """Run the prompt through forward(collect_cache) and build a cache."""
    from repro.models import transformer
    logits, aux, (ks, vs) = transformer.forward(
        cfg, params, {"tokens": tokens}, remat=False, collect_cache=True)
    B, S = tokens.shape
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    cache = api.init_cache(cfg, B, max_len)
    k = jax.lax.dynamic_update_slice(
        cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    v = jax.lax.dynamic_update_slice(
        cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    return logits, {"k": k, "v": v}


@dataclasses.dataclass
class Request:
    prompt: jnp.ndarray           # (S,) int32
    max_new_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """Slot-based batched generation for dense transformer families."""

    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 128,
                 batch_slots: int = 4, greedy: bool = True):
        assert cfg.family in ("dense", "moe", "vlm")
        self.cfg, self.params = cfg, params
        self.max_len, self.slots = max_len, batch_slots
        self.greedy = greedy
        self.serve_step = jax.jit(make_serve_step(cfg))

    def generate(self, prompts: list[jnp.ndarray],
                 max_new_tokens: int = 16) -> list[list[int]]:
        """Static batching within slot groups (continuous batching lite:
        new prompts join as finished ones free their slot group)."""
        results: list[list[int]] = []
        queue = list(prompts)
        while queue:
            group = queue[:self.slots]
            queue = queue[self.slots:]
            results.extend(self._generate_group(group, max_new_tokens))
        return results

    def _generate_group(self, prompts, max_new):
        B = len(prompts)
        S = max(len(p) for p in prompts)
        toks = jnp.stack([jnp.pad(p, (S - len(p), 0)) for p in prompts])
        logits, cache = prefill_transformer(self.cfg, self.params, toks,
                                            self.max_len)
        last = logits[:, -1]
        outs = [[] for _ in range(B)]
        pos = S
        for _ in range(max_new):
            nxt = jnp.argmax(last, -1).astype(jnp.int32) if self.greedy \
                else None
            for b in range(B):
                outs[b].append(int(nxt[b]))
            logits, cache = self.serve_step(
                self.params, cache, nxt[:, None], jnp.int32(pos))
            last = logits[:, -1]
            pos += 1
            if pos >= self.max_len:
                break
        return outs
