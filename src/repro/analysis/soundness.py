"""Rule-soundness differential harness (pass 3).

A ``RewriteRule`` is SOUND when every candidate it enumerates as legal
rewrites a well-formed program into another program the verifier +
legality analyzer accept.  This pass proves that property statically,
with no oracle evaluation: for each seed program it enumerates each
rule's curated candidates, applies the rewrite, and re-analyzes the
result — an analyzer rejection of a rule-accepted rewrite is an MT030
error (the rule's legality predicate and the analyzer disagree: one of
them is wrong, and either way the search space is poisoned).  A
candidate a rule enumerates but then rejects in its own ``rewrite``
is only an MT031 warning — self-rejection wastes a search expansion
but cannot corrupt state (``candidate_actions`` intentionally floats
some legality to rewrite time).

CI runs this over every committed suite × every registered rule
(``tests/test_analysis.py``); ``repro.analysis.lint --soundness``
exposes the same sweep from the command line.
"""
from __future__ import annotations

from repro.analysis.diagnostics import Diagnostic, error, warning
from repro.core import rules as rules_mod
from repro.core.kernel_ir import KernelProgram, sched_kind_of_group


def rule_candidates(prog: KernelProgram, rule, target=None):
    """One rule's curated candidates for ``prog`` (the same
    enumeration ``candidate_actions`` aggregates)."""
    from repro.core import hardware
    tgt = hardware.resolve(target)
    acts = []
    for g in prog.fusion_groups:
        root = prog.group_root(g)
        kind = sched_kind_of_group(prog, g)
        acts += rule.group_actions(prog, g, root, kind, tgt)
    acts += rule.global_actions(prog, tgt)
    return acts


def check_rule_soundness(prog: KernelProgram, rule, target=None,
                         depth: int = 1) -> list[Diagnostic]:
    """Differentially test one rule against one seed program.

    ``depth`` > 1 re-enumerates on each rewritten program and descends
    (bounded breadth-first), catching rules that are sound on pristine
    seeds but unsound after their own rewrites compose.
    """
    from repro.analysis.legality import analyze_program
    out: list[Diagnostic] = []
    frontier = [prog]
    for _ in range(max(1, depth)):
        nxt: list[KernelProgram] = []
        for p in frontier:
            for act in rule_candidates(p, rule, target):
                if rules_mod.is_terminal(act):
                    continue
                try:
                    new = rule.rewrite(p, act)
                except rules_mod.CompileError as e:
                    out.append(warning(
                        "MT031",
                        f"{rule.kind} enumerated {rules_mod.describe(act)} "
                        f"then rejected it: {e}",
                        span=(act.region,)))
                    continue
                bad = [d for d in analyze_program(new, target)
                       if d.is_error]
                if bad:
                    out.append(error(
                        "MT030",
                        f"{rule.kind} rewrite {rules_mod.describe(act)} "
                        f"produced a rejected program: "
                        f"{bad[0].code}: {bad[0].message}"
                        + (f" (+{len(bad) - 1} more)"
                           if len(bad) > 1 else ""),
                        span=(act.region,),
                        hint="the rule's legality predicate and the "
                             "analyzer disagree — align them"))
                else:
                    nxt.append(new)
        frontier = nxt
        if not frontier:
            break
    return out


def soundness_report(progs, target=None, extended: bool = True,
                     depth: int = 1) -> list[Diagnostic]:
    """The full sweep: every program × every registered rule."""
    out: list[Diagnostic] = []
    for prog in progs:
        for rule in rules_mod.registered_rules(extended):
            if rule.terminal:
                continue
            out += check_rule_soundness(prog, rule, target, depth)
    return out
