"""Quickstart: optimize one kernel with MTMC and inspect the trace.

    PYTHONPATH=src python examples/quickstart.py

Takes the naive (unfused, default-tiled) attention program — the
"PyTorch Eager"-style baseline — and runs the Macro-Thinking /
Micro-Coding loop.  Watch it discover the flash-attention fusion, then
tile it, with every step validated against the oracle.
"""
import sys

sys.path.insert(0, "src")

from repro.core import MTMCPipeline, OptimizeConfig, program_cost  # noqa: E402
from repro.core import tasks  # noqa: E402

task = tasks._attn_program("quickstart_attention", B=2, S=1024, H=8,
                           hd=64)
print(f"task: {task.name}")
print(f"  naive kernels: {[n.op for n in task.nodes]}")
c0 = program_cost(task)
print(f"  naive modeled time: {c0.total_s * 1e6:.1f} us "
      f"(bottleneck: {c0.bottleneck})")

pipe = MTMCPipeline(config=OptimizeConfig(mode="greedy_cost",
                                          max_steps=8))
result = pipe.optimize(task)

print("\noptimization trace:")
for i, step in enumerate(result.trace):
    print(f"  {i + 1}. {step}")
c1 = program_cost(result.program)
print(f"\nfinal kernels: {[n.op for n in result.program.nodes]}")
print(f"final modeled time: {c1.total_s * 1e6:.1f} us")
print(f"speedup: {result.speedup:.2f}x   "
      f"correct: {result.correct} (validated vs oracle)")
