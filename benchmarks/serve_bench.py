"""Serve-path benchmark: the online half of the evaluate/serve loop.

Two streams, mirroring production traffic shapes:

* **KernelService** under a Zipf-skewed optimize-request stream (hot
  kernels dominate, as many users submit the same few) driven by
  concurrent client threads — reports throughput, p50/p99 request
  latency, the coalescing hit-rate (identical in-flight requests
  sharing one search) and the segmented-LRU slab-eviction counters
  that replaced the old drop-wholesale store reset.
* **Engine** under a mixed-length prompt stream — continuous batching
  with per-slot positions; reports token throughput, per-request
  completion latency and mean slot occupancy, plus a batched-vs-solo
  parity check (the mixed-length correctness bug this PR fixes).

Gates (non-zero exit, wired into CI bench-smoke):
  * coalescing hit-rate must be > 0 on the repeated-request burst,
  * every service result must be oracle-correct,
  * batched Engine output must be token-identical to solo generation,
  * slab eviction must have run without a whole-store reset (the
    mechanism no longer exists; the counter row pins that).

  PYTHONPATH=src python -m benchmarks.serve_bench [--fast]
      [--out results/serve_bench.txt] [--csv results/serve_bench.csv]
"""
from __future__ import annotations

import argparse
import concurrent.futures as cf
import dataclasses
import os
import time

import numpy as np

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _pct(xs, p) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), p))


# ---------------------------------------------------------------------------
# KernelService stream
# ---------------------------------------------------------------------------

def bench_service(fast: bool) -> tuple[dict, list[str]]:
    from repro.core import tasks as T
    from repro.serve.engine import KernelService

    suite = T.kb_level1() + T.kb_level2() + T.kb_level3()
    n_req = 80 if fast else 300
    svc = KernelService(mode="greedy_cost",
                        max_steps=3 if fast else 6,
                        serve_workers=4,
                        max_programs=150 if fast else 1200,
                        evict_slab=30 if fast else 150)
    hot = suite[0]

    # phase 1 — repeated-request burst: the same task submitted
    # back-to-back while the first search is in flight MUST coalesce
    t0 = time.perf_counter()
    burst = [svc.submit(hot) for _ in range(16)]
    burst_res = [svc.result(f) for f in burst]
    burst_s = time.perf_counter() - t0
    burst_coalesced = svc.stats()["coalesced"]

    # phase 2 — Zipf-skewed concurrent client stream
    rng = np.random.default_rng(0)
    picks = [(int(z) - 1) % len(suite) for z in rng.zipf(1.5, n_req)]

    def one(i: int):
        t = time.perf_counter()
        r = svc.optimize(suite[i])
        return time.perf_counter() - t, bool(r.correct)

    t0 = time.perf_counter()
    with cf.ThreadPoolExecutor(max_workers=8) as ex:
        timed = list(ex.map(one, picks))
    wall = time.perf_counter() - t0
    svc.close()

    lats = [t for t, _ in timed]
    st = svc.stats()
    hot_fp = burst_res[0].program.fingerprint()

    # phase 3 — measured-mode spot check: a small measured service with
    # an on-disk DB; the restarted service must warm-start from it
    meas = _measured_spot_check()

    m = {
        "requests": st["requests"],
        "throughput_rps": n_req / wall,
        "p50_ms": 1e3 * _pct(lats, 50),
        "p99_ms": 1e3 * _pct(lats, 99),
        "coalesced": st["coalesced"],
        "coalesce_rate": st["coalesced"] / st["requests"],
        "burst_coalesced": burst_coalesced,
        "evictions": st["evictions"],
        "evicted_programs": st["evicted_programs"],
        "whole_store_resets": 0,     # mechanism removed: slabs only
        "hot_winner_cached": int(hot_fp in svc.store.programs),
        "store_programs": len(svc.store.programs),
        "all_correct": int(all(ok for _, ok in timed)
                           and all(r.correct for r in burst_res)),
        **{f"measured_{k}": v for k, v in meas.items()},
    }
    lines = [
        f"KernelService: {n_req} Zipf requests over {len(suite)} tasks, "
        f"8 client threads (+16-deep identical burst, {burst_s:.2f}s)",
        f"  throughput      : {m['throughput_rps']:.1f} req/s",
        f"  latency         : p50 {m['p50_ms']:.1f} ms, "
        f"p99 {m['p99_ms']:.1f} ms",
        f"  coalescing      : {m['coalesced']}/{m['requests']} requests "
        f"({100 * m['coalesce_rate']:.1f}%), "
        f"{m['burst_coalesced']}/15 possible on the burst",
        f"  store           : {m['store_programs']} programs, "
        f"{m['evictions']} slab evictions "
        f"({m['evicted_programs']} programs), "
        f"{m['whole_store_resets']} whole-store resets, "
        f"hot winner cached: {bool(m['hot_winner_cached'])}",
        f"  measured mode   : {m['measured_measured']} timed, "
        f"db {m['measured_db_hits']} hits / "
        f"{m['measured_db_misses']} misses, "
        f"{m['measured_warm_starts']} warm starts on restart, "
        f"reranked: {bool(m['measured_reranked'])}",
    ]
    return m, lines


def _measured_spot_check() -> dict:
    """Measured service + on-disk DB: counters for the stats row and the
    restart warm-start path (full coverage lives in measure_bench /
    tests; this keeps the serve-side counters honest in CI).  Sizes are
    fixed — already spot-check small in both CI and full runs."""
    import shutil
    import tempfile

    from repro.core import tasks as T
    from repro.measure.harness import MeasureConfig
    from repro.serve.engine import KernelService

    task = T.kb_level1()[0]
    db_dir = tempfile.mkdtemp(prefix="serve_bench_measure_db_")
    cfg = MeasureConfig(repeats=2, warmup=1)
    try:
        svc = KernelService(strategy="beam", measure=True,
                            measure_db=db_dir, rerank_top_k=3,
                            measure_cfg=cfg, max_steps=3)
        r1 = svc.optimize(task)
        st1 = svc.stats()
        svc.close()
        # a fresh process image of the service against the same DB dir:
        # the repeat request must warm-start (no search, no timing)
        svc2 = KernelService(strategy="beam", measure=True,
                             measure_db=db_dir, rerank_top_k=3,
                             measure_cfg=cfg, max_steps=3)
        r2 = svc2.optimize(task)
        st2 = svc2.stats()
        svc2.close()
    finally:
        shutil.rmtree(db_dir, ignore_errors=True)
    return {
        "measured": st1["measured"],
        "db_hits": st1["db_hits"],
        "db_misses": st1["db_misses"],
        "warm_starts": st2["warm_starts"],
        "reranked": int(r1.reranked),
        "warm_fp_match": int(r1.program.fingerprint()
                             == r2.program.fingerprint()),
        "warm_searchless": int(st2["fresh_applies"] == 0
                               and st2["measured"] == 0),
        "correct": int(r1.correct and r2.correct),
    }


# ---------------------------------------------------------------------------
# Engine stream
# ---------------------------------------------------------------------------

def _tiny_cfg():
    from repro.configs.registry import get_config, reduced
    cfg = reduced(get_config("qwen2_5_3b"))
    return dataclasses.replace(cfg, n_layers=2, d_model=64,
                               vocab_size=128, true_vocab_size=128)


def bench_engine(fast: bool) -> tuple[dict, list[str]]:
    import jax
    import jax.numpy as jnp
    from repro.models import api
    from repro.serve.engine import Engine, Request

    cfg = _tiny_cfg()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    n_req = 16 if fast else 48
    rng = np.random.default_rng(1)

    completions: list[float] = []

    class TimedEngine(Engine):
        def _retire(self, slot, s, pos):
            r = slot[s]
            was_done = r.done
            super()._retire(slot, s, pos)
            if r.done and not was_done:
                completions.append(time.perf_counter())

    eng = TimedEngine(cfg, params, max_len=64, batch_slots=4)
    prompts = [jnp.asarray(rng.integers(1, 100, rng.integers(1, 12)),
                           jnp.int32) for _ in range(n_req)]
    reqs = [Request(p, int(rng.integers(4, 13))) for p in prompts]
    want = [r.max_new_tokens for r in reqs]

    t0 = time.perf_counter()
    eng.run(reqs)
    wall = time.perf_counter() - t0
    lats = [c - t0 for c in completions]

    n_tok = sum(len(r.out) for r in reqs)
    st = eng.stats
    occ = st["occupancy_sum"] / max(st["decode_steps"], 1)
    # parity gate: mixed-length batched == solo, token-identical
    par_eng = Engine(cfg, params, max_len=64, batch_slots=4)
    outs = par_eng.generate(prompts[:6], max_new_tokens=5)
    parity = all(o == par_eng.generate([p], max_new_tokens=5)[0]
                 for p, o in zip(prompts[:6], outs))
    m = {
        "requests": n_req,
        "tokens": n_tok,
        "tok_per_s": n_tok / wall,
        "p50_ms": 1e3 * _pct(lats, 50),
        "p99_ms": 1e3 * _pct(lats, 99),
        "occupancy": occ,
        "truncations": st["truncations"],
        "budgets_met": int([len(r.out) for r in reqs] == want),
        "parity": int(parity),
    }
    lines = [
        f"Engine: {n_req} mixed-length requests (len 1-11, budgets "
        f"4-12) through 4 slots, token-level continuous batching",
        f"  throughput      : {m['tok_per_s']:.1f} tok/s "
        f"({n_tok} tokens in {wall:.2f}s)",
        f"  request latency : p50 {m['p50_ms']:.1f} ms, "
        f"p99 {m['p99_ms']:.1f} ms",
        f"  slot occupancy  : {100 * occ:.1f}% mean, "
        f"{st['truncations']} truncations, budgets met: "
        f"{bool(m['budgets_met'])}",
        f"  parity          : batched == solo token-identical: "
        f"{parity}",
    ]
    return m, lines


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke sizes")
    ap.add_argument("--out", default=os.path.join(RESULTS,
                                                  "serve_bench.txt"))
    ap.add_argument("--csv", default=os.path.join(RESULTS,
                                                  "serve_bench.csv"))
    args = ap.parse_args()

    svc_m, svc_lines = bench_service(args.fast)
    eng_m, eng_lines = bench_engine(args.fast)

    text = "\n".join(svc_lines + eng_lines) + "\n"
    print(text)
    os.makedirs(RESULTS, exist_ok=True)
    with open(args.out, "w") as f:
        f.write(text)
    with open(args.csv, "w") as f:
        f.write("name,us_per_call,derived\n")
        f.write(
            f"serve/service,{1e6 / svc_m['throughput_rps']:.1f},"
            f"coalesce_rate={svc_m['coalesce_rate']:.3f};"
            f"evictions={svc_m['evictions']};"
            f"resets={svc_m['whole_store_resets']};"
            f"hot_cached={svc_m['hot_winner_cached']};"
            f"p99_ms={svc_m['p99_ms']:.1f}\n")
        f.write(
            f"serve/measured,{svc_m['measured_measured']:.1f},"
            f"db_hits={svc_m['measured_db_hits']};"
            f"db_misses={svc_m['measured_db_misses']};"
            f"warm_starts={svc_m['measured_warm_starts']};"
            f"warm_searchless={svc_m['measured_warm_searchless']}\n")
        f.write(
            f"serve/engine,{1e6 / eng_m['tok_per_s']:.1f},"
            f"occupancy={eng_m['occupancy']:.2f};"
            f"parity={eng_m['parity']};"
            f"truncations={eng_m['truncations']};"
            f"p99_ms={eng_m['p99_ms']:.1f}\n")

    failures = []
    if svc_m["burst_coalesced"] <= 0:
        failures.append("coalescing hit-rate is 0 on the repeated-"
                        "request burst")
    if not svc_m["all_correct"]:
        failures.append("a service result failed the oracle")
    if svc_m["evictions"] >= 1 and not svc_m["hot_winner_cached"]:
        failures.append("slab eviction dropped the hot winner")
    if not eng_m["parity"]:
        failures.append("batched generation diverged from solo")
    if not eng_m["budgets_met"]:
        failures.append("a request missed its token budget")
    if not svc_m["measured_correct"]:
        failures.append("a measured-mode result failed the oracle")
    if not (svc_m["measured_warm_starts"] >= 1
            and svc_m["measured_warm_searchless"]
            and svc_m["measured_warm_fp_match"]):
        failures.append("measured-mode restart did not warm-start from "
                        "the on-disk DB")
    for msg in failures:
        print(f"FAIL: {msg}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
