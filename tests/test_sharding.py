"""ShardingRules unit + property tests (divisibility, padding, specs)."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs.base import normalize_for_mesh
from repro.configs.registry import ARCH_IDS, get_config
from repro.dist.sharding import ShardingRules
from repro.launch.mesh import make_local_mesh


@pytest.fixture(scope="module")
def rules():
    return ShardingRules(make_local_mesh())


def test_divisibility_replicates(rules):
    # 'model' axis has size 1 locally; use a fake 4-wide mesh via rule math
    spec = rules.spec((6, 8), ("heads", "embed"))
    assert isinstance(spec, P)


def test_padding_policy_all_archs():
    tp = 16
    for arch in ARCH_IDS:
        cfg = normalize_for_mesh(get_config(arch), tp)
        assert cfg.vocab_size % tp == 0, arch
        if cfg.n_kv_heads and cfg.n_kv_heads < cfg.n_heads:
            # GQA grouping must stay exact
            assert cfg.n_heads % cfg.n_kv_heads == 0, arch
        assert cfg.n_heads >= cfg.true_n_heads, arch
        assert cfg.vocab_size >= cfg.true_vocab_size, arch


def test_padding_specific_cases():
    tp = 16
    yi = normalize_for_mesh(get_config("yi_34b"), tp)
    assert yi.n_heads == 64 and yi.n_kv_heads == 8       # 56 -> 64
    hymba = normalize_for_mesh(get_config("hymba_1_5b"), tp)
    assert hymba.n_heads == 25                            # unpaddable GQA
    rwkv = normalize_for_mesh(get_config("rwkv6_3b"), tp)
    assert rwkv.n_heads == 48 and rwkv.n_kv_heads == 48   # MHA-style pad
    seam = normalize_for_mesh(get_config("seamless_m4t_medium"), tp)
    assert seam.n_heads == 16


@settings(max_examples=30, deadline=None)
@given(dim=st.integers(1, 4096))
def test_spec_divisibility_property(dim):
    """A sharded dim always divides the mesh axis product; otherwise the
    spec must replicate that dim."""
    mesh = make_local_mesh()
    rules = ShardingRules(mesh)
    spec = rules.spec((dim,), ("vocab",))
    axes = spec[0] if len(spec) > 0 else None
    if axes is not None:
        names = (axes,) if isinstance(axes, str) else tuple(axes)
        total = int(np.prod([mesh.shape[a] for a in names]))
        assert dim % total == 0


def test_no_duplicate_mesh_axes():
    mesh = make_local_mesh()
    rules = ShardingRules(mesh).with_fsdp()
    # expert and mlp both map to model: first-come-wins, no duplicates
    spec = rules.spec((4, 64, 128), ("expert", "embed", "mlp"))
    used = []
    for entry in spec:
        if entry is None:
            continue
        used += [entry] if isinstance(entry, str) else list(entry)
    assert len(used) == len(set(used))


def test_fsdp_rules_shard_embed():
    mesh = make_local_mesh()
    r0 = ShardingRules(mesh)
    r1 = r0.with_fsdp()
    assert r0.rules["embed"] == ()
    assert r1.rules["embed"] == ("data",)
