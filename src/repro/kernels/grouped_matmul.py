"""Grouped (per-expert) matmul for MoE (Pallas TPU).

x: (E, C, D) @ w: (E, D, F) -> (E, C, F); one grid axis per expert so each
expert's GEMM tiles stream independently (EP shards the E axis across the
mesh's model dimension).  Schedule: bc/bf/bd tiles + loop order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.schedule import KernelSchedule, default_schedule


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, nd: int):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(ki == nd - 1)
    def _fin():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("schedule", "interpret"))
def grouped_matmul(x: jax.Array, w: jax.Array, *,
                   schedule: KernelSchedule | None = None,
                   interpret: bool = False) -> jax.Array:
    s = schedule or default_schedule("grouped_matmul")
    E, C, D = x.shape
    _, _, F = w.shape
    bc = min(s.block("bc", 128), C)
    bf = min(s.block("bf", 128), F)
    bd = min(s.block("bd", 128), D)
    assert C % bc == 0 and F % bf == 0 and D % bd == 0
    grid = (E, C // bc, F // bf, D // bd)
    out = pl.pallas_call(
        functools.partial(_kernel, nd=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bd, bf), lambda e, i, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w.astype(x.dtype))
    return out
