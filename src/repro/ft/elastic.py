"""Elastic scaling: recompute the mesh when pods/hosts join or leave.

Checkpoints are topology-agnostic (ckpt stores full logical arrays), so a
rescale is: pick the new mesh shape -> rebuild ShardingRules -> device_put
the restored state under the new shardings -> resume at the same step.
The data pipeline is a pure function of (seed, step, row), so the global
batch is identical across topologies => loss curves continue exactly.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh

from repro.dist.sharding import ShardingRules


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple[int, ...]
    mesh_axes: tuple[str, ...]
    dropped_replicas: tuple[int, ...] = ()

    def make_mesh(self) -> Mesh:
        return jax.make_mesh(self.mesh_shape, self.mesh_axes)


def elastic_plan(n_chips: int, *, model_parallel: int = 16,
                 pods: int = 1) -> ElasticPlan:
    """Largest (pod, data, model) mesh fitting the surviving chips.

    Keeps TP fixed (param shardability is arch-determined) and shrinks the
    data axis — dropping one host of a 256-chip pod gives data=15 etc.
    """
    per_pod = n_chips // pods
    data = max(1, per_pod // model_parallel)
    if pods > 1:
        return ElasticPlan((pods, data, model_parallel),
                           ("pod", "data", "model"))
    return ElasticPlan((data, model_parallel), ("data", "model"))


def remesh_state(state_tree, shardings):
    """Move a restored (host) state onto the new mesh's shardings."""
    return jax.tree.map(jax.device_put, state_tree, shardings)


def survivors_after_failure(mesh: Mesh, failed_hosts: list[int],
                            chips_per_host: int = 4) -> int:
    total = mesh.devices.size
    return total - len(failed_hosts) * chips_per_host


def rescale_rules(mesh: Mesh, fsdp: bool = True) -> ShardingRules:
    rules = ShardingRules(mesh)
    return rules.with_fsdp() if fsdp else rules
