"""Hypothesis compatibility layer for this test suite.

The real ``hypothesis`` package is used when installed.  When it is not
(this container does not ship it and the repo pins no test extras), a
minimal deterministic fallback provides the tiny subset the suite uses:
``@settings(max_examples=..., deadline=...)``, ``@given(name=strategy)``,
``st.integers(lo, hi)`` and ``st.sampled_from(seq)``.

The fallback draws a fixed, seeded sample (boundary values first, then
uniform draws), so tests are reproducible property *spot checks* rather
than shrinking searches — good enough to keep the invariants exercised
in environments without hypothesis.
"""
from __future__ import annotations

try:                                        # pragma: no cover
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sampler, edges=()):
            self.sampler = sampler
            self.edges = list(edges)

        def draws(self, n, rng):
            out = list(self.edges[:n])
            while len(out) < n:
                out.append(self.sampler(rng))
            return out

    class strategies:                       # noqa: N801 (mimic module)
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                edges=[min_value, max_value])

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(
                lambda rng: items[int(rng.integers(len(items)))],
                edges=items[:2])

        @staticmethod
        def floats(min_value, max_value):
            span = max_value - min_value
            return _Strategy(
                lambda rng: float(min_value + span * rng.random()),
                edges=[min_value, max_value])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)),
                             edges=[False, True])

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 20)
                rng = np.random.default_rng(0)
                draws = {k: s.draws(n, rng) for k, s in strats.items()}
                for i in range(n):
                    fn(*args, **{k: v[i] for k, v in draws.items()},
                       **kwargs)
            # hide the drawn parameters from pytest's fixture resolution
            # (the real hypothesis does the same)
            wrapper.__dict__.pop("__wrapped__", None)
            params = [p for p in
                      inspect.signature(fn).parameters.values()
                      if p.name not in strats]
            wrapper.__signature__ = inspect.Signature(params)
            return wrapper
        return deco
