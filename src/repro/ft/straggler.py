"""Straggler detection + mitigation hooks.

On a real fleet each host reports step wall-time; the monitor keeps a
rolling watermark and flags hosts/steps exceeding ``threshold x p50``.
Mitigations exposed as hooks (the runtime wiring in launch/train.py):

  * ``should_checkpoint_now`` — preemptively snapshot when slowdowns
    cluster (disk/network degradation often precedes node death),
  * ``replicas_to_evict``    — replicas whose step time stays above the
    watermark for ``patience`` consecutive steps (elastic re-mesh then
    drops them via ft.elastic),
  * backup-task semantics for input pipeline (data.pipeline is stateless
    per (step, host), so any host can recompute another host's shard —
    that IS the straggler work-stealing story for data).
"""
from __future__ import annotations

import collections
import statistics
from dataclasses import dataclass, field


@dataclass
class StragglerMonitor:
    threshold: float = 2.0          # x median => straggler
    patience: int = 3               # consecutive slow steps => evict
    window: int = 50
    _times: dict = field(default_factory=dict)      # replica -> deque
    _slow_streak: dict = field(default_factory=dict)
    events: list = field(default_factory=list)

    def record(self, step: int, seconds: float, replica: int = 0) -> None:
        dq = self._times.setdefault(
            replica, collections.deque(maxlen=self.window))
        dq.append(seconds)
        med = self.median()
        if med and seconds > self.threshold * med:
            self._slow_streak[replica] = self._slow_streak.get(replica,
                                                               0) + 1
            self.events.append({"step": step, "replica": replica,
                                "sec": seconds, "median": med})
        else:
            self._slow_streak[replica] = 0

    def median(self) -> float:
        all_t = [t for dq in self._times.values() for t in dq]
        return statistics.median(all_t) if len(all_t) >= 5 else 0.0

    def replicas_to_evict(self) -> list[int]:
        return [r for r, s in self._slow_streak.items()
                if s >= self.patience]

    def should_checkpoint_now(self) -> bool:
        recent = self.events[-self.patience:]
        return len(recent) >= self.patience and \
            len({e["replica"] for e in recent}) >= 2
