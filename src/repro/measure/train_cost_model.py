"""Fit a ``LearnedCostModel`` artifact from MeasureDB directories.

    PYTHONPATH=src python -m repro.measure.train_cost_model \
        results/measure_db --out results/learned_cost_model.pkl

Samples are exported through ``MeasureDB.iter_samples`` (deterministic
order, corrupt records skipped+counted), filtered by ``--target`` /
``--env-fp`` when given, and fit with the group-normalized ridge of
``measure/learned.py``.  Exits non-zero when nothing trainable survives
(no program-embedding samples, or no candidate group with >= 2 of
them), so CI catches an accidentally empty DB instead of committing an
identity artifact.
"""
from __future__ import annotations

import argparse
import sys


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.measure.train_cost_model",
        description="fit a learned cost model from MeasureDB samples")
    ap.add_argument("dbs", nargs="+", metavar="DB",
                    help="MeasureDB directory (repeatable)")
    ap.add_argument("--out", required=True,
                    help="artifact path (.pkl)")
    ap.add_argument("--target", default=None,
                    help="only samples priced for this hardware target")
    ap.add_argument("--env-fp", default=None,
                    help="only samples from this env fingerprint")
    ap.add_argument("--ridge", type=float, default=1.0,
                    help="ridge regularization lambda (default 1.0)")
    ap.add_argument("--min-group", type=int, default=2,
                    help="min samples per (task,target,env) group")
    ap.add_argument("--allow-mixed-envs", action="store_true",
                    help="permit samples spanning env fingerprints "
                         "(group normalization makes them rankable; "
                         "absolute scale averages regimes)")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    from repro.measure.db import MeasureDB
    from repro.measure.learned import fit_learned_model

    def samples():
        for root in args.dbs:
            yield from MeasureDB(root).iter_samples(
                target=args.target, env_fp=args.env_fp)

    try:
        model = fit_learned_model(
            samples(), ridge_lambda=args.ridge,
            min_group=args.min_group,
            allow_mixed_envs=args.allow_mixed_envs,
            extra_meta={"dbs": sorted(args.dbs)})
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    if model is None:
        print("error: no trainable samples (need program-embedding "
              "samples in groups of >= 2 per (task, target, env))",
              file=sys.stderr)
        return 1
    model.save(args.out)
    if not args.quiet:
        m = model.meta
        print(f"wrote {args.out}: {m['n_samples']} samples / "
              f"{m['n_groups']} groups, targets={m['targets']}, "
              f"fit rho={m['spearman_fit']:.3f} "
              f"(skipped: {m['n_skipped_no_program']} without program, "
              f"{m['n_skipped_bad']} bad)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
