"""RWKV6 (Finch) chunked recurrence kernel (Pallas TPU).

Grid (B, H, n_chunks): the chunk axis is sequential ("arbitrary") and the
per-(b,h) decay state S (dk x dv, f32) lives in VMEM scratch across chunk
iterations — the TPU-native replacement for the CUDA wkv kernel's
per-warp registers.  Within a chunk, all pairwise-decay exponents are
differences of log-decay cumsums and hence <= 0 (numerically safe at any
decay magnitude; see kernels/ref.py docstring for the algebra).

Schedule: chunk length (Tiling), pipeline_depth (Pipeline).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.schedule import KernelSchedule, default_schedule


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sout_ref,
            S, *, nc: int, c: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        S[...] = s0_ref[0, 0].astype(jnp.float32)

    rc = r_ref[0, 0].astype(jnp.float32)        # (c, dk)
    kc = k_ref[0, 0].astype(jnp.float32)
    vc = v_ref[0, 0].astype(jnp.float32)        # (c, dv)
    wc = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)            # (dk,)

    lw = jnp.log(jnp.maximum(wc, 1e-26))        # <= 0, finite (w may
    # underflow to 0 at strong decay; -inf cumsum diffs would be NaN)
    ccum = jnp.cumsum(lw, axis=0)               # inclusive (c, dk)
    ecum = ccum - lw                            # exclusive

    o_inter = jnp.dot(rc * jnp.exp(ecum), S[...],
                      preferred_element_type=jnp.float32)      # (c, dv)

    diff = ecum[:, None, :] - ccum[None, :, :]                 # (c,c,dk)
    tri = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    dec = jnp.where(tri[..., None], jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    A = jnp.sum(rc[:, None, :] * kc[None, :, :] * dec, axis=-1)  # (c,c)
    diag = jnp.sum(rc * u[None, :] * kc, axis=-1)                # (c,)
    eye = (jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) ==
           jax.lax.broadcasted_iota(jnp.int32, (c, c), 1))
    A = A + jnp.where(eye, diag[:, None], 0.0)
    o = o_inter + jnp.dot(A, vc, preferred_element_type=jnp.float32)
    o_ref[0, 0] = o.astype(o_ref.dtype)

    rem = ccum[-1:, :] - ccum                                  # <= 0
    kd = kc * jnp.exp(rem)
    S[...] = jnp.exp(ccum[-1])[:, None] * S[...] + jnp.dot(
        kd.T, vc, preferred_element_type=jnp.float32)

    @pl.when(ti == nc - 1)
    def _fin():
        sout_ref[0, 0] = S[...]


@functools.partial(jax.jit, static_argnames=("schedule", "interpret"))
def rwkv6_scan(r, k, v, w, u, state=None, *,
               schedule: KernelSchedule | None = None,
               interpret: bool = False):
    """r,k,w: (B,T,H,dk); v: (B,T,H,dv); u: (H,dk); state: (B,H,dk,dv).
    Returns (o (B,T,H,dv), state (B,H,dk,dv) f32)."""
    s = schedule or default_schedule("rwkv6_scan")
    B, T, H, dk = r.shape
    dv = v.shape[-1]
    c = min(s.block("chunk", 64), T)
    assert T % c == 0
    nc = T // c
    if state is None:
        state = jnp.zeros((B, H, dk, dv), jnp.float32)
    rt, kt, vt, wt = (a.transpose(0, 2, 1, 3) for a in (r, k, v, w))

    o, s_out = pl.pallas_call(
        functools.partial(_kernel, nc=nc, c=c),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, c, dk), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, c, dk), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, c, dv), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, c, dk), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, dk), lambda b, h, t: (h, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, dv), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, dv), r.dtype),
            jax.ShapeDtypeStruct((B, H, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(rt, kt, vt, wt, u, state)
    return o.transpose(0, 2, 1, 3), s_out
