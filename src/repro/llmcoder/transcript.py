"""Content-addressed record/replay of micro-coder LLM exchanges.

Every request the verify-and-repair loop sends to a ``CoderBackend`` is
keyed by ``(task_fp, prog_fp, action_key, attempt)`` — the full identity
of *which question was asked*:

  task_fp     the optimization request's root program (scopes a
              recording session to the task it was captured under);
  prog_fp     the parent program the delta is proposed against;
  action_key  the Macro action being implemented (``env.action_key``);
  attempt     the repair round.  The attempt index MUST be part of the
              key: attempt 0 and attempt 2 carry different prompts (the
              later one embeds the rendered diagnostics of the earlier
              failures) and a real LLM answers them differently, so a
              replay that collapsed attempts would hand the repair loop
              answer N for question 0 and silently skip the repair path
              it is supposed to reproduce (DESIGN.md §16).

Records are JSON-lines files sharded by ``task_fp`` prefix so a
recording session adds one reviewable file per task rather than
hundreds of blobs.  The response field holds the backend's raw
completion (the program JSON for a successful proposal); non-transient
backend refusals are recorded too (``error``), so replay reproduces
failures as faithfully as successes.  Committed fixtures live under
``tests/fixtures/llm_transcripts/`` and are swept by
``python -m repro.analysis.lint --transcripts``.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading


def transcript_key(task_fp: str, prog_fp: str, action_key: str,
                   attempt: int) -> str:
    """Stable content address of one request identity."""
    raw = f"{task_fp}|{prog_fp}|{action_key}|{int(attempt)}"
    return hashlib.sha256(raw.encode()).hexdigest()[:24]


def make_record(task_fp: str, prog_fp: str, action_key: str,
                attempt: int, *, prompt: str = "",
                response: str | None = None,
                error: str | None = None) -> dict:
    """One transcript record.  The prompt itself is reconstructible
    from (program, action, feedback), so only its hash is stored — the
    committed fixtures stay reviewable and small while replay can still
    detect a prompt-schema drift (``ReplayBackend`` warns via detail,
    it does not refuse: the recorded ANSWER is still the answer to the
    recorded question identity)."""
    return {
        "key": transcript_key(task_fp, prog_fp, action_key, attempt),
        "task_fp": task_fp,
        "prog_fp": prog_fp,
        "action_key": action_key,
        "attempt": int(attempt),
        "prompt_sha": hashlib.sha256(prompt.encode()).hexdigest()[:16],
        "response": response,
        "error": error,
    }


class TranscriptStore:
    """Directory of ``*.jsonl`` transcript shards with an in-memory
    index.  Thread-safe; writes are append-only and idempotent (a
    record whose key is already present is not re-written, so a
    re-recording session leaves committed fixtures byte-stable)."""

    def __init__(self, root: str):
        self.root = root
        self._lock = threading.Lock()
        self._by_key: dict[str, dict] = {}
        # exact and any-task lookups (see ReplayBackend's fallback)
        self._exact: dict[tuple[str, str, str, int], str] = {}
        self._by_edge: dict[tuple[str, str, int], list[str]] = {}
        if os.path.isdir(root):
            for fn in sorted(os.listdir(root)):
                if fn.endswith(".jsonl"):
                    self._load_shard(os.path.join(root, fn))

    def _load_shard(self, path: str) -> None:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue       # lint --transcripts reports these
                self._index(rec)

    def _index(self, rec: dict) -> None:
        key = rec.get("key")
        if not key or key in self._by_key:
            return
        self._by_key[key] = rec
        ident = (rec.get("task_fp"), rec.get("prog_fp"),
                 rec.get("action_key"), int(rec.get("attempt", 0)))
        self._exact[ident] = key
        self._by_edge.setdefault(ident[1:], []).append(key)

    # -- lookup --------------------------------------------------------------
    def lookup(self, task_fp: str, prog_fp: str, action_key: str,
               attempt: int) -> dict | None:
        with self._lock:
            key = self._exact.get((task_fp, prog_fp, action_key,
                                   int(attempt)))
            return self._by_key.get(key) if key else None

    def lookup_any(self, prog_fp: str, action_key: str,
                   attempt: int) -> dict | None:
        """Any-task fallback: the same (parent, action, attempt) edge
        recorded under a different task root.  Sound because the coder
        contract requires task-independence of the answer (the same
        contract that lets ``TranspositionStore`` share edges across
        tasks); first recorded wins deterministically."""
        with self._lock:
            keys = self._by_edge.get((prog_fp, action_key, int(attempt)))
            return self._by_key[keys[0]] if keys else None

    # -- record --------------------------------------------------------------
    def put(self, rec: dict) -> str:
        key = rec["key"]
        with self._lock:
            if key in self._by_key:
                return key
            self._index(rec)
        os.makedirs(self.root, exist_ok=True)
        shard = os.path.join(self.root,
                             f"{rec['task_fp'][:16] or 'anon'}.jsonl")
        line = json.dumps(rec, sort_keys=True)
        with self._lock:
            with open(shard, "a") as f:
                f.write(line + "\n")
        return key

    # -- sweep (lint --transcripts) ------------------------------------------
    def records(self) -> list[dict]:
        with self._lock:
            return list(self._by_key.values())

    def __len__(self) -> int:
        with self._lock:
            return len(self._by_key)
