"""PPO training of the Macro Thinking policy on offline trees.

Standard clipped PPO + GAE over episodes rolled out in ``OfflineEnv``s
(one tree per training task).  The policy's action distribution is the
TWOSOME softmax over candidate-action token log-prob sums (policy.py);
gradients flow through the token log-probs of the chosen action relative
to the other candidates.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.env import EnvConfig, OfflineEnv, OfflineTree
from repro.core.policy import (MacroPolicy, PolicyConfig,
                               build_candidate_batch, policy_forward)
from repro.optim import adamw


@dataclasses.dataclass
class PPOConfig:
    lr: float = 3e-4
    clip: float = 0.2
    gamma: float = 0.98
    lam: float = 0.95
    value_coef: float = 0.5
    entropy_coef: float = 0.01
    epochs_per_iter: int = 2
    episodes_per_iter: int = 8
    iters: int = 30
    max_candidates: int = 40
    seed: int = 0


@dataclasses.dataclass
class Transition:
    tokens: np.ndarray        # (N_cand, T)
    mask: np.ndarray          # (N_cand, T)
    chosen: int
    logp_old: float
    reward: float
    value_old: float
    done: bool


def _pad_cands(tokens, mask, n: int):
    """Pad candidate axis to fixed n (rows of PADs get -inf scores)."""
    N, T = tokens.shape
    if N >= n:
        return tokens[:n], mask[:n], min(N, n)
    pt = np.zeros((n - N, T), tokens.dtype)
    pm = np.zeros((n - N, T), mask.dtype)
    return np.concatenate([tokens, pt]), np.concatenate([mask, pm]), N


def make_loss_fn(pcfg: PolicyConfig, cfg: PPOConfig):
    def loss_fn(params, batch):
        tokens = batch["tokens"]          # (B, NC, T)
        mask = batch["mask"]
        B, NC, T = tokens.shape
        flat_t = tokens.reshape(B * NC, T)
        flat_m = mask.reshape(B * NC, T)
        logits, values = policy_forward(pcfg, params, flat_t)
        logp = jax.nn.log_softmax(logits, -1)
        tgt = flat_t[:, 1:]
        lp = jnp.take_along_axis(logp[:, :-1], tgt[..., None], -1)[..., 0]
        m = flat_m[:, 1:]
        norm = (lp * m).sum(-1) / jnp.maximum(m.sum(-1), 1.0)
        norm = norm.reshape(B, NC)
        valid = batch["cand_valid"]                     # (B, NC)
        norm = jnp.where(valid, norm, -1e30)
        alogp = jax.nn.log_softmax(norm, -1)
        chosen_lp = jnp.take_along_axis(
            alogp, batch["chosen"][:, None], 1)[:, 0]
        # value of the state = value head on the chosen row (state tokens
        # dominate the pooled encoding)
        v = values.reshape(B, NC)[jnp.arange(B), batch["chosen"]]

        ratio = jnp.exp(chosen_lp - batch["logp_old"])
        adv = batch["adv"]
        adv = (adv - adv.mean()) / (adv.std() + 1e-6)
        pg = -jnp.minimum(
            ratio * adv,
            jnp.clip(ratio, 1 - cfg.clip, 1 + cfg.clip) * adv).mean()
        v_loss = jnp.mean(jnp.square(v - batch["returns"]))
        ent = -jnp.sum(jnp.exp(alogp) * jnp.where(valid, alogp, 0.0),
                       -1).mean()
        loss = pg + cfg.value_coef * v_loss - cfg.entropy_coef * ent
        return loss, {"pg": pg, "v_loss": v_loss, "entropy": ent}
    return loss_fn


class PPOTrainer:
    def __init__(self, trees: dict[str, OfflineTree],
                 pcfg: PolicyConfig | None = None,
                 cfg: PPOConfig | None = None,
                 env_cfg: EnvConfig | None = None):
        # all config defaults are None -> fresh per call: a dataclass-
        # instance default is constructed once at import time and (for
        # the mutable PPOConfig/EnvConfig) SHARED by every trainer;
        # PolicyConfig is frozen but gets the same hygiene so no config
        # object is ever built at import time (DESIGN.md §14)
        self.trees = trees
        self.pcfg = pcfg = pcfg if pcfg is not None else PolicyConfig()
        self.cfg = cfg = cfg if cfg is not None else PPOConfig()
        self.env_cfg = env_cfg if env_cfg is not None else EnvConfig()
        self.policy = MacroPolicy(pcfg, jax.random.PRNGKey(cfg.seed))
        self.opt_cfg = adamw.AdamWConfig(lr=cfg.lr, warmup_steps=10,
                                         total_steps=cfg.iters *
                                         cfg.epochs_per_iter,
                                         grad_clip=1.0, weight_decay=0.0)
        self.opt_state = adamw.init(self.policy.params)
        self.loss_fn = make_loss_fn(pcfg, cfg)
        self._grad = jax.jit(jax.value_and_grad(self.loss_fn,
                                                has_aux=True))
        self.log: list[dict] = []

    # -- rollouts -----------------------------------------------------------
    def _rollout(self, env: OfflineEnv, key) -> tuple[list[Transition],
                                                      float]:
        traj: list[Transition] = []
        env.reset()
        final_speedup = 1.0
        for _t in range(self.env_cfg.max_steps):
            prog = env.program()
            cands = env.candidates()[: self.cfg.max_candidates]
            tokens, mask, _ = build_candidate_batch(self.pcfg, prog,
                                                    cands)
            tokens, mask, n_valid = _pad_cands(
                tokens, mask, self.cfg.max_candidates)
            logp_all, value = self.policy.action_dist(prog,
                                                      cands)
            key, sub = jax.random.split(key)
            idx = int(jax.random.categorical(sub, jnp.asarray(logp_all)))
            res = env.step(cands[idx])
            final_speedup = res.info.get("speedup", final_speedup)
            traj.append(Transition(tokens, mask, idx,
                                   float(logp_all[idx]), res.reward,
                                   value, res.done))
            if res.done:
                break
        return traj, final_speedup

    def _gae(self, traj: list[Transition]):
        cfg = self.cfg
        adv = np.zeros(len(traj), np.float32)
        last = 0.0
        for i in reversed(range(len(traj))):
            next_v = 0.0 if (i == len(traj) - 1 or traj[i].done) \
                else traj[i + 1].value_old
            delta = traj[i].reward + cfg.gamma * next_v - \
                traj[i].value_old
            nonterm = 0.0 if traj[i].done else 1.0
            last = delta + cfg.gamma * cfg.lam * nonterm * last
            adv[i] = last
        returns = adv + np.array([t.value_old for t in traj], np.float32)
        return adv, returns

    # -- outer loop -----------------------------------------------------------
    def train(self, iters: int | None = None) -> MacroPolicy:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed)
        key = jax.random.PRNGKey(cfg.seed + 1)
        names = list(self.trees)
        for it in range(iters or cfg.iters):
            batch_tr: list[Transition] = []
            advs, rets, speedups = [], [], []
            for _e in range(cfg.episodes_per_iter):
                tree = self.trees[names[rng.integers(len(names))]]
                env = OfflineEnv(tree, self.env_cfg)
                key, sub = jax.random.split(key)
                traj, sp = self._rollout(env, sub)
                a, r = self._gae(traj)
                batch_tr += traj
                advs.append(a)
                rets.append(r)
                speedups.append(sp)
            adv = np.concatenate(advs)
            ret = np.concatenate(rets)
            batch = {
                "tokens": jnp.asarray(
                    np.stack([t.tokens for t in batch_tr])),
                "mask": jnp.asarray(np.stack([t.mask for t in batch_tr])),
                "cand_valid": jnp.asarray(np.stack(
                    [t.mask.any(-1) for t in batch_tr])),
                "chosen": jnp.asarray(
                    np.array([t.chosen for t in batch_tr], np.int32)),
                "logp_old": jnp.asarray(
                    np.array([t.logp_old for t in batch_tr],
                             np.float32)),
                "adv": jnp.asarray(adv),
                "returns": jnp.asarray(ret),
            }
            for _ in range(cfg.epochs_per_iter):
                (loss, aux), grads = self._grad(self.policy.params, batch)
                self.policy.params, self.opt_state, _ = adamw.update(
                    self.opt_cfg, grads, self.opt_state,
                    self.policy.params)
            mean_r = float(np.mean([t.reward for t in batch_tr]))
            self.log.append({
                "iter": it, "loss": float(loss),
                "mean_reward": mean_r,
                "mean_final_speedup": float(np.mean(speedups)),
                "entropy": float(aux["entropy"]),
            })
        return self.policy
