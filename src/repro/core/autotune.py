"""Framework integration: MTMC as the kernel autotuner.

``tune_model_kernels(cfg, shape)`` builds a KernelProgram per hot kernel
of the architecture (attention geometry, the big GEMMs, scans, MoE
grouped matmul), runs the MTMC pipeline on it, and installs the winning
schedule into the kernel registry (``kernels.ops.set_schedule``) that the
model forwards consult on TPU.  This is the paper's technique running as
a first-class framework feature rather than a side tool.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import tasks as T
from repro.core.config import UNSET, OptimizeConfig, resolve_config
from repro.core.pipeline import MTMCPipeline
from repro.kernels import ops


def _gemm_task(name, m, k, n):
    from repro.core.kernel_ir import chain_program
    return chain_program(name, {"a": (m, k), "b": (k, n)},
                         [("y", "matmul", ("a", "b"))])


def model_kernel_tasks(cfg: ModelConfig, shape: ShapeConfig,
                       tokens_cap: int = 2048) -> dict[str, tuple]:
    """(task, kernel_name, schedule_key) per hot kernel.

    Shapes are capped for CPU-side evaluation; the schedule key matches
    what ops.get_schedule looks up at trace time.
    """
    S = min(shape.seq_len, tokens_cap)
    B = max(1, min(shape.global_batch, 2))
    D, FF, hd = cfg.d_model, cfg.d_ff, cfg.head_dim
    H = min(cfg.n_heads, 8)
    out = {}
    if cfg.family in ("dense", "moe", "vlm", "hybrid", "encdec"):
        out["attention"] = (
            T._attn_program(f"{cfg.name}_attn", B, S, H, hd),
            "flash_attention", f"S{shape.seq_len}")
    m = min(B * S, tokens_cap)
    out["ffn_gemm"] = (_gemm_task(f"{cfg.name}_ffn", m, D, FF),
                       "matmul", f"({m}, {D})x({D}, {FF})")
    out["qkv_gemm"] = (_gemm_task(f"{cfg.name}_qkv", m, D,
                                  cfg.n_heads * hd),
                       "matmul", f"({m}, {D})x({D}, {cfg.n_heads * hd})")
    if cfg.family == "rwkv":
        out["rwkv"] = (T._rwkv_task(f"{cfg.name}_rwkv", B, S,
                                    min(cfg.n_heads, 8), hd),
                       "rwkv6_scan", f"T{shape.seq_len}")
    if cfg.family == "hybrid":
        out["ssm"] = (T._ssm_task(f"{cfg.name}_ssm", B, S, 4, 128,
                                  cfg.ssm_state),
                      "ssm_scan", f"T{shape.seq_len}")
    if cfg.family == "moe":
        from repro.models.moe import capacity
        C = min(capacity(cfg, B * S), 1024)
        out["moe"] = (T._moe_task(f"{cfg.name}_moe",
                                  min(cfg.n_experts, 8), C, D, FF),
                      "grouped_matmul",
                      f"({cfg.n_experts}, {C}, {D})")
    return out


#: historical tuner defaults: cheap greedy descent, oracle off (the
#: tuner's winners are schedule-only rewrites, proven structurally)
TUNE_DEFAULTS = OptimizeConfig(mode="greedy_cost", validate=False,
                               max_steps=6)


def tune_model_kernels(cfg: ModelConfig, shape: ShapeConfig,
                       pipeline: MTMCPipeline | None = None,
                       config: OptimizeConfig | None = None,
                       target=UNSET, strategy=UNSET,
                       measurer=UNSET, rerank_top_k=UNSET) -> dict:
    """Runs MTMC per hot kernel; installs schedules; returns report.

    ``config`` (an ``OptimizeConfig``) is the one knob surface; its
    ``target`` selects the hardware target the schedules are tuned
    against AND the registry slot they are installed under
    (``ops.set_schedule(..., target=...)``) — tuning for several chips
    fills independent slots and ``ops.set_active_target`` picks at
    serve time.  ``strategy`` optionally swaps the default greedy
    descent for a search strategy ("beam", "anneal", "policy").
    ``measurer`` (a ``measure.ExecutionHarness``) + ``rerank_top_k`` > 0
    turn on measured reranking: the installed schedule is the one whose
    program actually ran fastest, not the analytic pick (DESIGN.md §11).
    The flat target/strategy/measurer/rerank_top_k kwargs are
    deprecation shims over ``config``.
    """
    legacy = {"target": target, "strategy": strategy,
              "measurer": measurer, "rerank_top_k": rerank_top_k}
    has_overrides = (config is not None
                     or any(v is not UNSET for v in legacy.values()))
    if pipeline is not None and has_overrides:
        raise ValueError("pass either an explicit pipeline or "
                         "config/target/strategy/measurer/rerank_top_k "
                         "overrides, not both (the pipeline already "
                         "fixes its own)")
    if pipeline is None:
        oc = resolve_config("tune_model_kernels", config, legacy,
                            defaults=TUNE_DEFAULTS)
        pipeline = MTMCPipeline(config=oc)
    report = {}
    for kname, (task, kernel, key) in model_kernel_tasks(cfg,
                                                         shape).items():
        res = pipeline.optimize(task)
        sched = _extract_schedule(res.program, kernel)
        if sched is not None:
            ops.set_schedule(kernel, key, sched, target=pipeline.target)
        report[kname] = {"speedup": res.speedup, "correct": res.correct,
                         "schedule": sched, "trace": res.trace,
                         "target": pipeline.target.name,
                         "measured_s": res.measured_s,
                         "reranked": res.reranked}
    return report


def _extract_schedule(prog, kernel_kind: str):
    from repro.core.kernel_ir import sched_kind_of_group
    for g in prog.fusion_groups:
        if sched_kind_of_group(prog, g) == kernel_kind:
            return prog.schedule_for(g)
    return None
