"""Kernel schedules — the knobs the MTMC actions turn.

A ``KernelSchedule`` is the concrete, hardware-level realisation of the
semantic optimization state for one kernel:

  * Tiling     -> ``blocks``        (VMEM BlockSpec tile sizes)
  * Fusion     -> ``epilogue``      (fused producer/epilogue op)
  * Pipeline   -> ``pipeline_depth``(HBM->VMEM multi-buffering depth)
  * Reordering -> ``loop_order``    (grid-axis iteration order)

``core.micro_coding`` rewrites these; ``core.cost_model`` prices them;
the Pallas kernels below consume them.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Mapping


@dataclasses.dataclass(frozen=True)
class KernelSchedule:
    # ``blocks`` accepts a dict but is stored as a sorted tuple of pairs so
    # schedules are hashable (jit static args).
    blocks: tuple = dataclasses.field(default_factory=tuple)
    loop_order: tuple[str, ...] = ()
    pipeline_depth: int = 2           # 1 = no double buffering
    epilogue: str = "none"
    flags: tuple[str, ...] = ()       # free-form feature toggles

    def __post_init__(self):
        if isinstance(self.blocks, Mapping):
            object.__setattr__(self, "blocks",
                               tuple(sorted(self.blocks.items())))
        object.__setattr__(self, "loop_order", tuple(self.loop_order))
        object.__setattr__(self, "flags", tuple(self.flags))

    @property
    def blocks_dict(self) -> dict[str, int]:
        return dict(self.blocks)

    def block(self, name: str, default: int) -> int:
        return int(self.blocks_dict.get(name, default))

    def replace(self, **kw) -> KernelSchedule:
        if isinstance(kw.get("blocks"), Mapping):
            kw["blocks"] = tuple(sorted(kw["blocks"].items()))
        return dataclasses.replace(self, **kw)


DEFAULTS: dict[str, KernelSchedule] = {
    "matmul": KernelSchedule(blocks={"bm": 128, "bn": 128, "bk": 128},
                             loop_order=("m", "n", "k")),
    "flash_attention": KernelSchedule(blocks={"bq": 128, "bk": 128}),
    "rmsnorm": KernelSchedule(blocks={"rows": 256}),
    "rwkv6_scan": KernelSchedule(blocks={"chunk": 64}),
    "ssm_scan": KernelSchedule(blocks={"chunk": 64}),
    "grouped_matmul": KernelSchedule(
        blocks={"bc": 128, "bf": 128, "bd": 128},
        loop_order=("c", "f", "d")),
}


def default_schedule(kernel: str) -> KernelSchedule:
    return DEFAULTS.get(kernel, KernelSchedule())
