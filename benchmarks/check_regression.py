"""CI gate: fail when execute-accuracy or rank-correlation regressed.

Compares a freshly produced CSV against the committed baseline, for
every row name present in BOTH files:

* ``acc=`` (execute accuracy): the new value must be >= the baseline's
  within a 1e-9 float-print slack — accuracy is the correctness
  contract and never gets measurement slack.
* ``rho=`` (Spearman rank correlation between analytic cost and
  measured runtime, ``benchmarks.measure_bench``): the new value must
  be >= baseline - ``RHO_SLACK``.  Rank correlations come from real
  wall-clock timings, so a generous slack absorbs machine noise while
  a committed floor still catches a cost model or harness that stopped
  tracking reality.
* ``rho_learn=`` (same bench): the learned cost model's mean per-task
  candidate rank correlation under leave-one-task-out cross
  validation.  Same wall-clock-noise slack as ``rho=``; the bench
  itself additionally asserts ``rho_learn`` beats the per-task
  calibrated rho per target, so this gate guards the committed level
  while the in-bench check guards the learned-vs-calibrated ordering.
* ``rules_improved_frac=`` (``benchmarks.table9_rules``): the fraction
  of tasks where the extended rewrite-rule registry strictly improves
  the classic search.  Fully analytic and deterministic, so it gets no
  slack: a registry or cost-model change that silently neuters the
  extension rules fails CI.
* ``warm_rate=`` (``benchmarks.serve_bench`` fleet rows): the fraction
  of repeat requests a restarted/peer replica answers straight from
  the shared winner store without re-searching.  Near-deterministic
  (the committed value is 1.0), so the tiny ``WARM_SLACK`` only
  absorbs float printing — a warm-start protocol regression (key
  drift, record refusal, stamp bugs) fails CI.
* ``policy_expansion_ratio=`` (``benchmarks.table7_policy`` budget
  grid): policy-guided search's node expansions as a fraction of
  beam's on the budget-matched subset.  LOWER is better — the new
  value must be <= baseline + ``PEXP_SLACK``, and <= the 0.5 absolute
  ceiling the table itself asserts: a policy or search change that
  quietly re-inflates the search budget fails CI.
* ``policy_speedup_ratio=`` (same row): policy search's geomean
  speedup over beam's.  Must stay >= baseline - ``PSPD_SLACK`` (and
  the table asserts >= 1.0 absolutely): the trained policy must keep
  matching beam's solution quality.
* ``coder_parity=`` (``benchmarks.table11_coder``): fraction of
  closed-space tasks where the replay-LLM micro-coder lands a winner
  fingerprint-identical to the structured coder's.  Deterministic
  (committed transcripts, analytic search), so zero slack: a prompt,
  parser or repair-loop change that breaks closed-space equivalence
  fails CI.
* ``open_gain=`` (same table): geomean LLM/structured speedup ratio on
  the ragged-dimension open-space suite — the LLM coder's ability to
  land verified programs outside the closed rule space.  Deterministic,
  zero slack (the table also asserts > 1.0 absolutely).

Modeled speedups are deliberately NOT gated — they move whenever the
cost model or search deepens.

  python -m benchmarks.check_regression <baseline.csv> <new.csv>
"""
from __future__ import annotations

import re
import sys

_ACC = re.compile(r"(?:^|;)acc=([0-9.]+)")
_RHO = re.compile(r"(?:^|;)rho=(-?[0-9.]+)")
_RHO_LEARN = re.compile(r"(?:^|;)rho_learn=(-?[0-9.]+)")
_RULES = re.compile(r"(?:^|;)rules_improved_frac=([0-9.]+)")
_WARM = re.compile(r"(?:^|;)warm_rate=([0-9.]+)")
_PEXP = re.compile(r"(?:^|;)policy_expansion_ratio=([0-9.]+)")
_PSPD = re.compile(r"(?:^|;)policy_speedup_ratio=([0-9.]+)")
_CPAR = re.compile(r"(?:^|;)coder_parity=([0-9.]+)")
_OGAIN = re.compile(r"(?:^|;)open_gain=([0-9.]+)")

RHO_SLACK = 0.3
WARM_SLACK = 0.02
PEXP_SLACK = 0.05   # expansion ratio is near-deterministic
PSPD_SLACK = 0.02


def _parse(path: str, pattern: re.Pattern) -> dict[str, float]:
    out: dict[str, float] = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith(("name,", "#")):
                continue
            parts = line.split(",", 2)
            if len(parts) < 3:
                continue
            m = pattern.search(parts[2])
            if m:
                out[parts[0]] = float(m.group(1))
    return out


def parse_accuracies(path: str) -> dict[str, float]:
    return _parse(path, _ACC)


def parse_rhos(path: str) -> dict[str, float]:
    return _parse(path, _RHO)


def parse_learned_rhos(path: str) -> dict[str, float]:
    return _parse(path, _RHO_LEARN)


def parse_rules_improved(path: str) -> dict[str, float]:
    return _parse(path, _RULES)


def parse_warm_rates(path: str) -> dict[str, float]:
    return _parse(path, _WARM)


def parse_policy_expansion(path: str) -> dict[str, float]:
    return _parse(path, _PEXP)


def parse_policy_speedup(path: str) -> dict[str, float]:
    return _parse(path, _PSPD)


def parse_coder_parity(path: str) -> dict[str, float]:
    return _parse(path, _CPAR)


def parse_open_gain(path: str) -> dict[str, float]:
    return _parse(path, _OGAIN)


def _gate(kind: str, base: dict[str, float], new: dict[str, float],
          slack: float) -> tuple[int, list[str]]:
    shared = sorted(set(base) & set(new))
    drops = [f"REGRESSION {n}: {kind} {base[n]:.3f} -> {new[n]:.3f} "
             f"(slack {slack:g})"
             for n in shared if new[n] < base[n] - slack]
    print(f"compared {kind} on {len(shared)} rows "
          f"({len(base) - len(shared)} baseline-only, "
          f"{len(new) - len(shared)} new-only)")
    return len(shared), drops


def _gate_upper(kind: str, base: dict[str, float],
                new: dict[str, float], slack: float,
                ceiling: float | None = None) -> tuple[int, list[str]]:
    """Lower-is-better gate: fail when new > base + slack (or past an
    absolute ceiling, when one is contractual)."""
    shared = sorted(set(base) & set(new))
    drops = [f"REGRESSION {n}: {kind} {base[n]:.3f} -> {new[n]:.3f} "
             f"(slack {slack:g})"
             for n in shared if new[n] > base[n] + slack]
    if ceiling is not None:
        drops += [f"REGRESSION {n}: {kind} {new[n]:.3f} above absolute "
                  f"ceiling {ceiling:g}"
                  for n in shared if new[n] > ceiling]
    print(f"compared {kind} on {len(shared)} rows "
          f"({len(base) - len(shared)} baseline-only, "
          f"{len(new) - len(shared)} new-only)")
    return len(shared), drops


def main(argv: list[str]) -> int:
    if len(argv) != 3:
        print(__doc__)
        return 2
    n_acc, acc_drops = _gate("acc", parse_accuracies(argv[1]),
                             parse_accuracies(argv[2]), 1e-9)
    n_rho, rho_drops = _gate("rho", parse_rhos(argv[1]),
                             parse_rhos(argv[2]), RHO_SLACK)
    n_lrho, lrho_drops = _gate("rho_learn", parse_learned_rhos(argv[1]),
                               parse_learned_rhos(argv[2]), RHO_SLACK)
    n_rules, rules_drops = _gate(
        "rules_improved_frac", parse_rules_improved(argv[1]),
        parse_rules_improved(argv[2]), 1e-9)
    n_warm, warm_drops = _gate("warm_rate", parse_warm_rates(argv[1]),
                               parse_warm_rates(argv[2]), WARM_SLACK)
    n_pexp, pexp_drops = _gate_upper(
        "policy_expansion_ratio", parse_policy_expansion(argv[1]),
        parse_policy_expansion(argv[2]), PEXP_SLACK, ceiling=0.5)
    n_pspd, pspd_drops = _gate(
        "policy_speedup_ratio", parse_policy_speedup(argv[1]),
        parse_policy_speedup(argv[2]), PSPD_SLACK)
    n_cpar, cpar_drops = _gate(
        "coder_parity", parse_coder_parity(argv[1]),
        parse_coder_parity(argv[2]), 1e-9)
    n_ogain, ogain_drops = _gate(
        "open_gain", parse_open_gain(argv[1]),
        parse_open_gain(argv[2]), 1e-9)
    if (n_acc == 0 and n_rho == 0 and n_lrho == 0 and n_rules == 0
            and n_warm == 0 and n_pexp == 0 and n_pspd == 0
            and n_cpar == 0 and n_ogain == 0):
        print(f"error: no comparable rows between {argv[1]} and "
              f"{argv[2]}")
        return 2
    drops = (acc_drops + rho_drops + lrho_drops + rules_drops
             + warm_drops + pexp_drops + pspd_drops + cpar_drops
             + ogain_drops)
    for msg in drops:
        print(msg)
    if drops:
        return 1
    print("no execute-accuracy, rank-correlation, rule-ablation, "
          "warm-start, policy-budget or micro-coder regressions")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
