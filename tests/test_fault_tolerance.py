"""Fault-tolerance behaviour: node failure/restart, elastic rescale,
straggler detection, checkpoint integrity."""
import dataclasses
import os
import tempfile

import jax
import numpy as np
import pytest

from repro import ckpt
from repro.configs.base import RunConfig, ShapeConfig
from repro.configs.registry import get_config, reduced
from repro.ft import StragglerMonitor, elastic_plan
from repro.ft.elastic import survivors_after_failure
from repro.models import api
from repro.optim import adamw
from repro.train.trainer import Trainer


def _tiny_cfg():
    cfg = reduced(get_config("qwen2_5_3b"))
    return dataclasses.replace(cfg, n_layers=2, d_model=64,
                               vocab_size=128, true_vocab_size=128)


def test_ckpt_roundtrip_with_opt_state():
    cfg = _tiny_cfg()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 7, params, opt)
        p2, o2, step = ckpt.restore(d, 7)
        assert step == 7
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), params, p2)
        assert int(o2["step"]) == 0


def test_ckpt_detects_corruption():
    cfg = _tiny_cfg()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, params)
        target = os.path.join(d, "step_00000001")
        victim = next(f for f in os.listdir(target)
                      if f.endswith(".npy") and "embed" in f)
        arr = np.load(os.path.join(target, victim))
        arr.ravel()[0] += 1.0
        np.save(os.path.join(target, victim), arr)
        with pytest.raises(OSError, match="corruption"):
            ckpt.restore(d, 1)


def test_ckpt_atomic_write_never_leaves_partial():
    cfg = _tiny_cfg()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, params)
        ckpt.save(d, 2, params, async_=True)
        ckpt.wait_pending()
        dirs = sorted(os.listdir(d))
        assert "step_00000001" in dirs and "step_00000002" in dirs
        assert not any(x.startswith(".tmp") for x in dirs)
        assert ckpt.latest_step(d) == 2


def test_simulated_crash_restart_continues_training():
    """Kill mid-run, restart from latest ckpt, loss curve continues."""
    cfg = _tiny_cfg()
    shape = ShapeConfig("s", 32, 4, "train")
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(cfg, shape, RunConfig(accum_steps=1), ckpt_dir=d,
                     ckpt_every=4)
        st = tr.init_state()
        st = tr.run_steps(st, 8)         # ckpts at 4 and 8
        del tr, st                        # "crash"
        tr2 = Trainer(cfg, shape, RunConfig(accum_steps=1), ckpt_dir=d,
                      ckpt_every=4)
        st2 = tr2.restore_or_init()
        assert st2.step == 8
        st2 = tr2.run_steps(st2, 4)
        assert st2.step == 12
        assert all(np.isfinite(m["loss"]) for m in tr2.metrics_log)


def test_elastic_plan_shapes():
    p = elastic_plan(512, model_parallel=16, pods=2)
    assert p.mesh_shape == (2, 16, 16)
    p = elastic_plan(240, model_parallel=16)   # lost a host
    assert p.mesh_shape == (15, 16)
    assert survivors_after_failure(
        type("M", (), {"devices": np.zeros(256)})(), [0, 1]) == 248


def test_elastic_rescale_preserves_loss():
    """Restore the same checkpoint under a different data-parallel
    degree; the (deterministic) global batch and loss are identical."""
    cfg = _tiny_cfg()
    shape = ShapeConfig("s", 32, 4, "train")
    from repro.data.pipeline import host_batch
    from repro.train.trainer import make_train_step
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    batch = host_batch(cfg, shape, 0, process_index=0, process_count=1)
    step_fn = make_train_step(cfg, shape, RunConfig(accum_steps=1))
    _, _, m1 = jax.jit(step_fn)(params, opt, batch)
    # "rescaled": same logical state, accum 2 emulating half the hosts
    step_fn2 = make_train_step(cfg, shape, RunConfig(accum_steps=2))
    _, _, m2 = jax.jit(step_fn2)(params, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4


def test_straggler_monitor_flags_and_evicts():
    mon = StragglerMonitor(threshold=2.0, patience=2)
    for i in range(10):
        mon.record(i, 1.0, replica=0)
        mon.record(i, 1.0, replica=1)
    mon.record(10, 5.0, replica=1)
    mon.record(11, 5.0, replica=1)
    assert 1 in mon.replicas_to_evict()
    assert 0 not in mon.replicas_to_evict()
    assert mon.events


def test_straggler_preemptive_checkpoint_signal():
    mon = StragglerMonitor(threshold=1.5, patience=2)
    for i in range(8):
        mon.record(i, 1.0, replica=i % 3)
    for i in range(3):
        mon.record(8 + i, 4.0, replica=i)
    assert mon.should_checkpoint_now()
