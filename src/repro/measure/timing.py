"""Shared wall-clock timing helpers (monotonic, robust statistics).

One clock for the whole repo: ``stopwatch`` replaces the ad-hoc
``time.time()`` deltas that used to live in ``launch/dryrun.py`` (wall
clocks can step backwards under NTP; ``perf_counter`` cannot), and the
measured-execution harness (``measure/harness.py``) builds its
warmup / repeat / outlier-rejection loop from the same primitives so
dry-run compile timings and kernel measurements are comparable.

This module is deliberately dependency-free (no jax import): dryrun.py
must set XLA_FLAGS before anything touches jax, so the timing helpers
it calls cannot transitively import it.
"""
from __future__ import annotations

import dataclasses
import time
from collections.abc import Callable


@dataclasses.dataclass
class Stopwatch:
    """Monotonic elapsed-seconds recorder (``perf_counter`` based).

    Use as a context manager::

        with stopwatch() as sw:
            compiled = lowered.compile()
        meta["compile_s"] = sw.s
    """

    t0: float = 0.0
    s: float = 0.0

    def __enter__(self) -> Stopwatch:
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.s = time.perf_counter() - self.t0

    # phase-timing API (dryrun's lower -> compile sequence):
    #   sw = stopwatch().start(); ...; t_lower = sw.lap(); ...;
    #   t_compile = sw.lap()
    def start(self) -> Stopwatch:
        self.t0 = time.perf_counter()
        return self

    def lap(self) -> float:
        now = time.perf_counter()
        self.s = now - self.t0
        self.t0 = now
        return self.s


def stopwatch() -> Stopwatch:
    return Stopwatch()


def time_thunk(thunk: Callable[[], object], *, warmup: int = 1,
               repeats: int = 5) -> list[float]:
    """Raw per-call wall times of ``thunk`` after ``warmup`` calls.

    ``thunk`` must synchronize its own work (e.g. call
    ``jax.block_until_ready`` on its outputs) — this module stays
    jax-free, so it cannot do that for the caller.
    """
    for _ in range(max(0, warmup)):
        thunk()
    samples: list[float] = []
    for _ in range(max(1, repeats)):
        with stopwatch() as sw:
            thunk()
        samples.append(sw.s)
    return samples


def robust_time_s(samples: list[float], *, trim: float = 0.2,
                  mad_k: float = 4.0) -> tuple[float, int]:
    """(trimmed-median seconds, n_rejected) over raw samples.

    Two-stage robustness, matching what kernel-timing harnesses do in
    practice: (1) reject outliers farther than ``mad_k`` scaled MADs
    from the median (GC pauses, a concurrent process stealing the
    core), then (2) take the median of the central ``1 - 2*trim``
    fraction of the survivors.  With few samples both stages degrade
    gracefully to the plain median.
    """
    xs = sorted(float(s) for s in samples)
    if not xs:
        raise ValueError("no samples")
    med = _median(xs)
    mad = _median([abs(x - med) for x in xs])
    if mad > 0.0:
        lim = mad_k * 1.4826 * mad   # 1.4826: MAD -> sigma for normals
        kept = [x for x in xs if abs(x - med) <= lim]
    else:
        kept = xs
    if not kept:        # pathological: everything "rejected"
        kept = xs
    k = int(len(kept) * max(0.0, min(trim, 0.45)))
    core = kept[k:len(kept) - k] or kept
    return _median(core), len(xs) - len(kept)


def _median(xs: list[float]) -> float:
    n = len(xs)
    m = n // 2
    return xs[m] if n % 2 else 0.5 * (xs[m - 1] + xs[m])
