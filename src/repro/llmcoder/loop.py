"""The verify-and-repair driver: ``LLMMicroCoder``.

Implements the ``MicroCoder`` protocol over any ``CoderBackend``.  One
``apply(prog, act)`` call is a bounded conversation:

  attempt 0   build the propose-one-delta prompt, complete, parse;
  gate        static analysis first (the PR-8 verifier + schedule
              legality — milliseconds, catches the MT0xx classes), then
              the numeric oracle against the parent at the tolerances
              the child's rewrite rules declare;
  repair      every rejection is rendered into feedback bullets
              (diagnostics, oracle per-output max-|Δ| summary, parse
              errors) and appended to the next attempt's prompt;
  stop        success, a non-transient backend refusal, or
              ``max_attempts`` exhausted (``gave_up``).

Transient backend faults retry with exponential backoff *within* the
same attempt (the prompt has not changed, so the attempt index — and
hence the transcript replay key — must not move).  Slow backends are
bounded by a per-attempt wall-clock timeout; deterministic local
backends advertise ``instant`` and skip the timeout thread entirely.

The resulting ``ApplyResult`` vocabulary is exactly the structured
coder's: ``ok`` (verified child, history stamped with the action),
``compile_error`` (could not land a legal program), ``wrong_result``
(final attempt parsed and analyzed clean but failed the oracle).
Determinism: with a deterministic backend, ``apply`` is a pure function
of ``(prog.fingerprint(), action_key)`` — the contract the
transposition store memoizes on.
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import threading
import time

import jax
import numpy as np

from repro.core import rules as R
from repro.core.kernel_ir import (KernelProgram, evaluate, evaluate_np,
                                  make_inputs_np, program_to_json)
from repro.core.micro_coding import ApplyResult
from repro.core.pipeline import CHECK_ATOL, CHECK_RTOL, CHECK_SEED
from repro.llmcoder.backend import BackendError, CoderBackend, CoderRequest
from repro.llmcoder.prompts import (ResponseParseError, build_prompt,
                                    parse_response)


@dataclasses.dataclass(frozen=True)
class LoopConfig:
    """Repair-loop policy knobs (all bounded; nothing blocks forever)."""
    max_attempts: int = 3          # propose + up to 2 repair rounds
    attempt_timeout_s: float = 60.0
    transient_retries: int = 2     # extra tries per attempt on transient
    backoff_base_s: float = 0.05   # 0.05, 0.1, 0.2, ... between them
    seed: int = CHECK_SEED
    rtol: float = CHECK_RTOL
    atol: float = CHECK_ATOL


_COUNTERS = ("proposals", "repairs", "parse_rejects", "analysis_rejects",
             "oracle_rejects", "backend_errors", "repaired_ok", "gave_up")


class LLMMicroCoder:
    """``MicroCoder`` over a completion backend (see module docstring)."""

    def __init__(self, backend: CoderBackend,
                 cfg: LoopConfig | None = None):
        self.backend = backend
        self.cfg = cfg or LoopConfig()
        self.name = f"llm-{backend.name}"
        self._lock = threading.Lock()
        self._local = threading.local()
        self.counters = {k: 0 for k in _COUNTERS}
        # attempt index of each successful apply: [0]=first-try wins,
        # [1]=recovered after one repair round, ...
        self.repair_depth: dict[int, int] = {}

    # -- task scoping --------------------------------------------------------
    def bind_task(self, task: KernelProgram | None) -> None:
        """Scope subsequent transcript keys to an optimization request's
        root program.  Thread-local: ``evaluate_suite`` runs one task per
        worker thread over one shared coder."""
        self._local.task_fp = task.fingerprint() if task is not None else None

    def _task_fp(self, prog: KernelProgram) -> str:
        fp = getattr(self._local, "task_fp", None)
        # unbound (direct protocol use): the parent program scopes itself
        return fp if fp else prog.fingerprint()

    # -- telemetry -----------------------------------------------------------
    def _bump(self, key: str, n: int = 1) -> None:
        with self._lock:
            self.counters[key] += n

    def stats_dict(self) -> dict:
        with self._lock:
            out = {f"coder_{k}": v for k, v in self.counters.items()}
            out["coder_repair_depth"] = dict(sorted(
                self.repair_depth.items()))
        out["coder_name"] = self.name
        stats = getattr(self.backend, "stats", None)
        if isinstance(stats, dict):
            out.update({f"coder_backend_{k}": v for k, v in stats.items()})
        return out

    # -- entry point ---------------------------------------------------------
    def apply(self, prog: KernelProgram, act) -> ApplyResult:
        if R.is_terminal(act):
            return ApplyResult("ok", prog, "terminal")
        from repro.core.env import action_key as _akey
        akey = _akey(act)
        task_fp = self._task_fp(prog)
        prog_fp = prog.fingerprint()
        prog_json = program_to_json(prog)
        feedback: list[str] = []
        last: ApplyResult | None = None
        for attempt in range(self.cfg.max_attempts):
            self._bump("proposals")
            if attempt:
                self._bump("repairs")
            prompt = build_prompt(prog, act, tuple(feedback))
            req = CoderRequest(task_fp=task_fp, prog_fp=prog_fp,
                               action_key=akey, attempt=attempt,
                               prompt=prompt, program=prog_json,
                               action=act, feedback=tuple(feedback))
            try:
                text = self._complete(req)
            except BackendError as e:
                self._bump("backend_errors")
                # the backend cannot answer this request at all — more
                # repair context would reach the same refusal
                last = ApplyResult("compile_error", None,
                                   f"backend: {e}")
                break
            try:
                child = parse_response(text)
            except ResponseParseError as e:
                self._bump("parse_rejects")
                feedback.append(f"response rejected: {e}; reply with "
                                f"exactly one JSON program object")
                last = ApplyResult("compile_error", None, f"parse: {e}")
                continue
            # the coder owns identity/provenance, never the model
            child = child.replace(name=prog.name,
                                  history=prog.history + (act.describe(),))
            errs = self._static_errors(prog, child)
            if errs:
                self._bump("analysis_rejects")
                feedback.extend(errs)
                last = ApplyResult("compile_error", None,
                                   "; ".join(errs))
                continue
            mismatch = self._oracle_mismatch(prog, child)
            if mismatch:
                self._bump("oracle_rejects")
                feedback.append(mismatch)
                last = ApplyResult("wrong_result", None, mismatch)
                continue
            if attempt:
                self._bump("repaired_ok")
            with self._lock:
                self.repair_depth[attempt] = \
                    self.repair_depth.get(attempt, 0) + 1
            return ApplyResult("ok", child,
                               "repaired" if attempt else "")
        self._bump("gave_up")
        return last or ApplyResult("compile_error", None, "no attempts")

    # -- completion with timeout + transient backoff -------------------------
    def _complete(self, req: CoderRequest) -> str:
        delay = self.cfg.backoff_base_s
        for retry in range(self.cfg.transient_retries + 1):
            try:
                if self.backend.instant:
                    return self.backend.complete(req)
                return self._complete_timed(req)
            except BackendError as e:
                if not e.transient or retry == self.cfg.transient_retries:
                    raise
                time.sleep(delay)
                delay *= 2
        raise BackendError("unreachable")  # pragma: no cover

    def _complete_timed(self, req: CoderRequest) -> str:
        # manual shutdown(wait=False): a hung backend must not hang the
        # search with it (the worker thread is abandoned, not joined)
        ex = cf.ThreadPoolExecutor(max_workers=1)
        fut = ex.submit(self.backend.complete, req)
        try:
            return fut.result(timeout=self.cfg.attempt_timeout_s)
        except cf.TimeoutError:
            raise BackendError(
                f"attempt timed out after {self.cfg.attempt_timeout_s}s",
                transient=True) from None
        finally:
            ex.shutdown(wait=False)

    # -- gates ---------------------------------------------------------------
    def _static_errors(self, parent: KernelProgram,
                       child: KernelProgram) -> list[str]:
        """Contract + PR-8 analyzer rejections, rendered for feedback."""
        out = []
        if dict(child.inputs) != dict(parent.inputs):
            out.append("input contract changed: the rewritten program "
                       "must declare the same inputs")
        if len(child.outputs) != len(parent.outputs):
            out.append("output contract changed: the rewritten program "
                       "must produce the same outputs")
        if out:
            return out
        from repro.analysis.legality import analyze_program
        try:
            diags = analyze_program(child)
        except Exception:           # analyzer crash: fail-open, like the
            diags = []              # store's analysis_ok
        return [d.render(child.name) for d in diags if d.is_error]

    def _oracle_mismatch(self, parent: KernelProgram,
                         child: KernelProgram) -> str:
        """Empty string when the child matches the parent numerically;
        else a per-output max-|Δ| summary for repair feedback."""
        if child.eval_fingerprint() == parent.eval_fingerprint():
            return ""               # schedule-only rewrite: same graph
        inputs = make_inputs_np(parent, self.cfg.seed)
        try:
            try:
                a = evaluate_np(parent, inputs)
            except NotImplementedError:
                a = jax.jit(lambda i: evaluate(parent, i))(inputs)
            try:
                b = evaluate_np(child, inputs)
            except NotImplementedError:
                b = jax.jit(lambda i: evaluate(child, i))(inputs)
        except Exception as e:
            return f"oracle execution failed: {e}"
        per_tol = R.output_tolerances(child, self.cfg.rtol, self.cfg.atol)
        if R.outputs_match(a, b, self.cfg.rtol, self.cfg.atol,
                           per_output=per_tol):
            return ""
        deltas = []
        for i, (x, y) in enumerate(zip(a, b)):
            x = np.asarray(x, dtype=np.float64)
            y = np.asarray(y, dtype=np.float64)
            if x.shape != y.shape:
                deltas.append(f"out[{i}] shape {y.shape} != {x.shape}")
            else:
                deltas.append(f"out[{i}] max|delta|="
                              f"{float(np.max(np.abs(x - y))):.3e}")
        return ("numeric mismatch vs parent program: "
                + ", ".join(deltas))
