"""Kernel dispatch layer.

Models call these ops.  On TPU backends they run the Pallas kernels from
``repro.kernels.*`` with the schedule installed by the MTMC autotuner
(``repro.core.autotune``); on CPU (tests, dry-run lowering) they run the
mathematically identical jnp reference path, so the dry-run HLO reflects
the same computation.

``set_schedule(kernel_name, key, schedule)`` is the integration point the
MTMC pipeline uses to install tuned schedules.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers

# (kernel_name, shape_key, target_name) -> KernelSchedule; schedules are
# tuned against one hardware target's cost model (repro.core.hardware),
# so the registry keys them by target and dispatch consults the active
# target (default: the registry default, tpu_v5e)
_SCHEDULES: dict[tuple[str, str, str], Any] = {}
_ACTIVE_TARGET: str | None = None   # None -> hardware.DEFAULT_TARGET
_FORCE_REF = False          # tests can force the reference path
_FORCE_PALLAS = False       # tests force interpret-mode pallas on CPU


def _target_name(target: Any = None) -> str:
    from repro.core import hardware
    t = target if target is not None else _ACTIVE_TARGET
    if t is None:
        return hardware.DEFAULT_TARGET
    return t if isinstance(t, str) else t.name


def set_active_target(target: Any) -> None:
    """Select which target's tuned schedules dispatch consults (the chip
    this process is actually serving on).  Accepts a name, a
    ``HardwareTarget``, or None to fall back to the registry default."""
    global _ACTIVE_TARGET
    _ACTIVE_TARGET = None if target is None else _target_name(target)


def set_schedule(kernel: str, key: str, schedule: Any,
                 target: Any = None) -> None:
    _SCHEDULES[(kernel, key, _target_name(target))] = schedule


def get_schedule(kernel: str, key: str, default: Any = None,
                 target: Any = None) -> Any:
    """Schedule for (kernel, key) on the given/active target, falling
    back to the default target's entry (a v5e-tuned schedule is a sane
    starting point on any chip; a target-specific install overrides)."""
    from repro.core import hardware
    tname = _target_name(target)
    s = _SCHEDULES.get((kernel, key, tname))
    if s is None and tname != hardware.DEFAULT_TARGET:
        s = _SCHEDULES.get((kernel, key, hardware.DEFAULT_TARGET))
    return default if s is None else s


def use_pallas() -> bool:
    if _FORCE_REF:
        return False
    if _FORCE_PALLAS:
        return True
    return jax.default_backend() == "tpu"


def force(mode: str | None) -> None:
    """mode in {None, 'ref', 'pallas'} — used by kernel tests."""
    global _FORCE_REF, _FORCE_PALLAS
    _FORCE_REF = mode == "ref"
    _FORCE_PALLAS = mode == "pallas"


def interpret() -> bool:
    return _FORCE_PALLAS and jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

_DEFAULT_CHUNK = 1024     # q-block of the chunked fallback (Tiling knob)


def set_default_chunk(c: int) -> None:
    """§Perf: system-level Tiling action — larger q-chunks divide the KV
    re-read traffic of long-context attention by the same factor."""
    global _DEFAULT_CHUNK
    _DEFAULT_CHUNK = int(c)


def attention(q, k, v, *, causal=True, window=0, q_offset=0,
              bidir_prefix=0, chunk=None, kv_mask=None):
    """Flash attention (Pallas on TPU) / chunked online-softmax ref.

    kv_mask (B,Sk) marks valid key positions (mixed-length left-padded
    prefill); the Pallas kernel has no mask operand, so a masked call
    takes the reference path."""
    if chunk is None:
        chunk = _DEFAULT_CHUNK
    if use_pallas() and bidir_prefix == 0 and kv_mask is None \
            and q.shape[1] >= 128:
        from repro.kernels import flash_attention as fa
        sched = get_schedule("flash_attention", f"S{q.shape[1]}")
        return fa.flash_attention(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset, schedule=sched,
                                  interpret=interpret())
    return _ref_attention(q, k, v, causal=causal, window=window,
                          q_offset=q_offset, bidir_prefix=bidir_prefix,
                          chunk=chunk, kv_mask=kv_mask)


def _ref_attention(q, k, v, *, causal, window, q_offset, bidir_prefix,
                   chunk, kv_mask=None):
    if bidir_prefix:
        # PaliGemma-style prefix-LM mask: keys < prefix are always visible.
        scale = q.shape[-1] ** -0.5
        scores = layers._gqa_scores(q * scale, k)
        sq, sk = scores.shape[-2], scores.shape[-1]
        qpos = jnp.arange(sq) + q_offset
        kpos = jnp.arange(sk)
        mask = qpos[:, None] >= kpos[None, :] if causal else \
            jnp.ones((sq, sk), bool)
        mask |= kpos[None, :] < bidir_prefix
        if window:
            mask &= (kpos[None, :] > qpos[:, None] - window) | \
                (kpos[None, :] < bidir_prefix)
        if kv_mask is not None:
            mask = mask[None] & kv_mask[:, None, :]
            mask = mask[:, None, None]            # (B,1,1,Sq,Sk)
        scores = jnp.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return layers._gqa_out(probs, v)
    return layers.attention(q, k, v, causal=causal, window=window,
                            q_offset=q_offset, chunk=chunk,
                            kv_mask=kv_mask)


def decode_attention(q, k_cache, v_cache, pos, *, window=0, start=None):
    return layers.decode_attention(q, k_cache, v_cache, pos,
                                   window=window, start=start)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-6):
    if use_pallas() and x.shape[-1] % 128 == 0:
        from repro.kernels import rmsnorm as rn
        sched = get_schedule("rmsnorm", f"D{x.shape[-1]}")
        return rn.rmsnorm(x, scale, eps=eps, schedule=sched,
                          interpret=interpret())
    return layers.rms_norm(x, scale, eps)


# ---------------------------------------------------------------------------
# matmul with fusable epilogue (MTMC's Fusion action target)
# ---------------------------------------------------------------------------

def matmul(x, w, *, epilogue: str = "none", bias=None):
    if use_pallas() and x.ndim == 2 and x.shape[0] % 128 == 0 \
            and x.shape[1] % 128 == 0 and w.shape[1] % 128 == 0:
        from repro.kernels import matmul as mm
        sched = get_schedule("matmul", f"{x.shape}x{w.shape}")
        return mm.matmul(x, w, epilogue=epilogue, bias=bias,
                         schedule=sched, interpret=interpret())
    from repro.kernels import ref
    return ref.matmul(x, w, epilogue=epilogue, bias=bias)


# ---------------------------------------------------------------------------
# rwkv6 / ssm scans
# ---------------------------------------------------------------------------

def rwkv6_scan(r, k, v, w, u, state=None, *, chunk=64):
    T = r.shape[1]
    if use_pallas() and T > 1 and T % max(chunk, 8) == 0:
        from repro.kernels import rwkv6_scan as rk
        sched = get_schedule("rwkv6_scan", f"T{T}")
        return rk.rwkv6_scan(r, k, v, w, u, state, schedule=sched,
                             interpret=interpret())
    from repro.kernels import ref
    if T > 1 and T % chunk == 0:
        return ref.rwkv6_chunked(r, k, v, w, u, state, chunk=chunk)
    return ref.rwkv6_scan(r, k, v, w, u, state)


def ssm_scan(x, dt, A, B, C, state=None, *, chunk=64):
    T = x.shape[1]
    if use_pallas() and T > 1 and T % max(chunk, 8) == 0:
        from repro.kernels import ssm_scan as sk
        sched = get_schedule("ssm_scan", f"T{T}")
        return sk.ssm_scan(x, dt, A, B, C, state, schedule=sched,
                           interpret=interpret())
    from repro.kernels import ref
    if T > 1 and T % chunk == 0:
        return ref.ssm_chunked(x, dt, A, B, C, state, chunk=chunk)
    return ref.ssm_scan_step(x, dt, A, B, C, state)


# ---------------------------------------------------------------------------
# grouped matmul (MoE expert compute)
# ---------------------------------------------------------------------------

def grouped_matmul(x_groups, w_groups):
    """x: (E, C, D) or (G, E, C, D), w: (E, D, F) -> (..., E, C, F)."""
    if x_groups.ndim == 4:
        # group-local MoE dispatch: G is data-sharded; the TPU kernel
        # runs per-shard on the 3D slice (einsum here; GSPMD keeps the
        # G axis sharded)
        return jnp.einsum("gecd,edf->gecf", x_groups,
                          w_groups.astype(x_groups.dtype))
    if use_pallas() and x_groups.shape[1] % 128 == 0 \
            and x_groups.shape[2] % 128 == 0:
        from repro.kernels import grouped_matmul as gm
        sched = get_schedule("grouped_matmul", f"{x_groups.shape}")
        return gm.grouped_matmul(x_groups, w_groups, schedule=sched,
                                 interpret=interpret())
    return jnp.einsum("ecd,edf->ecf", x_groups,
                      w_groups.astype(x_groups.dtype))
