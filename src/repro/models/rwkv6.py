"""RWKV6 "Finch" — attention-free LM with data-dependent decay.
[arXiv:2404.05892]

Per block: time-mix (token-shift with data-dependent LoRA mixing, r/k/v/g
projections, per-channel decay ``w = exp(-exp(...))`` with LoRA
data-dependence, u bonus, grouped WKV recurrence) + channel-mix.

The WKV recurrence runs through ``kernels.ops.rwkv6_scan`` (Pallas chunked
kernel on TPU, chunked jnp on CPU).  O(1) state => long_500k decode runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import layers
from repro.models.layers import linear, normal_init, ones_init, zeros_init

MIX_DIM = 32      # TIME_MIX_EXTRA_DIM
DECAY_DIM = 64    # TIME_DECAY_EXTRA_DIM


def _decay_init():
    def init(key, shape, dtype):
        # w = exp(-exp(base)) spread across (0,1)
        return jnp.broadcast_to(
            jnp.linspace(-6.0, 1.0, shape[-1], dtype=dtype), shape)
    return init


def param_tree(cfg: ModelConfig, make):
    L, D, FF, V = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size
    H, dk = cfg.n_heads, cfg.head_dim
    w = normal_init(0.02)
    wo_init = normal_init(layers.depth_scale(0.02, L))
    blocks = {
        "ln1": make("ln1", (L, D), ("layers", "embed"), ones_init()),
        "ln2": make("ln2", (L, D), ("layers", "embed"), ones_init()),
        # token-shift mixing (5 targets: w,k,v,r,g)
        "mu_x": make("mu_x", (L, D), ("layers", "embed"), zeros_init()),
        "mu": make("mu", (L, 5, D), ("layers", None, "embed"),
                   zeros_init()),
        "mix_A": make("mix_A", (L, D, 5 * MIX_DIM),
                      ("layers", "embed", None), w),
        "mix_B": make("mix_B", (L, 5, MIX_DIM, D),
                      ("layers", None, None, "embed"), w),
        # projections
        "wr": make("wr", (L, D, H * dk), ("layers", "embed", "heads"), w),
        "wk": make("wk", (L, D, H * dk), ("layers", "embed", "heads"), w),
        "wv": make("wv", (L, D, H * dk), ("layers", "embed", "heads"), w),
        "wg": make("wg", (L, D, H * dk), ("layers", "embed", "heads"), w),
        "wo": make("wo", (L, H * dk, D), ("layers", "heads", "embed"),
                   wo_init),
        # decay
        "decay_base": make("decay_base", (L, H, dk),
                           ("layers", "heads", None), _decay_init()),
        "decay_A": make("decay_A", (L, D, DECAY_DIM),
                        ("layers", "embed", None), w),
        "decay_B": make("decay_B", (L, DECAY_DIM, H * dk),
                        ("layers", None, "heads"), w),
        "u": make("u", (L, H, dk), ("layers", "heads", None), w),
        # group norm over head outputs
        "gn_scale": make("gn_scale", (L, H * dk), ("layers", "heads"),
                         ones_init()),
        "gn_bias": make("gn_bias", (L, H * dk), ("layers", "heads"),
                        zeros_init()),
        # channel mix
        "cm_mu_k": make("cm_mu_k", (L, D), ("layers", "embed"),
                        zeros_init()),
        "cm_mu_r": make("cm_mu_r", (L, D), ("layers", "embed"),
                        zeros_init()),
        "cm_wk": make("cm_wk", (L, D, FF), ("layers", "embed", "mlp"), w),
        "cm_wr": make("cm_wr", (L, D, D), ("layers", "embed", "ffn_embed"),
                      w),
        "cm_wv": make("cm_wv", (L, FF, D), ("layers", "mlp", "embed"),
                      wo_init),
    }
    return {
        "embed": make("embed", (V, D), ("vocab", "embed"), normal_init()),
        "blocks": blocks,
        "final_norm": make("final_norm", (D,), ("embed",), ones_init()),
        "lm_head": make("lm_head", (D, V), ("embed", "vocab"),
                        normal_init()),
    }


def _shift(x: jax.Array, last: jax.Array | None = None):
    """token shift: out[t] = x[t-1]; position 0 gets ``last`` (decode) or 0."""
    prev = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :]
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _time_mix(cfg, p, x, shifted, state, rules=None):
    """x: (B,T,D) normed. Returns (out, new_wkv_state)."""
    B, T, D = x.shape
    H, dk = cfg.n_heads, cfg.head_dim
    dx = shifted - x
    xxx = x + dx * p["mu_x"].astype(x.dtype)
    kmix = jnp.tanh(jnp.einsum("btd,dm->btm", xxx,
                               p["mix_A"].astype(x.dtype)))
    kmix = kmix.reshape(B, T, 5, MIX_DIM)
    mixes = jnp.einsum("btfm,fmd->btfd", kmix,
                       p["mix_B"].astype(x.dtype))
    mixes = mixes + p["mu"].astype(x.dtype)                 # (B,T,5,D)
    xw, xk, xv, xr, xg = [x + dx * mixes[:, :, i] for i in range(5)]

    r = linear(xr, p["wr"]).reshape(B, T, H, dk)
    k = linear(xk, p["wk"]).reshape(B, T, H, dk)
    v = linear(xv, p["wv"]).reshape(B, T, H, dk)
    g = jax.nn.silu(linear(xg, p["wg"]))                    # (B,T,H*dk)

    dlora = jnp.einsum("btd,dm->btm", jnp.tanh(
        jnp.einsum("btd,dm->btm", xw, p["decay_A"].astype(x.dtype))),
        p["decay_B"].astype(x.dtype)).reshape(B, T, H, dk)
    logw = -jnp.exp(p["decay_base"].astype(jnp.float32)[None, None]
                    + dlora.astype(jnp.float32))            # < 0
    w = jnp.exp(logw)                                       # (0,1)
    if rules is not None:
        r = rules.constrain(r, ("batch", None, "heads", None))
        k = rules.constrain(k, ("batch", None, "heads", None))
        v = rules.constrain(v, ("batch", None, "heads", None))
        w = rules.constrain(w, ("batch", None, "heads", None))
    o, new_state = ops.rwkv6_scan(r, k, v, w.astype(r.dtype),
                                  p["u"], state)
    o = o.reshape(B, T, H * dk)
    # per-head group norm
    oh = o.reshape(B, T, H, dk).astype(jnp.float32)
    mean = oh.mean(-1, keepdims=True)
    var = oh.var(-1, keepdims=True)
    oh = (oh - mean) * jax.lax.rsqrt(var + 64e-5)
    o = oh.reshape(B, T, H * dk).astype(x.dtype)
    o = o * p["gn_scale"].astype(x.dtype) + p["gn_bias"].astype(x.dtype)
    return linear(o * g, p["wo"]), new_state


def _channel_mix(cfg, p, x, shifted):
    dx = shifted - x
    xk = x + dx * p["cm_mu_k"].astype(x.dtype)
    xr = x + dx * p["cm_mu_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(linear(xk, p["cm_wk"])))
    return jax.nn.sigmoid(linear(xr, p["cm_wr"])) * linear(kk, p["cm_wv"])


def forward(cfg: ModelConfig, params: dict, batch: dict, *, rules=None,
            remat: bool = True, collect_cache: bool = False):
    tokens = batch["tokens"]
    cdt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"].astype(cdt)[tokens]
    if rules is not None:
        x = rules.constrain(x, ("batch", None, None))

    def block(x, p):
        h1 = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        tm, _ = _time_mix(cfg, p, h1, _shift(h1), None, rules)
        x = x + tm
        h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + _channel_mix(cfg, p, h2, _shift(h2))
        if rules is not None:
            x = rules.constrain(x, ("batch", None, None))
        return x, jnp.float32(0)

    if remat:
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable)
    x, aux = jax.lax.scan(block, x, params["blocks"])
    x = ops.rmsnorm(x, params["final_norm"], eps=cfg.norm_eps)
    logits = jnp.einsum("...d,dv->...v", x,
                        params["lm_head"].astype(x.dtype))
    if rules is not None:
        logits = rules.constrain(logits, ("batch", None, "vocab"))
    return logits, jnp.mean(aux)


# ---------------------------------------------------------------------------
# decode: O(1) state
# ---------------------------------------------------------------------------

def cache_tree(cfg: ModelConfig, make, batch: int, max_len: int):
    L, D = cfg.n_layers, cfg.d_model
    H, dk = cfg.n_heads, cfg.head_dim
    return {
        "tm_x": make("tm_x", (L, batch, D), ("layers", "batch", "embed"),
                     zeros_init()),
        "cm_x": make("cm_x", (L, batch, D), ("layers", "batch", "embed"),
                     zeros_init()),
        "wkv": make("wkv", (L, batch, H, dk, dk),
                    ("layers", "batch", "heads", None, None), zeros_init()),
    }


def decode_step(cfg: ModelConfig, params: dict, cache: dict,
                tokens: jax.Array, pos: jax.Array, *, rules=None):
    cdt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"].astype(cdt)[tokens]      # (B,1,D)
    if rules is not None:
        x = rules.constrain(x, ("batch", None, None))

    def block(x, scanned):
        p, tm_x, cm_x, wkv = scanned
        h1 = layers.rms_norm(x, p["ln1"], cfg.norm_eps)
        tm, new_wkv = _time_mix(cfg, p, h1,
                                tm_x[:, None, :].astype(h1.dtype), wkv,
                                rules)
        x = x + tm
        h2 = layers.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + _channel_mix(cfg, p, h2, cm_x[:, None, :].astype(h2.dtype))
        return x, (h1[:, 0].astype(tm_x.dtype),
                   h2[:, 0].astype(cm_x.dtype), new_wkv)

    x, (tm_x, cm_x, wkv) = jax.lax.scan(
        block, x, (params["blocks"], cache["tm_x"], cache["cm_x"],
                   cache["wkv"]))
    x = ops.rmsnorm(x, params["final_norm"], eps=cfg.norm_eps)
    logits = jnp.einsum("...d,dv->...v", x,
                        params["lm_head"].astype(x.dtype))
    return logits, {"tm_x": tm_x, "cm_x": cm_x,
                    "wkv": wkv.astype(cache["wkv"].dtype)}
