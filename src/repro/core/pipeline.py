"""MTMC inference pipeline: Macro Thinking proposes, Micro Coding applies.

Modes (the paper's main method + its ablations):
  policy      — trained Macro policy (RL), step-by-step      [MTMC]
  untrained   — randomly initialised LM scores actions        ["w/o policy"
                proxy for a general-purpose LLM with no RL — see DESIGN.md]
  random      — uniform over the curated action space         ["w/o policy - random"]
  greedy_cost — oracle-ish: picks the best cost-model child   [upper bound]
  single_pass — samples a whole multi-action plan up front and
                applies it without intermediate validation    ["w/o Hier"]

``curated=False`` switches the action space to unrestricted proposals
("w/o AS").  Every run returns correctness (the rewritten program is
validated against the task's oracle), modeled speedup, and the trace.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import actions as A
from repro.core import cost_model, hardware, rules, search as S
from repro.core.config import UNSET, OptimizeConfig, resolve_config
from repro.core.env import EnvConfig, KernelEnv
from repro.core.kernel_ir import KernelProgram, evaluate, make_inputs
from repro.core.micro_coding import get_coder
from repro.core.policy import MacroPolicy


# tier-2 validation parameters — shared by the serial _check path and
# the engine's memoized TranspositionStore.check so they cannot diverge
CHECK_SEED = 7
CHECK_RTOL = CHECK_ATOL = 2e-3


@dataclasses.dataclass
class OptimizationResult:
    task: str
    program: KernelProgram
    correct: bool
    speedup: float                # modeled, vs naive ("eager") program
    steps: int
    n_failures: int               # compile/validation failures en route
    trace: tuple[str, ...]
    # measured-execution fields (None unless a measurer reranked the
    # search's top-K survivors — DESIGN.md §11)
    measured_s: float | None = None           # winner's measured time
    measured_baseline_s: float | None = None  # task's measured time
    reranked: bool = False        # measured winner != analytic winner

    @property
    def accuracy(self) -> bool:   # benchmark "execute accuracy"
        return self.correct

    @property
    def measured_speedup(self) -> float | None:
        if self.measured_s is None or self.measured_baseline_s is None:
            return None
        return self.measured_baseline_s / max(self.measured_s, 1e-12)


class MTMCPipeline:
    def __init__(self, policy: MacroPolicy | None = None, *,
                 config: OptimizeConfig | None = None, store=None,
                 mode=UNSET, curated=UNSET, extended_rules=UNSET,
                 max_steps=UNSET, seed=UNSET, validate=UNSET,
                 target=UNSET, strategy=UNSET,
                 cost_model_override=UNSET, measurer=UNSET,
                 rerank_top_k=UNSET):
        cfg = resolve_config("MTMCPipeline", config, {
            "mode": mode, "curated": curated,
            "extended_rules": extended_rules, "max_steps": max_steps,
            "seed": seed, "validate": validate, "target": target,
            "strategy": strategy, "cost_model": cost_model_override,
            "measurer": measurer, "rerank_top_k": rerank_top_k})
        # a cost_model spec string resolves to a model instance up
        # front ("learned:PATH" / "calibrated:PATH" / "analytic") so
        # everything downstream — including the store-consistency check
        # below — sees the real object
        if isinstance(cfg.cost_model, str):
            from repro.measure.learned import resolve_cost_model
            cfg = cfg.replace(
                cost_model=resolve_cost_model(cfg.cost_model))
        self.config = cfg
        self.policy = policy
        self.mode = cfg.mode
        self.curated = cfg.curated
        # True adds the non-default registry rules (dtype, split_k) to
        # the proposal space; False keeps the classic four
        self.extended_rules = cfg.extended_rules
        self.max_steps = cfg.max_steps
        self.seed = cfg.seed
        self.validate = cfg.validate
        # optional TranspositionStore (core.engine): memoizes rewrites,
        # costs and oracle checks; None keeps the uncached serial path.
        # The store is an object-sharing seam, not optimizer config, so
        # it stays a first-class argument
        self.store = store
        # the hardware target every cost/reward is priced against
        # (None = registry default, tpu_v5e)
        self.target = hardware.resolve(cfg.target)
        # optional SearchStrategy (core.search) — when set, optimize()
        # explores the macro action space with it instead of running a
        # single mode-driven rollout
        self.strategy = (None if cfg.strategy is None
                         else S.get_strategy(cfg.strategy))
        # pluggable pricing (e.g. measure.CalibratedCostModel,
        # duck-typed: program_cost/total_s).  A store is bound to ONE
        # cost model — its (fp, target) memo does not encode the model
        # — so a mismatched pair would silently mix price systems
        self.cost_model = cfg.cost_model
        if (store is not None and cfg.cost_model is not None
                and getattr(store, "cost_model", None)
                is not cfg.cost_model):
            raise ValueError(
                "store and OptimizeConfig.cost_model disagree: build "
                "the TranspositionStore with cost_model=<the same "
                "object> (DESIGN.md §11)")
        # optional measured-execution reranking (measure/harness.py):
        # after the search, the top ``rerank_top_k`` candidate programs
        # are actually executed and timed, and the measured winner is
        # returned instead of the analytic one
        self.measurer = cfg.measurer
        self.rerank_top_k = int(cfg.rerank_top_k)
        # Micro Coding implementation: the structured registry engine by
        # default, or an LLM-backed coder ("llm*" specs / a shared
        # MicroCoder instance from the engine) — see micro_coding.get_coder
        self._coder = get_coder(cfg.coder)

    # -- cached primitives ---------------------------------------------------
    def _apply(self, prog, act):
        if self.store is not None:
            return self.store.apply(self._coder, prog, act)
        return self._coder.apply(prog, act)

    def _cost(self, prog) -> float:
        if self.store is not None:
            return self.store.cost(prog, self.target)
        if self.cost_model is not None:
            return self.cost_model.total_s(prog, self.target)
        return cost_model.program_cost(prog, self.target).total_s

    # -- action selection ----------------------------------------------------
    def _select(self, prog, cands, key, rng):
        if self.mode == "random" or (self.mode in ("policy", "untrained")
                                     and self.policy is None):
            return cands[rng.integers(len(cands))]
        if self.mode in ("policy", "untrained"):
            idx, _, _ = self.policy.act(prog, cands, key, greedy=False)
            return cands[idx]
        if self.mode == "greedy_cost":
            best, best_c = A.STOP, self._cost(prog)
            for a in cands:
                if rules.is_terminal(a):
                    continue
                r = self._apply(prog, a)
                if r.status == "ok":
                    c = self._cost(r.program)
                    if c < best_c * 0.999:
                        best, best_c = a, c
            return best
        raise ValueError(self.mode)

    # -- main loop -------------------------------------------------------------
    def optimize(self, task: KernelProgram) -> OptimizationResult:
        # scope LLM-coder transcripts/telemetry to this request's root
        # (no-op hook for coders without task state; thread-local inside
        # the coder, so evaluate_suite workers don't race)
        bind = getattr(self._coder, "bind_task", None)
        if bind is not None:
            bind(task)
        if self.strategy is not None:
            return self._search(task)
        rng = np.random.default_rng(self.seed)
        key = jax.random.PRNGKey(self.seed)
        if self.mode == "single_pass":
            return self._single_pass(task, rng, key)
        env_cfg = EnvConfig(max_steps=self.max_steps,
                            curated_actions=self.curated,
                            extended_rules=self.extended_rules)
        env = KernelEnv(task, self._coder, env_cfg, store=self.store,
                        target=self.target)
        state = env.reset()
        best = state
        # price the baseline through _cost, not env.baseline_s: with a
        # cost_model_override and no store the env prices analytically,
        # and mixing the two systems would corrupt best-tracking and
        # the reported speedup ratio (they agree whenever a store is
        # shared, since the store holds the pipeline's model)
        base_s = best_s = self._cost(task)
        best_steps = 0
        n_fail = 0
        visited = [(best_s, state)]
        for t in range(self.max_steps):
            cands = env.candidates()
            key, sub = jax.random.split(key)
            act = self._select(state, cands, sub, rng)
            res = env.step(act)
            if res.info["status"] in ("compile_error", "wrong_result"):
                n_fail += 1
            state = res.program
            s = self._cost(state)
            visited.append((s, state))
            if s < best_s:
                best, best_s, best_steps = state, s, t + 1
            if rules.is_terminal(act) or res.done:
                break
        best, best_s, meas, meas_base, reranked = self._maybe_rerank(
            task, S.top_candidates(visited), best, best_s)
        if reranked:
            best_steps = len(best.history) - len(task.history)
        correct = self._check(task, best)
        # steps/trace describe the BEST program (the one returned and
        # graded), not wherever the episode happened to wander afterwards
        return OptimizationResult(
            task.name, best, correct,
            base_s / best_s, best_steps, n_fail, best.history,
            measured_s=meas, measured_baseline_s=meas_base,
            reranked=reranked)

    def _search(self, task: KernelProgram) -> OptimizationResult:
        """Strategy-driven exploration (core.search) sharing the
        pipeline's store, target and action curation.  A pipeline built
        without a store gets a private one — strategies lean on the
        transposition property (beam siblings / restarts share every
        visited edge), so searching uncached would repeat rewrites."""
        store = self.store
        if store is None:
            from repro.core.engine import TranspositionStore
            store = TranspositionStore(cost_model=self.cost_model)
        out = self.strategy.search(
            task, coder=self._coder, store=store, target=self.target,
            max_steps=self.max_steps, seed=self.seed,
            curated=self.curated, extended=self.extended_rules,
            policy=self.policy)
        best, best_s, meas, meas_base, reranked = self._maybe_rerank(
            task, out.candidates, out.program, out.cost_s)
        steps = out.steps if not reranked else \
            len(best.history) - len(task.history)
        correct = True if not self.validate else \
            store.check(task, best)
        return OptimizationResult(
            task.name, best, correct,
            out.baseline_s / max(best_s, 1e-12), steps,
            out.n_failures, best.history,
            measured_s=meas, measured_baseline_s=meas_base,
            reranked=reranked)

    def _single_pass(self, task, rng, key) -> OptimizationResult:
        """'w/o Hier': commit to a full plan against the INITIAL state and
        apply all steps blindly; any failing step poisons the rest (the
        paper's observed single-pass failure mode)."""
        enum = (A.candidate_actions if self.curated
                else A.unrestricted_actions)
        cands = enum(task, target=self.target,
                     extended=self.extended_rules)
        n = min(self.max_steps, 4)
        plan = [cands[rng.integers(len(cands))] for _ in range(n)]
        prog = task
        n_fail = 0
        for act in plan:
            # regions/params were chosen against the initial program; they
            # may no longer exist after earlier rewrites
            res = self._apply(prog, act)
            if res.status != "ok":
                n_fail += 1
                continue
            prog = res.program
        base = self._cost(task)
        cur = self._cost(prog)
        # single-pass parity with LLM whole-kernel generation: any failed
        # step means the emitted kernel as a whole is wrong
        correct = (n_fail == 0) and self._check(task, prog)
        return OptimizationResult(task.name, prog, correct, base / cur,
                                  n, n_fail, prog.history)

    def _maybe_rerank(self, task, candidates, best, best_s):
        """Measured reranking of the search's top-K survivors.

        Measures the task (measured baseline) and the ``rerank_top_k``
        cheapest distinct candidates (analytic best included), then
        returns the measured-cheapest candidate that passes the oracle:
        ``(program, analytic_cost_s, measured_s, measured_baseline_s,
        reranked)``.  No measurer / empty candidates -> the analytic
        best, unchanged.  Measurement failures (ineligible lowering in
        ``mode="pallas"``) skip that candidate rather than the request.
        """
        if self.measurer is None or self.rerank_top_k <= 0 \
                or not candidates:
            return best, best_s, None, None, False
        from repro.measure.harness import MeasureError
        cands = list(candidates[:self.rerank_top_k])
        if all(p.fingerprint() != best.fingerprint()
               for _, p in cands):
            cands.append((best_s, best))
        try:
            base_t = self.measurer.measure(
                task, task, target=self.target).time_s
        except MeasureError:
            base_t = None
        timed = []
        for _, p in cands:
            try:
                m = self.measurer.measure(task, p, target=self.target)
            except MeasureError:
                continue
            timed.append((m.time_s, p.fingerprint(), p))
        timed.sort(key=lambda e: (e[0], e[1]))
        best_fp = best.fingerprint()
        for t, fp, p in timed:
            if fp == best_fp or self._check(task, p):
                return (p, self._cost(p), t, base_t, fp != best_fp)
        return best, best_s, None, base_t, False

    def _check(self, task: KernelProgram, prog: KernelProgram) -> bool:
        if not self.validate:
            return True
        if self.store is not None:
            return self.store.check(task, prog)
        inputs = make_inputs(task, jax.random.PRNGKey(CHECK_SEED))
        # the rewritten program's rules may relax the tolerance (e.g.
        # a reduced-precision dtype rewrite) — same per-output hook
        # the store's memoized check consults
        per_tol = rules.output_tolerances(prog, CHECK_RTOL, CHECK_ATOL)
        try:
            a = evaluate(task, inputs)
            b = evaluate(prog, inputs)
        except Exception:
            return False
        return rules.outputs_match(a, b, CHECK_RTOL, CHECK_ATOL,
                                   per_output=per_tol)


def suite_metrics(results: list[OptimizationResult]) -> dict:
    """Benchmark metrics over per-task results (paper Eqs. 3-4): execute
    accuracy, fast_1/fast_2, mean speedup (failed tasks count 0)."""
    n = len(results)
    acc = sum(r.correct for r in results) / n
    sp = [r.speedup if r.correct else 0.0 for r in results]
    fast1 = sum(s > 1.0 for s in sp) / n
    fast2 = sum(s > 2.0 for s in sp) / n
    return {"n": n, "accuracy": acc, "fast1": fast1, "fast2": fast2,
            "mean_speedup": float(np.mean(sp)),
            "results": results}


def evaluate_suite(tasks: list[KernelProgram], pipeline: MTMCPipeline
                   ) -> dict:
    """Serial reference evaluator (one task after another).  The batched,
    cached path is ``core.engine.EvalEngine.evaluate_suite`` — same
    metrics, shared transposition store, worker pool."""
    return suite_metrics([pipeline.optimize(t) for t in tasks])
