"""Blocked MXU matmul with fusable epilogue (Pallas TPU).

Grid (m, n, k) with a float32 VMEM accumulator; K is the sequential
("arbitrary") dimension, m/n are parallel.  The schedule controls:
  * blocks bm/bn/bk   — VMEM tiles (MXU-aligned multiples of 128),
  * loop_order        — grid permutation ("Reordering" action: K-innermost
                        reuses the accumulator; N-innermost maximises x-tile
                        reuse for wide outputs),
  * epilogue          — fused bias/activation/row-max ("Fusion" action),
  * pipeline_depth    — HBM->VMEM multi-buffering via dimension semantics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref
from repro.kernels.compat import CompilerParams
from repro.kernels.schedule import KernelSchedule, default_schedule


def _apply_epilogue(y, b_ref, epilogue):
    if "bias" in epilogue:
        y = y + b_ref[...].astype(jnp.float32)
    if epilogue.endswith("relu"):
        y = jnp.maximum(y, 0.0)
    elif epilogue.endswith("gelu"):
        y = jax.nn.gelu(y)
    elif epilogue.endswith("silu"):
        y = jax.nn.silu(y)
    return y


def _kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, k_axis: int,
            nk: int, epilogue: str, k_innermost: bool):
    ki = pl.program_id(k_axis)

    if k_innermost:
        # fast path: f32 VMEM accumulator lives across the K loop
        @pl.when(ki == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                                preferred_element_type=jnp.float32)

        @pl.when(ki == nk - 1)
        def _fin():
            o_ref[...] = _apply_epilogue(acc_ref[...], b_ref,
                                         epilogue).astype(o_ref.dtype)
    else:
        # K not innermost ("Reordering" away from the accumulator-friendly
        # order): revisit the output block — correct, but pays an HBM
        # round-trip per K step; the cost model prices this.
        @pl.when(ki == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        acc = o_ref[...].astype(jnp.float32) + jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

        @pl.when(ki < nk - 1)
        def _mid():
            o_ref[...] = acc.astype(o_ref.dtype)

        @pl.when(ki == nk - 1)
        def _fin():
            o_ref[...] = _apply_epilogue(acc, b_ref,
                                         epilogue).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("epilogue", "schedule",
                                             "interpret"))
def matmul(x: jax.Array, w: jax.Array, *, epilogue: str = "none",
           bias: jax.Array | None = None,
           schedule: KernelSchedule | None = None,
           interpret: bool = False) -> jax.Array:
    """x: (M,K) @ w: (K,N) -> (M,N), epilogue fused in-kernel."""
    if epilogue == "row_max":      # reduction epilogue: separate path
        y = matmul(x, w, epilogue="none", bias=None, schedule=schedule,
                   interpret=interpret)
        return jnp.max(y, axis=-1, keepdims=True)
    s = schedule or default_schedule("matmul")
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    bm, bn, bk = (min(s.block("bm", 128), M), min(s.block("bn", 128), N),
                  min(s.block("bk", 128), K))
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, s.blocks)
    order = tuple(s.loop_order) or ("m", "n", "k")
    sizes = {"m": M // bm, "n": N // bn, "k": K // bk}
    grid = tuple(sizes[a] for a in order)
    gi = {a: i for i, a in enumerate(order)}       # axis -> grid position

    def idx(*axes):
        def index_map(*g):
            return tuple(g[gi[a]] if a is not None else 0 for a in axes)
        return index_map

    if bias is None:
        bias = jnp.zeros((N,), x.dtype)
    sem = tuple("arbitrary" if a == "k" else "parallel" for a in order)
    k_innermost = order[-1] == "k"
    out = pl.pallas_call(
        functools.partial(_kernel, k_axis=gi["k"], nk=sizes["k"],
                          epilogue=epilogue, k_innermost=k_innermost),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), idx("m", "k")),
            pl.BlockSpec((bk, bn), idx("k", "n")),
            pl.BlockSpec((bn,), idx("n")),
        ],
        out_specs=pl.BlockSpec((bm, bn), idx("m", "n")),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(dimension_semantics=sem),
        interpret=interpret,
    )(x, w, bias)
    return out


reference = ref.matmul
