"""Decode-vs-teacher-forcing consistency for every family.

The strongest end-to-end correctness check we have: running the model
token-by-token through its decode cache (KV / ring-buffer / wkv state /
ssm state / conv state) must reproduce the full-sequence forward logits
at every position.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, reduced
from repro.models import api

T = 8


def _decode_all(cfg, params, toks, max_len=16):
    model = api.get_model(cfg)
    cache = api.init_cache(cfg, toks.shape[0], max_len)
    outs = []
    for t in range(toks.shape[1]):
        logits, cache = model.decode_step(
            cfg, params, cache, toks[:, t:t + 1], jnp.int32(t))
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1)


@pytest.mark.parametrize("arch", ["qwen2_5_3b", "qwen3_14b", "yi_34b",
                                  "phi3_5_moe_42b", "rwkv6_3b",
                                  "hymba_1_5b"])
def test_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    model = api.get_model(cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, T), 1,
                              cfg.true_vocab_size)
    full, _ = model.forward(cfg, params, {"tokens": toks}, remat=False)
    dec = _decode_all(cfg, params, toks)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_decode_matches_forward_encdec():
    cfg = reduced(get_config("seamless_m4t_medium"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    from repro.models import encdec
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (2, T), 1, cfg.true_vocab_size)
    enc = jax.random.normal(jax.random.fold_in(key, 1),
                            (2, cfg.enc_len, cfg.d_model))
    full, _ = encdec.forward(cfg, params, {"tokens": toks,
                                           "enc_embeds": enc},
                             remat=False)
    # build the decode cache: cross K/V from the encoder output
    enc_out = encdec.encode(cfg, params, enc, remat=False)
    cache = api.init_cache(cfg, 2, 16)
    ck, cv = [], []
    for i in range(cfg.n_layers):
        p = jax.tree.map(lambda a: a[i], params["blocks"])
        k, v = encdec._enc_kv(cfg, p, enc_out)
        ck.append(k)
        cv.append(v)
    cache["cross_k"] = jnp.stack(ck).astype(cache["cross_k"].dtype)
    cache["cross_v"] = jnp.stack(cv).astype(cache["cross_v"].dtype)
    outs = []
    for t in range(T):
        logits, cache = encdec.decode_step(
            cfg, params, cache, toks[:, t:t + 1], jnp.int32(t))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_decode_matches_forward_vlm():
    """PaliGemma: prefix embeddings enter via forward; decode continues
    text positions after the prefix."""
    cfg = reduced(get_config("paligemma_3b"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    from repro.models import transformer
    key = jax.random.PRNGKey(1)
    P = cfg.prefix_len
    toks = jax.random.randint(key, (2, T), 1, cfg.true_vocab_size)
    pre = 0.1 * jax.random.normal(jax.random.fold_in(key, 2),
                                  (2, P, cfg.d_model))
    full, _ = transformer.forward(
        cfg, params, {"tokens": toks, "prefix_embeds": pre},
        remat=False)
    # teacher-force the decode from a cache prefilled by forward
    logits, aux, (ks, vs) = transformer.forward(
        cfg, params, {"tokens": toks[:, :-1], "prefix_embeds": pre},
        remat=False, collect_cache=True)
    S0 = P + T - 1
    cache = api.init_cache(cfg, 2, P + T + 4)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    step_logits, _ = transformer.decode_step(
        cfg, params, cache, toks[:, -1:], jnp.int32(S0))
    np.testing.assert_allclose(np.asarray(step_logits[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=2e-2, atol=2e-2)
