"""Offline trajectory collection -> tree-structured RL environment.

Mirrors the paper's "compact human-curated dataset" of optimization
trajectories: for each training task we roll out exploration policies
(epsilon-greedy on the cost model + uniform random) through the live
MicroCoder, materializing every visited (state, action) transition into
the task's OfflineTree.  The PPO loop then trains entirely offline (no
live Micro Coding latency — the paper's stated motivation).

Scaled to CPU budget: ~10^3-10^4 transitions rather than 60k trajectories;
``collect`` is embarrassingly parallel across tasks/seeds on a fleet.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import actions as A
from repro.core import rules
from repro.core.env import EnvConfig, KernelEnv, OfflineTree
from repro.core.kernel_ir import KernelProgram
from repro.core.micro_coding import StructuredMicroCoder


@dataclasses.dataclass
class CollectConfig:
    episodes_random: int = 6
    episodes_greedy: int = 4
    max_steps: int = 8
    eps: float = 0.35              # greedy-explorer epsilon
    seed: int = 0
    max_actions_per_node: int = 48


def _greedy_action(tree: OfflineTree, fp: str, cands, coder, rng):
    """Pick the materialized-or-new action with best cost-model child."""
    best, best_cost = None, np.inf
    for a in cands:
        if rules.is_terminal(a):
            continue
        child, status = tree.expand(fp, a, coder)
        if status == "ok" and child is not None:
            c = tree.nodes[child].cost_s
            if c < best_cost:
                best, best_cost = a, c
    return best


def collect(task: KernelProgram, ccfg: CollectConfig | None = None,
            env_cfg: EnvConfig | None = None, store=None, target=None,
            reward_source=None) -> OfflineTree:
    """``store`` (core.engine.TranspositionStore) lets collection reuse —
    and feed — the same transposition table the evaluation engine uses.
    ``reward_source`` (core.env.RewardSource) prices the tree's node
    costs — the costs PPO's offline replay rewards against — e.g. a
    ``MeasuredRewardSource`` replaying a MeasureDB (DESIGN.md §14).
    Config defaults are None (fresh per call), never shared dataclass
    instances."""
    ccfg = ccfg if ccfg is not None else CollectConfig()
    env_cfg = env_cfg if env_cfg is not None else EnvConfig()
    rng = np.random.default_rng(ccfg.seed)
    coder = StructuredMicroCoder()
    tree = OfflineTree(task, store=store, target=target,
                       reward_source=reward_source)
    env = KernelEnv(task, coder, env_cfg, store=store, target=target,
                    reward_source=reward_source)

    def rollout(pick):
        fp = tree.root
        for _ in range(ccfg.max_steps):
            prog = tree.nodes[fp].program
            # the env owns enumeration (curated/extended/target come
            # from its config) — collection proposes what it would see
            cands = env.candidates(prog)
            if len(cands) > ccfg.max_actions_per_node:
                idx = rng.choice(len(cands),
                                 ccfg.max_actions_per_node, replace=False)
                cands = [cands[i] for i in idx] + [A.STOP]
            a = pick(fp, cands)
            if a is None or rules.is_terminal(a):
                break
            child, status = tree.expand(fp, a, coder)
            if status != "ok" or child is None:
                continue                      # stay, try another action
            fp = child

    for _ep in range(ccfg.episodes_random):
        rollout(lambda fp, cands: cands[rng.integers(len(cands))])
    for _ep in range(ccfg.episodes_greedy):
        def pick(fp, cands):
            if rng.random() < ccfg.eps:
                return cands[rng.integers(len(cands))]
            return _greedy_action(tree, fp, cands, coder, rng)
        rollout(pick)
    return tree


def collect_suite(tasks: list[KernelProgram],
                  ccfg: CollectConfig | None = None,
                  env_cfg: EnvConfig | None = None, store=None,
                  target=None, reward_source=None
                  ) -> dict[str, OfflineTree]:
    ccfg = ccfg if ccfg is not None else CollectConfig()
    out = {}
    for i, t in enumerate(tasks):
        c = dataclasses.replace(ccfg, seed=ccfg.seed + i)
        out[t.name] = collect(t, c, env_cfg, store=store, target=target,
                              reward_source=reward_source)
    return out


def tree_stats(tree: OfflineTree) -> dict:
    n_edges = sum(len(n.children) for n in tree.nodes.values())
    ok = sum(1 for n in tree.nodes.values()
             for c, s in n.children.values() if s == "ok")
    best = min(n.cost_s for n in tree.nodes.values())
    root = tree.nodes[tree.root].cost_s
    return {"nodes": tree.size, "edges": n_edges, "ok_edges": ok,
            "best_speedup": root / best}
