"""The unified ``OptimizeConfig`` surface (core/config.py, DESIGN.md §14).

Covers: every entry point accepting ``config=``, the deprecation shims
(legacy kwargs -> identical outcomes + exactly one DeprecationWarning
per entry point), the config-xor-legacy TypeError, the cost-model
consistency check, strategy-registry semantics, and the repo-wide AST
gate that no in-repo call site still uses the deprecated kwargs.
"""
import os
import sys
import warnings

import pytest

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import (EvalEngine, MTMCPipeline, OptimizeConfig,
                        TranspositionStore,
                        reset_deprecation_warnings)
from repro.core import tasks as T
from repro.core.autotune import tune_model_kernels
from repro.core.search import (PolicySearch, STRATEGIES, get_strategy,
                               register_strategy)
from repro.measure.calibrate import CalibratedCostModel, Calibration
from repro.serve.engine import KernelService
from repro.serve.fleet import Fleet, FleetConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TASK = T.kb_level1()[0]
FAST = OptimizeConfig(mode="greedy_cost", max_steps=3, validate=False)


def _outcome(res):
    return (res.program.fingerprint(), res.speedup, tuple(res.trace),
            res.correct)


# ---------------------------------------------------------------------------
# config= everywhere, shims produce identical outcomes
# ---------------------------------------------------------------------------

def test_pipeline_config_and_legacy_agree():
    reset_deprecation_warnings()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = MTMCPipeline(mode="greedy_cost", max_steps=3,
                              validate=False)
        MTMCPipeline(mode="greedy_cost", max_steps=3, validate=False)
    deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(deps) == 1, "legacy kwargs must warn exactly once"
    assert "OptimizeConfig" in str(deps[0].message)
    new = MTMCPipeline(config=FAST)
    assert _outcome(legacy.optimize(TASK)) == _outcome(new.optimize(TASK))
    assert new.config == FAST


def test_pipeline_rejects_config_plus_legacy():
    with pytest.raises(TypeError, match="not both"):
        MTMCPipeline(config=FAST, max_steps=5)


def test_engine_config_and_legacy_agree():
    reset_deprecation_warnings()
    store = TranspositionStore()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = EvalEngine(store=store, mode="greedy_cost",
                            max_steps=3, validate=False, seed=1)
    assert sum(issubclass(x.category, DeprecationWarning)
               for x in w) == 1
    new = EvalEngine(store=store, config=FAST.replace(seed=1))
    assert legacy.cfg == new.cfg
    m_legacy = legacy.evaluate_suite([TASK])
    m_new = new.evaluate_suite([TASK])
    assert m_legacy["mean_speedup"] == m_new["mean_speedup"]
    assert m_legacy["accuracy"] == m_new["accuracy"]


def test_engine_keeps_cfg_and_workers_first_class():
    eng = EvalEngine(config=FAST, workers=3, seed_stride=2)
    assert eng.cfg.workers == 3 and eng.cfg.seed_stride == 2
    # the EngineConfig object path still works, without warnings
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        eng2 = EvalEngine(cfg=eng.cfg)
    assert not [x for x in w
                if issubclass(x.category, DeprecationWarning)]
    assert eng2.cfg == eng.cfg
    with pytest.raises(TypeError, match="not both"):
        EvalEngine(cfg=eng.cfg, config=FAST)
    with pytest.raises(TypeError, match="not both"):
        EvalEngine(cfg=eng.cfg, mode="random")


def test_service_config_and_legacy_agree():
    reset_deprecation_warnings()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = KernelService(mode="greedy_cost", max_steps=3,
                               serve_workers=1)
    assert sum(issubclass(x.category, DeprecationWarning)
               for x in w) == 1
    new = KernelService(config=OptimizeConfig(mode="greedy_cost",
                                              max_steps=3,
                                              rerank_top_k=4),
                        serve_workers=1)
    try:
        assert legacy._engine.cfg == new._engine.cfg
        r1 = legacy.optimize(TASK)
        r2 = new.optimize(TASK)
        assert r1.program.fingerprint() == r2.program.fingerprint()
    finally:
        legacy.close()
        new.close()


def test_service_defaults_unchanged():
    svc = KernelService(serve_workers=1)
    try:
        assert svc.config.mode == "greedy_cost"
        assert svc.config.rerank_top_k == 4
        # without a harness the engine's effective rerank depth is 0
        assert svc._engine.cfg.rerank_top_k == 0
    finally:
        svc.close()


def test_fleet_accepts_config_and_folds_legacy(tmp_path):
    cfg = OptimizeConfig(mode="greedy_cost", max_steps=3)
    fl = Fleet(str(tmp_path / "db1"), FleetConfig(replicas=1),
               auto_start=False, config=cfg, serve_workers=1)
    reset_deprecation_warnings()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        fl2 = Fleet(str(tmp_path / "db2"), FleetConfig(replicas=1),
                    auto_start=False, max_steps=3, serve_workers=1)
    try:
        assert sum(issubclass(x.category, DeprecationWarning)
                   for x in w) == 1
        assert (fl.replicas[0]._engine.cfg
                == fl2.replicas[0]._engine.cfg)
        # per-role rerank depths: replicas 0, refiner FleetConfig's
        assert fl.replicas[0].config.rerank_top_k == 0
        assert fl.refiner.config.rerank_top_k == \
            FleetConfig().rerank_top_k
        with pytest.raises(TypeError, match="rerank_top_k"):
            Fleet(str(tmp_path / "db3"), auto_start=False,
                  rerank_top_k=2)
    finally:
        fl.close()
        fl2.close()


def test_tune_model_kernels_accepts_config():
    mcfg = ModelConfig(name="cfgtest", family="dense", n_layers=1,
                       d_model=128, n_heads=2, n_kv_heads=2, d_ff=256,
                       vocab_size=256)
    shape = ShapeConfig("tiny", 128, 1, "train")
    report = tune_model_kernels(
        mcfg, shape, config=OptimizeConfig(mode="greedy_cost",
                                           validate=False, max_steps=2))
    assert report and all("speedup" in v for v in report.values())
    with pytest.raises(ValueError, match="not both"):
        tune_model_kernels(mcfg, shape,
                           pipeline=MTMCPipeline(config=FAST),
                           config=FAST)


# ---------------------------------------------------------------------------
# cost-model duality collapsed into one field
# ---------------------------------------------------------------------------

def test_cost_model_field_consistency_check():
    cal = CalibratedCostModel(Calibration(factors=(), n_samples=()))
    store = TranspositionStore(cost_model=cal)
    # matching pair: fine, and the pipeline prices through it
    pipe = MTMCPipeline(config=FAST.replace(cost_model=cal),
                        store=store)
    assert pipe.cost_model is cal
    # mismatched pair: refused (the store is bound to ONE model)
    other = TranspositionStore()
    with pytest.raises(ValueError, match="cost_model"):
        MTMCPipeline(config=FAST.replace(cost_model=cal), store=other)
    # legacy spelling routes through the same field and check
    reset_deprecation_warnings()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        with pytest.raises(ValueError, match="cost_model"):
            MTMCPipeline(cost_model_override=cal, store=other)


# ---------------------------------------------------------------------------
# strategy registry
# ---------------------------------------------------------------------------

def test_strategy_registry_semantics():
    assert set(STRATEGIES) >= {"greedy", "beam", "anneal", "policy"}
    assert isinstance(get_strategy("policy"), PolicySearch)
    inst = PolicySearch(width=2)
    assert get_strategy(inst) is inst
    with pytest.raises(KeyError, match="registered"):
        get_strategy("mcts")
    with pytest.raises(ValueError, match="already registered"):
        register_strategy("policy", PolicySearch)
    with pytest.raises(ValueError, match="non-empty"):
        register_strategy("", PolicySearch)
    # replace=True swaps the factory; restore the original after
    class _Custom(PolicySearch):
        pass
    register_strategy("policy", _Custom, replace=True)
    try:
        assert isinstance(get_strategy("policy"), _Custom)
    finally:
        register_strategy("policy", PolicySearch, replace=True)


# ---------------------------------------------------------------------------
# repo-wide gate: no in-repo call site uses the deprecated kwargs
# ---------------------------------------------------------------------------

def test_no_in_repo_call_site_uses_deprecated_kwargs():
    """src/, benchmarks/ and examples/ must construct through
    ``config=OptimizeConfig(...)``; only tests exercise the shims.
    The AST walk lives in tools/repolint.py (shared with CI); this
    test pins it into tier 1."""
    sys.path.insert(0, os.path.join(REPO, "tools"))
    try:
        import repolint
    finally:
        sys.path.pop(0)
    offenders = repolint.lint_config_kwargs(REPO)
    assert not offenders, (
        "deprecated optimizer kwargs at:\n" + "\n".join(offenders))
