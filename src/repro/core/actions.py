"""Semantic optimization action space (Macro Thinking's vocabulary).

An action = (optimization type, code region, parameter) — exactly the
paper's "(Optimization Type, Code Region)" with the concrete knob value.
What kinds exist, how their candidates are enumerated, when they are
legal and how they rewrite the IR all live in the declarative rewrite-
rule registry (``core/rules.py``); this module keeps the ``Action``
record itself plus the dataflow helper the fusion rule enumerates from.

``candidate_actions`` is the curated space ("w/ AS" in Table 7): only
hardware-meaningful values, with tile presets derived from the active
``HardwareTarget``'s lane/sublane geometry and VMEM capacity.
``unrestricted_actions`` is the "w/o AS" ablation: it also proposes
misaligned tiles, bogus regions and illegal fusions — the way an
unconstrained LLM does.  ``extended=True`` adds the non-default rules
(``dtype``, ``split_k``) to either space.
"""
from __future__ import annotations

import dataclasses

from repro.core.kernel_ir import KernelProgram


@dataclasses.dataclass(frozen=True)
class Action:
    kind: str          # a registered rule kind (core/rules.py) | stop
    region: str        # group root node name ("" for stop)
    param: tuple = ()  # knob payload, hashable

    def describe(self) -> str:
        from repro.core import rules
        return rules.describe(self)


STOP = Action("stop", "")


def fusion_candidates(prog: KernelProgram) -> list[tuple[str, str]]:
    """Adjacent (producer_root, consumer_root) group pairs (dataflow)."""
    roots = {}
    for g in prog.fusion_groups:
        for n in g:
            roots[n] = prog.group_root(g)
    pairs = []
    nm = prog.node_map
    for n in prog.nodes:
        for inp in n.inputs:
            if inp in nm and roots[inp] != roots[n.name]:
                pairs.append((roots[inp], roots[n.name]))
    return sorted(set(pairs))


def candidate_actions(prog: KernelProgram, target=None,
                      extended: bool = False) -> list[Action]:
    from repro.core import rules
    return rules.candidate_actions(prog, target=target,
                                   extended=extended)


def unrestricted_actions(prog: KernelProgram, target=None,
                         extended: bool = False) -> list[Action]:
    """'w/o AS' ablation: adds invalid-prone proposals."""
    from repro.core import rules
    return rules.unrestricted_actions(prog, target=target,
                                      extended=extended)
