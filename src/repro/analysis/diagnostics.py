"""Diagnostic records and the stable MT0xx code registry.

Every static-analysis finding — and every legality failure the rewrite
rules raise — is one ``Diagnostic``: a stable code, a severity, the
node/group span it anchors to, a human message and a fix-hint.  Codes
are REGISTERED here and never renumbered (tests golden-match them;
external tooling may grep logs for them), exactly like a compiler's
diagnostic registry.

This module is a leaf: it imports nothing from ``repro.core`` so the
rule registry (``core/rules.py``) can attach diagnostics to its
``CompileError``s without an import cycle (the analysis passes import
the core; the core imports only this record type).

Code blocks (DESIGN.md §15):

  MT001-MT019   well-formedness (verifier pass)
  MT020-MT029   target legality (schedule analyzer pass)
  MT030-MT039   rule soundness (differential harness)
"""
from __future__ import annotations

import dataclasses

ERROR = "error"
WARNING = "warning"

#: code -> (default severity, one-line meaning).  Append-only: codes
#: are stable identifiers (golden-tested); never renumber or reuse.
CODES: dict[str, tuple[str, str]] = {
    # -- well-formedness (verifier) -------------------------------------
    "MT001": (ERROR, "duplicate tensor name (node shadows a node or input)"),
    "MT002": (ERROR, "reference to an undefined tensor"),
    "MT003": (ERROR, "unknown op kind"),
    "MT004": (ERROR, "wrong operand count for op"),
    "MT005": (ERROR, "operand shapes incompatible with op"),
    "MT006": (WARNING, "operand dtypes inconsistent with shape inference"),
    "MT007": (ERROR, "program output names no node or input"),
    "MT008": (WARNING, "dead node: result feeds no node and no output"),
    "MT009": (WARNING, "unused program input"),
    "MT010": (ERROR, "fusion groups are not a partition of the nodes"),
    "MT011": (ERROR, "fused group matches no kernel template"),
    "MT012": (ERROR, "schedule keyed on a name that is no group root"),
    "MT013": (ERROR, "cyclic or forward reference (use before def)"),
    "MT014": (ERROR, "fusion group is not dataflow-connected"),
    "MT015": (ERROR, "invalid or unsupported tensor dtype"),
    # -- target legality (schedule analyzer) ----------------------------
    "MT020": (ERROR, "tile parameter not applicable to kernel kind"),
    "MT021": (ERROR, "tile does not divide its dimension (grid)"),
    "MT022": (ERROR, "tile violates lane/sublane alignment"),
    "MT023": (ERROR, "VMEM overflow: tiles x pipeline depth exceed capacity"),
    "MT024": (ERROR, "pipeline depth out of range"),
    "MT025": (ERROR, "invalid loop order"),
    "MT026": (ERROR, "compute dtype unsupported on target"),
    "MT027": (ERROR, "invalid split_k schedule flag"),
    "MT028": (ERROR, "unknown schedule epilogue"),
    # -- rule soundness (differential harness) --------------------------
    "MT030": (ERROR, "rule rewrite produced a program the verifier rejects"),
    "MT031": (WARNING, "enumerated candidate rejected by its own rule"),
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One analysis finding, anchored to the nodes/groups it concerns.

    ``span`` is a tuple of node (or input/group-root) names — the IR has
    no source text, so names are its line numbers.  ``render()`` is the
    stable one-line form golden tests and the lint CLI print.
    """

    code: str
    message: str
    span: tuple[str, ...] = ()
    hint: str = ""
    severity: str = ""      # "" -> the code's registered default

    def __post_init__(self):
        if self.code not in CODES:
            raise ValueError(f"unregistered diagnostic code {self.code!r}")
        if not self.severity:
            object.__setattr__(self, "severity", CODES[self.code][0])

    @property
    def is_error(self) -> bool:
        return self.severity == ERROR

    def render(self, program: str = "") -> str:
        where = ",".join(self.span) if self.span else "<program>"
        head = f"{program}:{where}" if program else where
        out = f"{head}: {self.severity} {self.code}: {self.message}"
        if self.hint:
            out += f" [hint: {self.hint}]"
        return out


def error(code: str, message: str, *, span: tuple[str, ...] = (),
          hint: str = "") -> Diagnostic:
    return Diagnostic(code, message, span=span, hint=hint,
                      severity=ERROR)


def warning(code: str, message: str, *, span: tuple[str, ...] = (),
            hint: str = "") -> Diagnostic:
    return Diagnostic(code, message, span=span, hint=hint,
                      severity=WARNING)


class AnalysisError(Exception):
    """A program was rejected by static analysis.

    Raised by the gating integrations (measure harness, serve path) so
    callers get the diagnostics themselves instead of a deep stack
    trace out of a lowerer.  ``diagnostics`` holds every finding, worst
    first; ``str()`` renders them one per line.
    """

    def __init__(self, diagnostics: tuple[Diagnostic, ...],
                 program: str = ""):
        self.diagnostics = tuple(diagnostics)
        self.program = program
        super().__init__("\n".join(
            d.render(program) for d in self.diagnostics)
            or "static analysis rejected the program")
