"""rwkv6-3b [ssm] — Finch, data-dependent decay, attention-free.
[arXiv:2404.05892]  head_dim=64 -> 40 heads; O(1) state => long-context OK.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="rwkv",
    n_layers=32,
    d_model=2560,
    n_heads=40,          # rwkv6 heads: d_model / 64
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    supports_long_context=True,
)
