"""Serving engine: prefill + token-level continuous-batching decode.

``make_serve_step`` (one token for the whole batch against a KV cache)
is the function the decode_* / long_* dry-run cells lower.  ``Engine``
below runs real generation for the examples/tests (transformer
families; rwkv/hymba decode through their own cache trees), and
``KernelService`` is kernel-optimization-as-a-service on top of
``core.engine`` with request coalescing and segmented-LRU store
eviction.
"""
from __future__ import annotations

import collections
import concurrent.futures as cf
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.config import UNSET, OptimizeConfig
from repro.models import api


def make_serve_step(cfg: ModelConfig, *, rules=None):
    model = api.get_model(cfg)

    def serve_step(params, cache, tokens, pos, start=None):
        # ``start`` fences cache positions below it (left-padded
        # prefills); only the transformer families take it, and only
        # when the caller passes one
        kw = {} if start is None else {"start": start}
        return model.decode_step(cfg, params, cache, tokens, pos,
                                 rules=rules, **kw)
    return serve_step


def prefill_transformer(cfg: ModelConfig, params, tokens, max_len: int,
                        lengths=None):
    """Run the prompt through forward(collect_cache) and build a cache.

    ``tokens`` is (B, S) with prompts right-aligned (left-padded).  For
    mixed-length batches pass ``lengths`` (B,): pad positions are then
    masked out of the prefill attention.  Without the mask, pad keys
    and values both contaminate the prefill logits of shorter rows AND
    sit live in cache positions ``0..S-1``, where an unfenced decode
    attends to them — the classic mixed-length corruption.  Decode
    after a masked prefill must fence the cache with
    ``serve_step(..., start=S - lengths)``.
    """
    from repro.models import transformer
    B, S = tokens.shape
    pad_mask = None
    if lengths is not None:
        lengths = jnp.asarray(lengths)
        pad_mask = jnp.arange(S)[None, :] >= (S - lengths)[:, None]
    logits, aux, (ks, vs) = transformer.forward(
        cfg, params, {"tokens": tokens}, remat=False, collect_cache=True,
        pad_mask=pad_mask)
    cache = api.init_cache(cfg, B, max_len)
    k = jax.lax.dynamic_update_slice(
        cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    v = jax.lax.dynamic_update_slice(
        cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    return logits, {"k": k, "v": v}


@dataclasses.dataclass
class Request:
    prompt: jnp.ndarray           # (S,) int32
    max_new_tokens: int = 16
    eos_id: int | None = None     # per-request EOS (None: never stops)
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    truncated: bool = False       # hit max_len before max_new_tokens


class Engine:
    """Token-level continuous batching for dense transformer families.

    One persistent KV cache of ``batch_slots`` rows; every request owns
    one slot for its lifetime.  Each scheduler step (a) refills freed
    slots from the queue — a joining request is prefilled solo (B=1,
    right-padded to a power-of-two length bucket, so no left-pad ever
    enters the cache) and its K/V rows are written into the slot — and
    (b) runs ONE batched decode step with per-slot positions: slots at
    different depths decode together, the per-slot attention mask
    (``kpos <= pos[slot]``) fences each row to its own written cache
    prefix, so freed/stale slot contents are never attended.  Requests
    retire individually on their own EOS / token budget / cache-full
    (reported via ``Request.truncated``) and their slot refills on the
    very next step — no group barrier.  Per-slot decode is
    mathematically independent across rows, so batched output is
    token-identical to per-prompt solo generation (tier-1 parity test).
    """

    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 128,
                 batch_slots: int = 4, greedy: bool = True,
                 eos_id: int | None = None):
        assert cfg.family in ("dense", "moe", "vlm")
        if not greedy:
            raise NotImplementedError(
                "only greedy decoding is implemented; sampling would "
                "need per-slot RNG state threaded through run()")
        self.cfg, self.params = cfg, params
        self.max_len, self.slots = max_len, batch_slots
        self.greedy = greedy
        self.eos_id = eos_id
        # the cache is rebound from the return value every step and
        # never aliased, so donating it avoids an O(cache) copy per
        # generated token
        self.serve_step = jax.jit(make_serve_step(cfg),
                                  donate_argnums=1)

        def _prefill(params, toks):
            from repro.models import transformer
            logits, _, (ks, vs) = transformer.forward(
                cfg, params, {"tokens": toks}, remat=False,
                collect_cache=True)
            return logits, ks, vs
        self._prefill = jax.jit(_prefill)

        def _insert(cache, ks, vs, slot):
            # one fused in-place row write (the cache buffer is
            # donated): un-jitted .at[].set here would copy the whole
            # (L, slots, max_len, KV, hd) cache twice per admission
            k = jax.lax.dynamic_update_slice(
                cache["k"], ks.astype(cache["k"].dtype),
                (0, slot, 0, 0, 0))
            v = jax.lax.dynamic_update_slice(
                cache["v"], vs.astype(cache["v"].dtype),
                (0, slot, 0, 0, 0))
            return {"k": k, "v": v}
        self._insert = jax.jit(_insert, donate_argnums=0)
        self.stats = {"prefills": 0, "decode_steps": 0,
                      "decode_tokens": 0, "completed": 0,
                      "truncations": 0, "occupancy_sum": 0.0}

    # -- public API ----------------------------------------------------------
    def generate(self, prompts: list[jnp.ndarray],
                 max_new_tokens: int = 16) -> list[list[int]]:
        """Continuous batching: queued prompts join the running batch as
        slots free, one request at a time."""
        reqs = [Request(p, max_new_tokens, self.eos_id) for p in prompts]
        self.run(reqs)
        return [r.out for r in reqs]

    def run(self, requests: list[Request]) -> list[Request]:
        """Drive every request to completion; returns the same list with
        ``out``/``done``/``truncated`` filled in."""
        B = self.slots
        cache = api.init_cache(self.cfg, B, self.max_len)
        queue = collections.deque(requests)
        slot: list[Request | None] = [None] * B
        pos = np.zeros(B, np.int64)       # next write position per slot
        pending = np.zeros(B, np.int64)   # next input token per slot
        while queue or any(r is not None for r in slot):
            for s in range(B):
                if slot[s] is not None or not queue:
                    continue
                r = queue.popleft()
                if r.max_new_tokens <= 0:
                    r.done = True
                    self.stats["completed"] += 1
                    continue
                cache, first = self._admit(cache, s, r)
                slot[s] = r
                pos[s] = min(len(np.asarray(r.prompt)), self.max_len - 1)
                pending[s] = first
                r.out.append(first)
                self._retire(slot, s, pos)
            active = [s for s in range(B) if slot[s] is not None]
            if not active:
                continue
            toks = jnp.asarray(pending[:, None], jnp.int32)
            posv = jnp.asarray(np.minimum(pos, self.max_len - 1),
                               jnp.int32)
            logits, cache = self.serve_step(self.params, cache, toks,
                                            posv)
            nxt = np.asarray(jnp.argmax(logits[:, -1], -1))
            self.stats["decode_steps"] += 1
            self.stats["decode_tokens"] += len(active)
            self.stats["occupancy_sum"] += len(active) / B
            for s in active:
                pos[s] += 1
                pending[s] = int(nxt[s])
                slot[s].out.append(int(nxt[s]))
                self._retire(slot, s, pos)
        return requests

    # -- scheduler internals -------------------------------------------------
    def _admit(self, cache, s: int, r: Request):
        """Solo-prefill ``r`` and write its K/V rows into slot ``s``.

        The prompt is RIGHT-padded to a power-of-two bucket (bounded
        recompiles): under causal attention the real tokens never see
        the tail pad, and the pad K/V written past the prompt length
        are overwritten by decode before any step attends that far —
        so no mask is needed and the slot is bit-identical to a solo
        prefill."""
        p = np.asarray(r.prompt)
        if len(p) >= self.max_len:        # leave room for >= 1 token
            r.truncated = True
            p = p[: self.max_len - 1]
        n = len(p)
        bucket = 1
        while bucket < n:
            bucket *= 2
        bucket = min(bucket, self.max_len)
        toks = jnp.asarray(np.pad(p, (0, bucket - n)), jnp.int32)[None]
        logits, ks, vs = self._prefill(self.params, toks)
        # the whole bucket row is written, pad K/V included: decode
        # overwrites position p before any step attends p, so the tail
        # pad (like a freed slot's stale lines) is never read
        cache = self._insert(cache, ks, vs, jnp.int32(s))
        self.stats["prefills"] += 1
        return cache, int(jnp.argmax(logits[0, n - 1]))

    def _retire(self, slot, s: int, pos) -> None:
        r = slot[s]
        if r.eos_id is not None and r.out and r.out[-1] == r.eos_id:
            r.done = True
        if len(r.out) >= r.max_new_tokens:
            r.done = True
        elif pos[s] >= self.max_len and not r.done:
            # the cache is full mid-request: surface it instead of
            # silently breaking the whole group (the old lockstep bug)
            r.done = r.truncated = True
            self.stats["truncations"] += 1
        if r.done:
            slot[s] = None
            self.stats["completed"] += 1


class KernelService:
    """Kernel-optimization-as-a-service on top of ``core.engine``.

    A long-lived server process keeps ONE transposition store: repeated
    or similar optimization requests (the common case in production —
    many users submitting the same hot kernels) hit cached rewrites,
    cost pricing and oracle checks instead of redoing the search
    substrate.  Two production behaviours on top (DESIGN.md §10):

    * **Request coalescing** — concurrent identical requests (same
      ``(task fingerprint, target, seed)``) share ONE in-flight search
      through a futures map: ``submit()`` returns the already-running
      future instead of spawning a duplicate; ``stats()["coalesced"]``
      counts the joins.
    * **Segmented-LRU slab eviction** — past ``max_programs`` the store
      evicts its coldest fingerprints (and their cost/edge/check/oracle
      entries) in slabs instead of being dropped wholesale, so a hot
      working set never cold-starts under sustained distinct-kernel
      traffic.  In-flight request roots are never evicted.
    * **Measured mode** (DESIGN.md §11) — ``measure=True`` attaches a
      ``measure.ExecutionHarness``: every search's top-``rerank_top_k``
      survivors are actually executed and timed, the measured winner is
      returned/installed, and with ``measure_db=<dir>`` samples AND the
      per-task winning program persist on disk — a RESTARTED service
      pointed at the same directory answers repeat requests straight
      from ``winners/`` without re-running the search (warm start).
      ``stats()`` exposes ``measured`` / ``db_hits`` / ``db_misses`` /
      ``warm_starts``.
    """

    #: historical service defaults: cheap greedy descent, measured
    #: reranking depth 4 (only active once a harness is attached)
    DEFAULTS = None  # filled below the class (needs OptimizeConfig)

    def __init__(self, policy=None, *, config=None, workers: int = 0,
                 store=None, max_programs: int = 200_000,
                 serve_workers: int = 4, evict_slab: int | None = None,
                 measure: bool = False, measure_db: str | None = None,
                 measure_cfg=None, mode=UNSET, max_steps=UNSET,
                 target=UNSET, strategy=UNSET, rerank_top_k=UNSET):
        from repro.core import hardware
        from repro.core.config import resolve_config
        from repro.core.engine import EvalEngine, TranspositionStore
        cfg = resolve_config(
            "KernelService", config,
            {"mode": mode, "max_steps": max_steps, "target": target,
             "strategy": strategy, "rerank_top_k": rerank_top_k},
            defaults=KernelService.DEFAULTS)
        self.config = cfg
        self.store = store if store is not None else TranspositionStore()
        # default hardware target requests are priced against; a single
        # service instance serves mixed-target traffic (per-request
        # override) because the store keys costs by (program, target)
        # and shares rewrites/oracle checks across targets
        self.target = hardware.resolve(cfg.target)
        self.harness = None
        if measure or measure_db is not None:
            from repro.measure.db import MeasureDB
            from repro.measure.harness import (ExecutionHarness,
                                               MeasureConfig)
            db = MeasureDB(measure_db) if measure_db else None
            self.harness = ExecutionHarness(
                db=db, cfg=measure_cfg or MeasureConfig())
        self._engine = EvalEngine(
            policy, store=self.store, workers=workers,
            config=cfg.replace(
                target=self.target.name, measurer=self.harness,
                rerank_top_k=(cfg.rerank_top_k if self.harness else 0)))
        # capacity bound: the store never invalidates for correctness
        # (all entries are pure functions of their keys) but a server
        # fed a stream of DISTINCT kernels grows without bound — evict
        # the coldest slab past the cap (never the whole store)
        self.max_programs = max_programs
        self.evict_slab = evict_slab if evict_slab is not None else \
            max(1, max_programs // 8)
        self.n_requests = 0
        self.n_coalesced = 0
        self.n_warm_starts = 0
        self.n_analysis_rejects = 0   # submissions refused at admission
        self._closed = False
        self._lock = threading.Lock()
        self._inflight: dict[tuple, cf.Future] = {}
        self._pool = cf.ThreadPoolExecutor(
            max_workers=max(1, serve_workers),
            thread_name_prefix="kernel-svc")

    # -- async request path --------------------------------------------------
    def _key(self, task, seed, target) -> tuple:
        from repro.core import hardware
        tgt = self.target if target is None else hardware.resolve(target)
        # None stays None (engine default seed): collapsing it onto an
        # integer sentinel would coalesce it with a real seed request
        return (task.fingerprint(), tgt.name,
                None if seed is None else int(seed))

    def _admit(self, task) -> None:
        """Static-analysis admission gate: an ill-formed submission is
        rejected synchronously with its diagnostics
        (``repro.analysis.AnalysisError``) instead of a deep stack
        trace out of the search/lowering machinery.  Memoized through
        the store's per-fingerprint verdict, so the well-formed steady
        state pays one dict lookup per request."""
        if self.store.analysis_ok(task):
            return
        with self._lock:
            self.n_analysis_rejects += 1
        from repro.analysis.legality import check_program
        check_program(task, name=task.name)       # raises AnalysisError

    def submit(self, task, seed: int | None = None,
               target=None) -> cf.Future:
        """Enqueue one optimize request; returns a Future resolving to
        its ``OptimizationResult``.  An identical in-flight request is
        joined rather than re-searched (coalescing).  Submissions that
        fail static analysis raise ``AnalysisError`` here, before any
        search work is enqueued."""
        self._admit(task)
        key = self._key(task, seed, target)
        with self._lock:
            if self._closed:
                raise RuntimeError("KernelService is closed")
            fut = self._inflight.get(key)
            self.n_requests += 1
            if fut is not None:
                self.n_coalesced += 1
                return fut
            fut = self._pool.submit(self._serve_one, key, task, seed,
                                    target)
            self._inflight[key] = fut
            return fut

    def result(self, fut: cf.Future, timeout: float | None = None):
        return fut.result(timeout)

    def _serve_one(self, key, task, seed, target):
        try:
            self._maybe_evict()
            res, stale = self._warm_start(task, seed, target)
            if res is not None:
                return res
            res = self._engine.optimize(task, seed, target=target)
            # force past the merge policy only when the on-disk record
            # provably failed the live oracle (stale after a semantic
            # change): the fresh result must overwrite it even if the
            # stale record was a measured one
            self._record_winner(task, seed, target, res, force=stale)
            return res
        finally:
            with self._lock:
                self._inflight.pop(key, None)

    # -- measured mode: persistent warm start (DESIGN.md §11) ----------------
    def _winner_db_key(self, task, seed,
                       target) -> tuple[str, str, str] | None:
        if self.harness is None or self.harness.db is None:
            return None
        from repro.core import hardware
        tgt = self.target if target is None else hardware.resolve(target)
        # the seed and the search configuration join the key for the
        # same reason the seed joins the coalescing key (_key above):
        # different seeds / strategies / depths are different questions,
        # and a warm answer must only serve its own — a service
        # restarted with max_steps=8 must re-search, not replay the
        # 3-step winner (env_fp covers only the MEASUREMENT config).
        # rerank_top_k is deliberately NOT part of the question:
        # measured reranking refines the ANSWER to the same search
        # (same space, same survivors, measured tiebreak), which is
        # what lets a fleet's background worker hot-swap a replica's
        # analytic pick for a measured one under the same key
        # (DESIGN.md §13) — records carry measured_s so consumers can
        # tell which kind they hold.
        ec = self._engine.cfg
        sig = f"{ec.mode}|{ec.strategy}|{ec.max_steps}|{ec.curated}"
        # a non-default coder is a different question too (an LLM coder
        # may land programs the structured space cannot); the default
        # leaves the signature unchanged so pre-existing winner records
        # keep warm-starting structured services
        if ec.coder != "structured":
            sig += f"|{ec.coder}"
        tkey = f"{task.fingerprint()}#{sig}" if seed is None \
            else f"{task.fingerprint()}#{sig}#s{int(seed)}"
        return (tkey, tgt.name, self.harness.env_fp(tgt))

    def _warm_start(self, task, seed, target):
        """Answer from the on-disk winner record, if one exists for this
        (task, target, environment) — no search, no measurement; the
        oracle check still runs against the live store so a warm answer
        is graded exactly like a fresh one.  The record may come from a
        prior session OR from a peer replica sharing the directory
        (``get_winner`` revalidates by file stamp).  Returns
        ``(result | None, stale)``: ``stale`` marks an on-disk record
        that failed the live oracle, which the fresh search's result
        must force-overwrite."""
        key = self._winner_db_key(task, seed, target)
        if key is None:
            return None, False
        rec = self.harness.db.get_winner(*key)
        if rec is None:
            return None, False
        if self._engine.cfg.rerank_top_k > 0 \
                and rec.get("measured_s") is None:
            # a MEASURING service must not serve an unmeasured record:
            # re-search (cheap against a warm store), measure the
            # survivors, and upgrade the record — the fleet hot-swap
            # path (the merge policy below makes the upgrade stick)
            return None, False
        from repro.core.kernel_ir import program_from_json
        from repro.core.pipeline import OptimizationResult
        prog = program_from_json(rec["program"])
        correct = self.store.check(task, prog)
        if not correct:
            # a winner that no longer passes the live oracle (repo code
            # changed under the same env fingerprint) must not be
            # served — fall through to a fresh search, whose result
            # overwrites the stale record
            return None, True
        with self._lock:
            self.n_warm_starts += 1
        return OptimizationResult(
            task.name, prog, correct, float(rec["speedup"]),
            int(rec["steps"]), 0, tuple(prog.history),
            measured_s=rec.get("measured_s"),
            measured_baseline_s=rec.get("measured_baseline_s"),
            reranked=bool(rec.get("reranked", False))), False

    def _record_winner(self, task, seed, target, res, *,
                       force: bool = False) -> None:
        key = self._winner_db_key(task, seed, target)
        if key is None or not res.correct:
            return
        from repro.core.kernel_ir import program_to_json
        rec = {
            "task": res.task,
            "program": program_to_json(res.program),
            "speedup": float(res.speedup),
            "steps": int(res.steps),
            "measured_s": res.measured_s,
            "measured_baseline_s": res.measured_baseline_s,
            "reranked": bool(res.reranked)}

        def merge(old):
            # last-write-wins across replicas EXCEPT an analytic pick
            # never downgrades a measured winner for the same question
            # (a background refiner may have upgraded the record while
            # we searched); force=True — the stale-oracle fallback —
            # always overwrites
            if old is not None and not force \
                    and old.get("measured_s") is not None \
                    and rec["measured_s"] is None:
                return None
            return rec
        self.harness.db.update_winner(*key, merge)

    def close(self) -> None:
        """Deterministic shutdown: after close() returns, every future
        handed out by ``submit`` — coalesced joiners included — is
        resolved (queued work is drained, never cancelled), no new
        submissions are accepted (``RuntimeError``), and a second
        close() is a no-op.  A caller blocked on ``result()`` therefore
        never hangs on a shut-down service."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._pool.shutdown(wait=True)

    # -- capacity ------------------------------------------------------------
    def _maybe_evict(self) -> None:
        if len(self.store.programs) <= self.max_programs:
            return
        with self._lock:
            protect = {k[0] for k in self._inflight}
        self.store.evict_lru(
            keep=max(self.max_programs - self.evict_slab, 0),
            protect=protect)

    # -- sync entry points ---------------------------------------------------
    def optimize(self, task, seed: int | None = None, target=None):
        """One request -> OptimizationResult (cached substrate).

        ``target`` prices this request against a different registered
        chip; transitions/oracle entries are shared with every other
        target's requests (only cost memos are per-target).  Runs
        through ``submit`` so identical concurrent callers coalesce."""
        return self.result(self.submit(task, seed, target))

    def optimize_install(self, task, kernel: str, key: str, *,
                         seed: int | None = None, target=None):
        """Optimize and install the winning schedule into the kernel
        registry under the request's target
        (``ops.set_schedule(kernel, key, sched, target)``) — the serving
        path picks it up when that target is active."""
        from repro.core import hardware
        from repro.core.autotune import _extract_schedule
        from repro.kernels import ops
        res = self.optimize(task, seed, target=target)
        sched = _extract_schedule(res.program, kernel)
        if sched is not None and res.correct:
            tgt = self.target if target is None else \
                hardware.resolve(target)
            ops.set_schedule(kernel, key, sched, target=tgt)
        return res, sched

    def optimize_batch(self, tasks) -> dict:
        with self._lock:
            if self._closed:
                raise RuntimeError("KernelService is closed")
            # under the lock like every other counter bump: suite
            # evaluation runs concurrently with submit()-path requests,
            # and an unlocked += here loses increments under contention
            self.n_requests += len(tasks)
        self._maybe_evict()
        return self._engine.evaluate_suite(tasks)

    @property
    def load(self) -> int:
        """In-flight (submitted, unresolved) request count — the
        routing signal a fleet dispatcher balances on."""
        with self._lock:
            return len(self._inflight)

    def stats(self) -> dict:
        m = (self.harness.stats_dict() if self.harness is not None
             else {"measured": 0, "db_hits": 0, "db_misses": 0,
                   "verify_fallbacks": 0})
        with self._lock:
            # one consistent snapshot: n_requests/_inflight mutate under
            # this lock on the request path, and stats() may race it
            n_req, n_coal = self.n_requests, self.n_coalesced
            n_warm, inflight = self.n_warm_starts, len(self._inflight)
            n_rej = self.n_analysis_rejects
        coder_stats = getattr(self._engine.coder, "stats_dict", None)
        coder = coder_stats() if callable(coder_stats) else {
            "coder_name": getattr(self._engine.coder, "name",
                                  "structured")}
        return dict(self.store.stats_dict(), **coder, requests=n_req,
                    coalesced=n_coal,
                    inflight=inflight,
                    submit_analysis_rejects=n_rej,
                    target=self.target.name,
                    measured=m["measured"], db_hits=m["db_hits"],
                    db_misses=m["db_misses"],
                    verify_fallbacks=m["verify_fallbacks"],
                    warm_starts=n_warm,
                    db_corrupt_records=m.get("db_corrupt_records", 0),
                    db_tmp_reaped=m.get("db_tmp_reaped", 0),
                    db_lock_timeouts=m.get("db_lock_timeouts", 0),
                    db_winner_refreshes=m.get("db_winner_refreshes", 0))


KernelService.DEFAULTS = OptimizeConfig(mode="greedy_cost",
                                        rerank_top_k=4)
