"""Prompt construction and response parsing for the LLM micro-coder.

The serialization direction: a ``KernelProgram`` plus the Macro
``Action`` become a *propose-one-delta* prompt — the backend is asked
to return the FULL rewritten program as one JSON object in the
``program_to_json`` schema, implementing exactly the one semantic
action proposed (the paper's Micro Coding contract: one atomic
optimization per step, never a whole-kernel regeneration).

The parsing direction: ``parse_response`` extracts the first JSON
object from the completion (tolerating chat framing and markdown code
fences) and ``kernel_ir.program_from_json`` rebuilds the program.  The
repair loop owns everything after that — identity/history stamping,
the static-analysis gate, the numeric oracle.

Prompts are deterministic in (program structure, action, feedback):
the program is serialized with a neutral name and an empty history so
two routes reaching the same fingerprint ask the byte-identical
question — the property that makes transcript replay and the
transposition store compose (DESIGN.md §16).
"""
from __future__ import annotations

import json

from repro.core import rules as R
from repro.core.kernel_ir import (KernelProgram, program_from_json,
                                  program_to_json)


class ResponseParseError(ValueError):
    """The completion held no parseable program JSON."""


_INSTRUCTIONS = """\
You are a GPU/TPU kernel micro-coder.  You receive one kernel program
in a JSON IR and ONE semantic optimization action proposed by a
planning policy.  Implement exactly that action as a rewrite of the
program and return the FULL rewritten program as a single JSON object
in the same schema.  Rules:
- implement only the proposed action; change nothing else;
- keep the "inputs" and "outputs" contracts identical (same names,
  shapes, dtypes) — the result is verified numerically against the
  original;
- schedule legality: tiles must divide their dimension, matmul-family
  tiles must be multiples of 8, tiled buffers x pipeline depth must
  fit 16MiB of VMEM;
- reply with the JSON object only (no prose)."""


def render_program(prog: KernelProgram) -> str:
    """Deterministic, route-independent serialization for prompting."""
    neutral = prog.replace(name="kernel", history=())
    return json.dumps(program_to_json(neutral), sort_keys=True)


def build_prompt(prog: KernelProgram, act, feedback=()) -> str:
    parts = [_INSTRUCTIONS,
             "\n## Program\n" + render_program(prog),
             "\n## Proposed action\n" + R.describe(act)]
    if feedback:
        parts.append(
            "\n## Previous attempt failed verification\n"
            "Your earlier rewrite for this action was rejected.  Fix "
            "the problems below and return a corrected program:\n"
            + "\n".join(f"- {f}" for f in feedback))
    return "\n".join(parts)


def extract_json(text: str) -> dict:
    """First JSON object in ``text``: tolerate code fences and prose
    around it by brace-scanning from the first ``{``."""
    start = text.find("{")
    if start < 0:
        raise ResponseParseError("no JSON object in response")
    depth = 0
    in_str = esc = False
    for i in range(start, len(text)):
        ch = text[i]
        if in_str:
            if esc:
                esc = False
            elif ch == "\\":
                esc = True
            elif ch == '"':
                in_str = False
            continue
        if ch == '"':
            in_str = True
        elif ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
            if depth == 0:
                try:
                    return json.loads(text[start:i + 1])
                except json.JSONDecodeError as e:
                    raise ResponseParseError(
                        f"malformed JSON object: {e}") from e
    raise ResponseParseError("unterminated JSON object in response")


def parse_response(text: str) -> KernelProgram:
    """Completion text -> ``KernelProgram`` (identity not yet stamped:
    the repair loop overrides name/history from the actual parent)."""
    if not isinstance(text, str) or not text.strip():
        raise ResponseParseError("empty response")
    payload = extract_json(text)
    try:
        return program_from_json(payload)
    except ResponseParseError:
        raise
    except Exception as e:
        raise ResponseParseError(
            f"JSON does not decode to a program: {e}") from e
